from repro.checkpoint.ckpt import (CheckpointFuture, all_steps, latest_step,
                                   load_extra, load_flat, restore_checkpoint,
                                   save_checkpoint)

__all__ = ["save_checkpoint", "restore_checkpoint", "all_steps",
           "latest_step", "load_flat", "load_extra", "CheckpointFuture"]
