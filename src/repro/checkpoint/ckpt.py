"""Sharded, manifest-driven checkpointing with elastic restore.

Layout per step::

    <dir>/step_000100/
        manifest.json            # tree structure, shapes, dtypes, writer info
        host_000.npz             # this host's addressable shards
        COMMITTED                # written last -> crash-safe atomicity

Restore is **elastic**: the manifest stores logical (global) shapes, restore
re-shards onto whatever mesh/sharding the caller provides (different chip
count than the writer is fine).  ``save_checkpoint(..., background=True)``
runs serialization off the training thread; callers sync via the returned
:class:`CheckpointFuture`, whose ``join()`` **re-raises** any exception the
background write hit — a failed serialization must surface as a loud crash
at the next sync point, never as a silently missing step.

Device->host transfer happens eagerly (cheap: addressable shards only); only
file IO is deferred to the background thread.

Beyond parameter trees, the layout doubles as the generic atomic snapshot
transport for the serving layer (DESIGN.md §11): ``extra=`` attaches a
JSON-serializable payload to the manifest (scheduler metadata), and a
checkpoint saved from a *flat* ``{name: array}`` dict can be loaded back
without an abstract tree via :func:`load_flat` — which is how
``GenServer.snapshot`` / ``GenServer.restore`` move lane state.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _key(i: int) -> str:
    return f"leaf_{i:05d}"


def _to_serializable(arr: np.ndarray) -> np.ndarray:
    """npz-safe view: ml_dtypes (bf16/f8) round-trip as uint views."""
    if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype) or \
            "float8" in str(arr.dtype):
        return arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
    return arr


def _from_serializable(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    import ml_dtypes
    if "bfloat16" in dtype_str:
        return arr.view(ml_dtypes.bfloat16)
    if "float8_e4m3" in dtype_str:
        return arr.view(ml_dtypes.float8_e4m3fn)
    return arr


class CheckpointFuture:
    """Handle to a background checkpoint write.

    ``join()`` blocks until the write finishes and **re-raises** any
    exception it hit.  The pre-fix daemon-thread path printed the traceback
    to stderr and dropped it: a full disk or doctored serializer lost the
    step silently, and the train loop kept checkpoint-gating on a file that
    did not exist.  Every sync point (the next save, the recovery path, the
    end of training) now surfaces the failure instead.
    """

    def __init__(self, target):
        self._exc: BaseException | None = None

        def _run():
            try:
                target()
            except BaseException as e:     # re-raised on join(), never lost
                self._exc = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def join(self, timeout: float | None = None) -> None:
        self._thread.join(timeout)
        if self._exc is not None:
            raise self._exc

    def is_alive(self) -> bool:
        return self._thread.is_alive()


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3,
                    background: bool = False,
                    extra: dict | None = None) -> CheckpointFuture | None:
    """Save a pytree of (possibly sharded) jax arrays / numpy arrays.

    ``extra`` (JSON-serializable) rides in the manifest — scheduler/loop
    metadata next to the array payload, read back via :func:`load_extra` or
    :func:`load_flat`.  When ``tree`` is a flat ``{name: array}`` dict the
    manifest also records the key order, so :func:`load_flat` can restore
    it without an abstract tree.
    """
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "shapes": [list(l.shape) for l in host_leaves],
        "dtypes": [str(l.dtype) for l in host_leaves],
        "process_count": jax.process_count(),
    }
    if extra is not None:
        manifest["extra"] = extra
    if isinstance(tree, dict) and all(
            not isinstance(v, (dict, list, tuple)) for v in tree.values()):
        # flat dict of arrays: jax flattens by sorted key, record that order
        # (a nested dict that happens to hold one leaf per top-level key
        # must NOT qualify — its leaf order would not match the key list)
        manifest["flat_keys"] = sorted(tree)

    def _write():
        final = os.path.join(directory, f"step_{step:06d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"host_{jax.process_index():03d}.npz"),
                 **{_key(i): _to_serializable(l)
                    for i, l in enumerate(host_leaves)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(directory, keep)

    if background:
        return CheckpointFuture(_write)
    _write()
    return None


def _gc(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:06d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "COMMITTED")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def _read_manifest(directory: str, step: int) -> dict:
    path = os.path.join(directory, f"step_{step:06d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def load_extra(directory: str, step: int) -> dict | None:
    """The manifest's ``extra`` payload (or None if the save had none)."""
    return _read_manifest(directory, step).get("extra")


def load_flat(directory: str, step: int) -> tuple[dict, dict | None]:
    """Load a checkpoint saved from a flat ``{name: array}`` dict.

    Returns ``(arrays, extra)`` — no abstract tree needed: the manifest
    recorded the key order at save time.  This is the transport the serving
    layer's lane snapshots use (DESIGN.md §11).
    """
    manifest = _read_manifest(directory, step)
    keys = manifest.get("flat_keys")
    if keys is None:
        raise ValueError(
            f"checkpoint at step {step} was not saved from a flat dict "
            f"(no flat_keys in manifest); use restore_checkpoint")
    path = os.path.join(directory, f"step_{step:06d}")
    data = np.load(os.path.join(path, f"host_{jax.process_index():03d}.npz"))
    arrays = {k: _from_serializable(data[_key(i)], manifest["dtypes"][i])
              for i, k in enumerate(keys)}
    return arrays, manifest.get("extra")


def restore_checkpoint(directory: str, step: int, abstract_tree,
                       shardings=None):
    """Restore into the structure of ``abstract_tree``.

    ``shardings``: optional matching pytree of NamedSharding — arrays are
    device_put with them (elastic re-shard onto the current mesh).
    """
    path = os.path.join(directory, f"step_{step:06d}")
    manifest = _read_manifest(directory, step)
    data = np.load(os.path.join(path, f"host_{jax.process_index():03d}.npz"))
    leaves, treedef = _flatten(abstract_tree)
    assert len(leaves) == len(manifest["shapes"]), "tree structure changed"
    restored = []
    for i, ref in enumerate(leaves):
        arr = _from_serializable(data[_key(i)], manifest["dtypes"][i])
        if list(arr.shape) != list(ref.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != model {ref.shape}")
        restored.append(arr.astype(ref.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    return tree
