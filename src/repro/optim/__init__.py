from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.loss_scale import (DynamicLossScale, LossScaleState,
                                    select_tree)
from repro.optim.schedules import cosine_schedule, linear_warmup

__all__ = ["adamw_init", "adamw_update", "cosine_schedule", "linear_warmup",
           "DynamicLossScale", "LossScaleState", "select_tree"]
