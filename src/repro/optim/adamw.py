"""AdamW with fp32 master weights, built from scratch (no optax here).

State layout (pytree-of-dicts mirroring params):
  master  — fp32 copy of the parameters (authoritative)
  mu, nu  — fp32 first/second moments
  step    — scalar int32

Optimizer state inherits each parameter's sharding (FSDP keeps the 3x fp32
state sharded alongside the bf16 compute copy).  Gradient clipping is by
global norm.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    master: Any
    mu: Any
    nu: Any


def adamw_init(params, *, memory_mode: str = "fp32") -> AdamWState:
    """``memory_mode='bf16'`` drops the fp32 master and keeps bf16 moments —
    6 bytes/param instead of 14, the knob that lets a 398B model train on a
    single 256-chip pod (update math stays f32; stochastic rounding
    recommended on real hardware)."""
    if memory_mode == "bf16":
        zeros = lambda p: jnp.zeros(p.shape, jnp.bfloat16)
        master = None  # bf16 params ARE the master copy
    else:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        master=master,
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, *, lr: jax.Array,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (new_params, new_state).  ``lr`` may be a traced scalar."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * g
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat, vhat = mf / c1, vf / c2
        wf = w.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + eps) + weight_decay * w.astype(jnp.float32))
        return mf.astype(m.dtype), vf.astype(v.dtype), wf.astype(w.dtype)

    masters = state.master if state.master is not None else params
    out = jax.tree.map(upd, grads, state.mu, state.nu, masters)
    is_triple = lambda t: isinstance(t, tuple) and len(t) == 3
    mu = jax.tree.map(lambda t: t[0], out, is_leaf=is_triple)
    nu = jax.tree.map(lambda t: t[1], out, is_leaf=is_triple)
    master = jax.tree.map(lambda t: t[2], out, is_leaf=is_triple)
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    if state.master is None:
        return new_params, AdamWState(step, None, mu, nu), gnorm
    return new_params, AdamWState(step, master, mu, nu), gnorm
