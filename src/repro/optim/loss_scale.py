"""Dynamic loss scaling for bf16 training (DESIGN.md §12).

bf16 keeps fp32's exponent range, so classic fp16-style underflow is rare —
but tiny late-layer gradients still lose mantissa bits, and a single
overflowing step (inf/nan from a degenerate batch) must not corrupt the
fp32 master weights.  The standard recipe handles both:

* the loss is multiplied by ``scale`` before ``grad`` (so the backward pass
  carries amplified values), and the gradients are divided by it after;
* if any unscaled gradient is non-finite, the step is *skipped* and the
  scale halves (``backoff``);
* after ``growth_interval`` consecutive finite steps the scale doubles,
  probing the headroom back.

All state transitions are branchless (``jnp.where``) so the update jits
into the train step.  The scaler is a frozen config dataclass +
:class:`LossScaleState` NamedTuple — the same pattern as
``repro.optim.adamw`` (functional, pytree-friendly).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class LossScaleState(NamedTuple):
    """Dynamic-scale state: the current scale and the finite-step streak."""
    scale: jax.Array        # fp32 scalar
    good_steps: jax.Array   # int32 scalar — consecutive finite steps


@dataclasses.dataclass(frozen=True)
class DynamicLossScale:
    """Config + pure transition functions of the dynamic loss scaler."""

    init_scale: float = 2.0 ** 15
    growth_factor: float = 2.0
    backoff_factor: float = 0.5
    growth_interval: int = 200      # finite steps between growth probes
    min_scale: float = 1.0
    max_scale: float = 2.0 ** 24

    def init(self) -> LossScaleState:
        return LossScaleState(jnp.asarray(self.init_scale, jnp.float32),
                              jnp.zeros((), jnp.int32))

    def scale(self, state: LossScaleState, loss: jax.Array) -> jax.Array:
        """Amplify the loss (in fp32) before differentiation."""
        return loss.astype(jnp.float32) * state.scale

    def unscale(self, state: LossScaleState, grads):
        """Divide a gradient pytree by the current scale (in fp32)."""
        inv = 1.0 / state.scale
        return jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32) * inv, grads)

    @staticmethod
    def all_finite(grads) -> jax.Array:
        """Scalar bool: every element of every leaf is finite."""
        leaves = jax.tree_util.tree_leaves(grads)
        if not leaves:
            return jnp.asarray(True)
        return jnp.all(jnp.stack([jnp.all(jnp.isfinite(g)) for g in leaves]))

    def update(self, state: LossScaleState,
               finite: jax.Array) -> LossScaleState:
        """Branchless post-step transition: backoff, hold, or grow."""
        grown = state.good_steps + 1 >= self.growth_interval
        next_scale = jnp.where(
            finite,
            jnp.where(grown, state.scale * self.growth_factor, state.scale),
            state.scale * self.backoff_factor)
        next_scale = jnp.clip(next_scale, self.min_scale, self.max_scale)
        next_good = jnp.where(finite & ~grown, state.good_steps + 1, 0)
        return LossScaleState(next_scale.astype(jnp.float32),
                              next_good.astype(jnp.int32))


def select_tree(pred: jax.Array, on_true, on_false):
    """``jnp.where`` over matching pytrees — applies a step conditionally
    (skipped steps keep params/optimizer state bit-identical)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(pred, a, b), on_true, on_false)


__all__ = ["DynamicLossScale", "LossScaleState", "select_tree"]
