import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Perf-iteration harness (§Perf): re-lower a cell with a named variant and
report the roofline-term deltas vs baseline.

  PYTHONPATH=src python -m repro.launch.perf --arch qwen3-32b \
      --shape train_4k --variants baseline,mb1,dots

Variants compose config/step overrides; every run writes
results/perf/<arch>__<shape>__<variant>.json.
"""

import argparse
import json


VARIANTS = {
    "baseline": {},
    "mb1": {"microbatches": 1},
    "mb2": {"microbatches": 2},
    "mb4": {"microbatches": 4},
    "mb8": {"microbatches": 8},
    "mb16": {"microbatches": 16},
    "dots": {"remat_policy": "dots"},          # save dot outputs in remat
    "nothing": {"remat_policy": "nothing"},
    "noremat": {"remat": False},
    "mb1_dots": {"microbatches": 1, "remat_policy": "dots"},
    "mb2_dots": {"microbatches": 2, "remat_policy": "dots"},
    "f32opt_off": {"opt_memory_mode": "bf16"},
    "nosp": {"no_seq_sp": True},
    "mb1_nosp": {"microbatches": 1, "no_seq_sp": True},
}


def run_variant(arch: str, shape: str, variant: str, *, multi_pod: bool,
                out_dir: str = "results/perf") -> dict:
    import jax

    from repro.configs import get_config
    from repro.distributed import hlo_analysis as ha
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell

    ov = dict(VARIANTS[variant])
    cfg = get_config(arch)
    cfg_kw = {k: v for k, v in ov.items()
              if k in ("remat", "remat_policy", "opt_memory_mode")}
    if cfg_kw:
        cfg = cfg.replace(**cfg_kw)
    mb = ov.get("microbatches")
    from repro.models import layers as _layers
    _layers.DISABLE_SEQ_SP = bool(ov.get("no_seq_sp", False))

    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, _ = lower_cell(cfg, shape, mesh, microbatches=mb)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    a = ha.analyze(compiled.as_text())
    terms = ha.roofline_terms(a)
    rec = {
        "arch": arch, "shape": shape, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mem_gb": round((mem.argument_size_in_bytes + mem.temp_size_in_bytes
                         + mem.output_size_in_bytes
                         - mem.alias_size_in_bytes) / 2**30, 3),
        "flops_per_chip": a.flops,
        "hbm_bytes_per_chip": a.hbm_bytes,
        "collective_wire_bytes": a.collective_wire_bytes,
        "collectives": {k: {"count": v.count, "wire": v.wire_bytes}
                        for k, v in a.collectives.items()},
        "roofline": terms,
        "bound": max(terms, key=terms.get).replace("_s", ""),
        "step_time_overlap_s": max(terms.values()),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(f"{out_dir}/{arch}__{shape}__{rec['mesh']}__{variant}.json",
              "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    base = None
    for v in args.variants.split(","):
        r = run_variant(args.arch, args.shape, v, multi_pod=args.multi_pod)
        t = r["roofline"]
        line = (f"{v:12s} mem={r['mem_gb']:8.2f}GB "
                f"comp={t['compute_s']:7.2f}s mem_t={t['memory_s']:7.2f}s "
                f"coll={t['collective_s']:7.2f}s bound={r['bound']:10s} "
                f"overlap_step={r['step_time_overlap_s']:7.2f}s")
        if base is None:
            base = r
        else:
            d = r["step_time_overlap_s"] / base["step_time_overlap_s"] - 1
            line += f"  vs-base {100*d:+.1f}%"
        print(line, flush=True)


if __name__ == "__main__":
    main()
