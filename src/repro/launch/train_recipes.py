"""Mixed-precision train recipes for the conv workloads (DESIGN.md §12).

One recipe per decomposition workload — ENet / ESPNet (segmentation NLL)
and the DCGAN generator (pixel regression smoke objective) — each wiring
the same four-part bf16 contract around the model's ``forward``:

* **fp32 masters**: parameters (and AdamW state) stay fp32; the forward
  casts per-layer via ``compute_dtype`` so only activations are bf16;
* **fp32 loss**: logits/images are promoted to fp32 before the reduction,
  so the objective itself never rounds in bf16;
* **dynamic loss scaling** (:class:`repro.optim.DynamicLossScale`): the
  loss is amplified before ``grad`` and the gradients divided after;
* **skip-on-nonfinite**: a step whose unscaled gradients contain inf/nan
  applies *no* update (params and optimizer state pass through bitwise via
  :func:`repro.optim.select_tree`) and backs the scale off.

``compute_dtype=None`` degenerates to the plain fp32 step (the scaler
still runs, at scale 1 if configured so) — the parity tests train both
and compare.  Everything jits into ONE step function; the skip logic is
branchless so a skipped step costs the same dispatch.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import dcgan, enet, espnet
from repro.optim import (DynamicLossScale, LossScaleState, adamw_init,
                         adamw_update, select_tree)

#: workloads with a recipe here (DCGAN trains the generator alone against a
#: pixel target — the adversarial game is out of scope for a step recipe).
RECIPES = ("enet", "espnet", "dcgan")


class TrainState(NamedTuple):
    """Everything one recipe step threads: fp32 params + AdamW + scaler."""
    params: dict
    opt: object
    scale: LossScaleState


def _seg_loss(forward, params, batch, **fw_kw):
    """Mean per-pixel NLL, reduced in fp32 regardless of compute dtype."""
    logits = forward(params, batch["image"], **fw_kw)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["label"][..., None], axis=-1)
    return jnp.mean(nll)


def _gen_loss(params, batch, **fw_kw):
    """Generator pixel-regression smoke objective (fp32 reduction)."""
    img = dcgan.forward(params, batch["z"], **fw_kw)
    err = img.astype(jnp.float32) - batch["target"].astype(jnp.float32)
    return jnp.mean(jnp.square(err))


def _loss_fn(model: str, *, backend: str, decomposed: bool,
             interpret: bool | None, compute_dtype: str | None):
    if model == "enet":
        kw = dict(backend=backend, decomposed=decomposed,
                  compute_dtype=compute_dtype)
        return functools.partial(_seg_loss, enet.forward, **kw)
    if model == "espnet":
        kw = dict(backend=backend, decomposed=decomposed,
                  compute_dtype=compute_dtype)
        return functools.partial(_seg_loss, espnet.forward, **kw)
    if model == "dcgan":
        kw = dict(backend=backend, decomposed=decomposed,
                  interpret=interpret, compute_dtype=compute_dtype)
        return functools.partial(_gen_loss, **kw)
    raise ValueError(f"unknown recipe {model!r}; known: {RECIPES}")


def init_state(params: dict,
               scaler: DynamicLossScale | None = None) -> TrainState:
    """fp32 masters + AdamW state + loss-scale state for a recipe step."""
    params = jax.tree_util.tree_map(
        lambda p: p.astype(jnp.float32), params)
    scaler = scaler or DynamicLossScale()
    return TrainState(params, adamw_init(params), scaler.init())


def make_train_step(model: str, *, backend: str = "xla",
                    decomposed: bool = True, interpret: bool | None = None,
                    compute_dtype: str | None = None,
                    scaler: DynamicLossScale | None = None,
                    lr: float = 1e-3, weight_decay: float = 1e-4):
    """Jitted ``step(state, batch) -> (state', metrics)`` for one recipe.

    ``batch`` is ``{"image", "label"}`` for the segmentation recipes and
    ``{"z", "target"}`` for the generator.  Metrics: ``loss`` (unscaled,
    fp32), ``grad_norm`` (of the *applied* gradients; 0 on a skipped
    step), ``scale`` (loss scale after the update), ``skipped`` (1.0 when
    non-finite gradients suppressed the update).
    """
    scaler = scaler or DynamicLossScale()
    loss_fn = _loss_fn(model, backend=backend, decomposed=decomposed,
                       interpret=interpret, compute_dtype=compute_dtype)

    @jax.jit
    def step(state: TrainState, batch: dict):
        def scaled_loss(p):
            loss = loss_fn(p, batch)
            return scaler.scale(state.scale, loss), loss

        (_, loss), grads = jax.value_and_grad(scaled_loss,
                                              has_aux=True)(state.params)
        grads = scaler.unscale(state.scale, grads)
        finite = scaler.all_finite(grads)
        # a non-finite gradient must not reach the AdamW moments: zero the
        # grads before the update, then discard the whole update anyway
        zeros = jax.tree_util.tree_map(jnp.zeros_like, grads)
        safe = select_tree(finite, grads, zeros)
        new_params, new_opt, gnorm = adamw_update(
            safe, state.opt, state.params, lr=jnp.float32(lr),
            weight_decay=weight_decay)
        new_params = select_tree(finite, new_params, state.params)
        new_opt = select_tree(finite, new_opt, state.opt)
        scale_state = scaler.update(state.scale, finite)
        metrics = {"loss": loss,
                   "grad_norm": jnp.where(finite, gnorm, 0.0),
                   "scale": scale_state.scale,
                   "skipped": 1.0 - finite.astype(jnp.float32)}
        return TrainState(new_params, new_opt, scale_state), metrics

    return step


__all__ = ["RECIPES", "TrainState", "init_state", "make_train_step"]
