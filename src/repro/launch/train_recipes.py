"""Mixed-precision train recipes for the conv workloads (DESIGN.md §12).

One recipe per decomposition workload — ENet / ESPNet (segmentation NLL)
and the DCGAN generator (pixel regression smoke objective) — each wiring
the same four-part bf16 contract around the model's ``forward``:

* **fp32 masters**: parameters (and AdamW state) stay fp32; the forward
  casts per-layer via ``compute_dtype`` so only activations are bf16;
* **fp32 loss**: logits/images are promoted to fp32 before the reduction,
  so the objective itself never rounds in bf16;
* **dynamic loss scaling** (:class:`repro.optim.DynamicLossScale`): the
  loss is amplified before ``grad`` and the gradients divided after;
* **skip-on-nonfinite**: a step whose unscaled gradients contain inf/nan
  applies *no* update (params and optimizer state pass through bitwise via
  :func:`repro.optim.select_tree`) and backs the scale off.

``compute_dtype=None`` degenerates to the plain fp32 step (the scaler
still runs, at scale 1 if configured so) — the parity tests train both
and compare.  Everything jits into ONE step function; the skip logic is
branchless so a skipped step costs the same dispatch.

:func:`make_sharded_train_step` is the multi-device variant (DESIGN.md
§13): the batch is pre-chunked into a fixed number of *virtual shards*
(independent of the mesh size), per-chunk gradients are taken under
``shard_map`` over the data axes, and the cross-device reduction goes
through :func:`repro.distributed.compression.mesh_allreduce` — an
all-gather of the chunk stacks plus ONE fixed-order sum, so the
reduction tree (and therefore every fp32 rounding) is identical on every
mesh size.  With the dense transport the step is 1-device ≡ N-device
*bitwise*; the bf16 transport halves the collective's wire size and is
held to convergence bounds instead.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import compression as _compression
from repro.distributed import sharding as _sharding
from repro.models import dcgan, enet, espnet
from repro.optim import (DynamicLossScale, LossScaleState, adamw_init,
                         adamw_update, select_tree)

#: workloads with a recipe here (DCGAN trains the generator alone against a
#: pixel target — the adversarial game is out of scope for a step recipe).
RECIPES = ("enet", "espnet", "dcgan")


class TrainState(NamedTuple):
    """Everything one recipe step threads: fp32 params + AdamW + scaler."""
    params: dict
    opt: object
    scale: LossScaleState


def _seg_loss(forward, params, batch, **fw_kw):
    """Mean per-pixel NLL, reduced in fp32 regardless of compute dtype."""
    logits = forward(params, batch["image"], **fw_kw)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["label"][..., None], axis=-1)
    return jnp.mean(nll)


def _gen_loss(params, batch, **fw_kw):
    """Generator pixel-regression smoke objective (fp32 reduction)."""
    img = dcgan.forward(params, batch["z"], **fw_kw)
    err = img.astype(jnp.float32) - batch["target"].astype(jnp.float32)
    return jnp.mean(jnp.square(err))


def _loss_fn(model: str, *, backend: str, decomposed: bool,
             interpret: bool | None, compute_dtype: str | None):
    if model == "enet":
        kw = dict(backend=backend, decomposed=decomposed,
                  compute_dtype=compute_dtype)
        return functools.partial(_seg_loss, enet.forward, **kw)
    if model == "espnet":
        kw = dict(backend=backend, decomposed=decomposed,
                  compute_dtype=compute_dtype)
        return functools.partial(_seg_loss, espnet.forward, **kw)
    if model == "dcgan":
        kw = dict(backend=backend, decomposed=decomposed,
                  interpret=interpret, compute_dtype=compute_dtype)
        return functools.partial(_gen_loss, **kw)
    raise ValueError(f"unknown recipe {model!r}; known: {RECIPES}")


def init_state(params: dict,
               scaler: DynamicLossScale | None = None) -> TrainState:
    """fp32 masters + AdamW state + loss-scale state for a recipe step."""
    params = jax.tree_util.tree_map(
        lambda p: p.astype(jnp.float32), params)
    scaler = scaler or DynamicLossScale()
    return TrainState(params, adamw_init(params), scaler.init())


def make_train_step(model: str, *, backend: str = "xla",
                    decomposed: bool = True, interpret: bool | None = None,
                    compute_dtype: str | None = None,
                    scaler: DynamicLossScale | None = None,
                    lr: float = 1e-3, weight_decay: float = 1e-4):
    """Jitted ``step(state, batch) -> (state', metrics)`` for one recipe.

    ``batch`` is ``{"image", "label"}`` for the segmentation recipes and
    ``{"z", "target"}`` for the generator.  Metrics: ``loss`` (unscaled,
    fp32), ``grad_norm`` (of the *applied* gradients; 0 on a skipped
    step), ``scale`` (loss scale after the update), ``skipped`` (1.0 when
    non-finite gradients suppressed the update).
    """
    scaler = scaler or DynamicLossScale()
    loss_fn = _loss_fn(model, backend=backend, decomposed=decomposed,
                       interpret=interpret, compute_dtype=compute_dtype)

    @jax.jit
    def step(state: TrainState, batch: dict):
        def scaled_loss(p):
            loss = loss_fn(p, batch)
            return scaler.scale(state.scale, loss), loss

        (_, loss), grads = jax.value_and_grad(scaled_loss,
                                              has_aux=True)(state.params)
        grads = scaler.unscale(state.scale, grads)
        finite = scaler.all_finite(grads)
        # a non-finite gradient must not reach the AdamW moments: zero the
        # grads before the update, then discard the whole update anyway
        zeros = jax.tree_util.tree_map(jnp.zeros_like, grads)
        safe = select_tree(finite, grads, zeros)
        new_params, new_opt, gnorm = adamw_update(
            safe, state.opt, state.params, lr=jnp.float32(lr),
            weight_decay=weight_decay)
        new_params = select_tree(finite, new_params, state.params)
        new_opt = select_tree(finite, new_opt, state.opt)
        scale_state = scaler.update(state.scale, finite)
        metrics = {"loss": loss,
                   "grad_norm": jnp.where(finite, gnorm, 0.0),
                   "scale": scale_state.scale,
                   "skipped": 1.0 - finite.astype(jnp.float32)}
        return TrainState(new_params, new_opt, scale_state), metrics

    return step


# ---------------------------------------------------------------------------
# Sharded train step (DESIGN.md §13)
# ---------------------------------------------------------------------------

def shard_batch(mesh, batch: dict, *, virtual_shards: int = 8):
    """Pre-chunk a recipe batch into ``(C, B/C, ...)`` and place it.

    ``C = virtual_shards`` is FIXED (independent of the mesh), so the chunk
    boundaries — and with them every per-chunk rounding — never move when the
    device count changes.  The leading chunk axis shards over the mesh's data
    axes; each device vmaps over its local chunks.
    """
    c = virtual_shards
    nd = _sharding.data_axis_size(mesh)
    if c % nd:
        raise ValueError(
            f"virtual_shards={c} must be a multiple of the data-axis "
            f"extent {nd} so every device holds whole chunks")

    def chunk(x):
        b = x.shape[0]
        if b % c:
            raise ValueError(
                f"batch dim {b} not divisible by virtual_shards={c}")
        return x.reshape((c, b // c) + x.shape[1:])

    axes = _sharding.data_axes(mesh)
    spec = P(axes if len(axes) > 1 else axes[0])
    return jax.device_put(jax.tree_util.tree_map(chunk, batch),
                          NamedSharding(mesh, spec))


def place_state(mesh, state: TrainState) -> TrainState:
    """Replicate a :class:`TrainState` over every device of the mesh."""
    return jax.device_put(state, _sharding.replicated(mesh))


def make_sharded_train_step(model: str, mesh, *, virtual_shards: int = 8,
                            grad_transport: str = "dense",
                            backend: str = "xla", decomposed: bool = True,
                            interpret: bool | None = None,
                            compute_dtype: str | None = None,
                            scaler: DynamicLossScale | None = None,
                            lr: float = 1e-3, weight_decay: float = 1e-4):
    """Jitted multi-device ``step(state, chunks) -> (state', metrics)``.

    ``chunks`` comes from :func:`shard_batch` (leading virtual-shard axis
    sharded over the mesh's data axes); ``state`` from :func:`place_state`.
    The recipe contract is identical to :func:`make_train_step` — fp32
    masters, fp32 loss reduction, dynamic loss scaling, branchless
    skip-on-nonfinite — with the gradient reduction routed through
    :func:`repro.distributed.compression.mesh_allreduce`:

    * ``grad_transport="dense"`` — fp32 chunk stacks on the wire; the step is
      **bitwise identical** on every mesh size (the fixed-order sum is the
      only cross-chunk reduction).
    * ``grad_transport="bf16"`` — bf16 stacks on the wire (2x smaller
      collective in the compiled HLO); convergence-bounded, not bitwise.

    XLA backend only: per-chunk gradients vmap over the model forward, and
    the Pallas kernels' ``custom_vjp`` has no batching rule.
    """
    if backend != "xla":
        raise ValueError(
            f"sharded step requires backend='xla', got {backend!r}")
    scaler = scaler or DynamicLossScale()
    loss_fn = _loss_fn(model, backend=backend, decomposed=decomposed,
                       interpret=interpret, compute_dtype=compute_dtype)
    axes = _sharding.data_axes(mesh)
    axis = axes if len(axes) > 1 else axes[0]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(), P(axis)), out_specs=(P(), P()),
        check_rep=False)
    def chunk_grads(params, scale_state, chunks):
        # per-chunk scaled-loss gradients, SEQUENTIALLY per device: lax.map
        # compiles one per-chunk graph applied to every chunk, so the chunk
        # backward is identical on every mesh size (a vmap over the local
        # chunks fuses at the local width and breaks bitwise at ~1e-8).
        # Only the reduction order could then differ — mesh_allreduce pins it.
        def scaled_chunk_loss(p, chunk):
            loss = loss_fn(p, chunk)
            return scaler.scale(scale_state, loss), loss

        def one(chunk):
            (_, loss), g = jax.value_and_grad(
                scaled_chunk_loss, has_aux=True)(params, chunk)
            return g, loss

        grads, losses = jax.lax.map(one, chunks)
        grads = _compression.mesh_allreduce(grads, axis,
                                            transport=grad_transport)
        losses = jax.lax.all_gather(losses, axis, axis=0, tiled=True)
        return grads, losses

    @jax.jit
    def step(state: TrainState, chunks: dict):
        grad_sum, losses = chunk_grads(state.params, state.scale, chunks)
        # equal-size chunks: the batch mean is the mean of chunk means
        loss = jnp.sum(losses.astype(jnp.float32)) / virtual_shards
        grads = scaler.unscale(state.scale, grad_sum)
        grads = jax.tree_util.tree_map(
            lambda g: g / virtual_shards, grads)
        finite = scaler.all_finite(grads)
        zeros = jax.tree_util.tree_map(jnp.zeros_like, grads)
        safe = select_tree(finite, grads, zeros)
        new_params, new_opt, gnorm = adamw_update(
            safe, state.opt, state.params, lr=jnp.float32(lr),
            weight_decay=weight_decay)
        new_params = select_tree(finite, new_params, state.params)
        new_opt = select_tree(finite, new_opt, state.opt)
        scale_state = scaler.update(state.scale, finite)
        metrics = {"loss": loss,
                   "grad_norm": jnp.where(finite, gnorm, 0.0),
                   "scale": scale_state.scale,
                   "skipped": 1.0 - finite.astype(jnp.float32)}
        return TrainState(new_params, new_opt, scale_state), metrics

    return step


__all__ = ["RECIPES", "TrainState", "init_state", "make_train_step",
           "shard_batch", "place_state", "make_sharded_train_step"]
