"""Assigned input-shape cells and their abstract input specs.

Every (architecture x shape) pair is a *cell*; ``input_specs`` returns
weak-type-correct ShapeDtypeStructs (no allocation) for the step function the
cell lowers:

  * ``train_4k``    -> train_step   (tokens/labels/mask)
  * ``prefill_32k`` -> prefill_step (tokens -> logits + caches)
  * ``decode_32k``  -> serve_step   (1 new token, KV cache of seq_len)
  * ``long_500k``   -> serve_step   (sub-quadratic archs only)
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(supported, reason-if-not) per DESIGN.md §4."""
    cell = SHAPES[shape]
    if cell.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention config: a 500k dense KV per layer "
                       "has no published sparsity mechanism for this arch")
    if cell.kind == "decode" and not cfg.decode_supported:
        return False, "encoder-only architecture has no decode step"
    return True, ""


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """Abstract model inputs for the cell (ShapeDtypeStruct stand-ins)."""
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        specs = {
            "tokens": _i32((b, s)),
            "labels": _i32((b, s)),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }
        if cfg.encoder_layers:  # stub modality frontend: frame embeddings
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_ctx, cfg.d_model), jnp.float32)
        return specs
    if cell.kind == "prefill":
        specs = {"tokens": _i32((b, s))}
        if cfg.encoder_layers:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_ctx, cfg.d_model), jnp.float32)
        return specs
    # decode: one new token against a seq_len KV cache
    specs = {"token": _i32((b, 1)), "cache_pos": _i32(())}
    if cfg.encoder_layers:
        specs["enc_out"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_ctx, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs
