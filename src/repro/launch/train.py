"""Production train loop: pjit step, checkpoint/restart, failure recovery,
straggler watchdog, grad accumulation — runs the same code path from 1 CPU
device to the 512-chip mesh.

The step itself comes from :func:`repro.launch.steps.make_train_step`
(microbatched grad accumulation), shardings from
``repro.distributed.sharding``, and the loop adds the operational shell:
background checkpointing every ``--ckpt-every`` steps, automatic
restore-and-resume after a failure (``FailureInjector`` exercises that path
in tests), heartbeats, and a straggler watchdog.

Usage (CPU-scale; examples/train_enet.py covers the paper workload, and a
killed run restarted with the same ``--ckpt-dir`` resumes where it died):

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --reduced --steps 20 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config, get_reduced
from repro.data import LMDataPipeline
from repro.distributed import sharding as shd
from repro.distributed.fault_tolerance import (FailureInjector, Heartbeat,
                                               StragglerWatchdog)
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import make_train_step
from repro.models import encdec, transformer
from repro.optim import adamw_init


def train(cfg, *, steps: int, global_batch: int, seq_len: int,
          ckpt_dir: str | None = None, ckpt_every: int = 10,
          mesh=None, injector: FailureInjector | None = None,
          log_every: int = 1) -> dict:
    """Returns final metrics; restartable + failure-tolerant."""
    mesh = mesh or make_smoke_mesh()
    mod = encdec if cfg.encoder_layers else transformer

    with shd.use_mesh(mesh):
        params_a = mod.init_abstract(cfg)
        p_sh = shd.make_param_shardings(mesh, params_a)
        rep = NamedSharding(mesh, P())

        def init_all(key):
            params = mod.init_params(key, cfg)
            return params, adamw_init(params, memory_mode=cfg.opt_memory_mode)

        from repro.launch.steps import _opt_shardings
        opt_a = jax.eval_shape(lambda p: adamw_init(
                p, memory_mode=cfg.opt_memory_mode), params_a)
        o_sh = _opt_shardings(mesh, opt_a, p_sh)

        init_jit = jax.jit(init_all, out_shardings=(p_sh, o_sh))

        step_fn = make_train_step(cfg, warmup=max(2, steps // 10),
                                  total_steps=steps)
        batch_sh = {
            "tokens": shd.batch_sharding(mesh, 2),
            "labels": shd.batch_sharding(mesh, 2),
            "mask": shd.batch_sharding(mesh, 2),
        }
        if cfg.encoder_layers:
            batch_sh["frames"] = shd.batch_sharding(mesh, 3)
        train_jit = jax.jit(
            step_fn, in_shardings=(p_sh, o_sh, batch_sh),
            out_shardings=(p_sh, o_sh,
                           {"loss": rep, "grad_norm": rep, "lr": rep}),
            donate_argnums=(0, 1))

        pipe = LMDataPipeline(global_batch, seq_len, cfg.vocab)
        watchdog = StragglerWatchdog()
        heart = Heartbeat(ckpt_dir or "/tmp/repro_hb")

        start = 0
        if ckpt_dir and (s := latest_step(ckpt_dir)) is not None:
            params, opt_state = restore_checkpoint(
                ckpt_dir, s, (params_a, opt_a), (p_sh, o_sh))
            start = s
            pipe.seek(start)
            print(f"[train] restored checkpoint at step {s}", flush=True)
        else:
            params, opt_state = init_jit(jax.random.PRNGKey(0))

        ckpt_thread = None
        metrics = {}
        step = start
        recoveries = 0
        while step < steps:
            try:
                got_step, np_batch = next(pipe)
                if cfg.encoder_layers:
                    np_batch["frames"] = np.zeros(
                        (global_batch, cfg.encoder_ctx, cfg.d_model),
                        np.float32)
                batch = jax.device_put(np_batch, batch_sh)
                if injector is not None:
                    injector.maybe_fail(got_step)
                t0 = time.time()
                if injector is not None:
                    # slow faults stall inside the timed window, so the
                    # watchdog sees exactly the injected straggler
                    stall = injector.sleep_faults(got_step)
                    if stall > 0:
                        time.sleep(stall)
                params, opt_state, metrics = train_jit(params, opt_state,
                                                       batch)
                metrics = jax.device_get(metrics)
                dt = time.time() - t0
                slow = watchdog.observe(got_step, dt)
                heart.beat(got_step)
                step = got_step + 1
                if got_step % log_every == 0:
                    print(f"[train] step={got_step} loss={metrics['loss']:.4f}"
                          f" gnorm={metrics['grad_norm']:.3f} dt={dt*1e3:.0f}ms"
                          f"{' STRAGGLER' if slow else ''}", flush=True)
                if ckpt_dir and step % ckpt_every == 0:
                    if ckpt_thread is not None:
                        ckpt_thread.join()
                    ckpt_thread = save_checkpoint(
                        ckpt_dir, step, (params, opt_state), background=True)
            except RuntimeError as e:
                # node failure path: restore newest checkpoint and resume
                print(f"[train] FAILURE: {e}; recovering", flush=True)
                if not ckpt_dir:
                    raise
                recoveries += 1
                if ckpt_thread is not None:
                    # join() re-raises a failed background save — a recovery
                    # must not silently restore from a step that never landed
                    ckpt_thread.join()
                    ckpt_thread = None
                s = latest_step(ckpt_dir)
                if s is None:
                    params, opt_state = init_jit(jax.random.PRNGKey(0))
                    step = 0
                else:
                    params, opt_state = restore_checkpoint(
                        ckpt_dir, s, (params_a, opt_a), (p_sh, o_sh))
                    step = s
                pipe.seek(step)
        if ckpt_thread is not None:
            ckpt_thread.join()
        pipe.close()
        metrics["stragglers"] = len(watchdog.flagged)
        metrics["recoveries"] = recoveries
        metrics["final_step"] = step
        return metrics


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    out = train(cfg, steps=args.steps, global_batch=args.batch,
                seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every)
    print(f"[train] done: {out}")


if __name__ == "__main__":
    main()
