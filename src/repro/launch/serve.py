"""Batched LM serving loop: prefill + decode with KV caches and a simple
continuous-batching request queue.

``Server`` holds sharded params + caches and serves fixed-size decode
batches through one jitted :func:`repro.launch.steps.make_serve_step` with
the caches donated.  Prefill runs the whole prompt through that same step
in ONE call (the KV cache takes all ``S`` prompt entries at once and
attention masks causally within the chunk); ``slow_prefill`` /
``--slow-prefill`` keeps the token-by-token loop for configs the parallel
path cannot serve — recurrent-state mixers (mamba/xlstm) and sliding-window
layers update their caches one token at a time.

The generative sibling — continuous batching of iterative diffusion /
single-shot GAN sampling over the decomposition engine — lives in
:mod:`repro.launch.serve_gen` (DESIGN.md §9).

CPU-scale usage:
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --reduced \
      --batch 4 --prompt-len 16 --gen-len 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.distributed import sharding as shd
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import cache_shardings, make_serve_step
from repro.models import encdec, transformer


def parallel_prefill_ok(cfg) -> bool:
    """Whether one multi-token serve_step call can prefill ``cfg``.

    Attention KV caches take a whole prompt chunk in one write with a
    causal-within-chunk mask; recurrent-state mixers (mamba/xlstm) and
    sliding-window ring buffers update one token at a time, so those
    configs keep the sequential fallback.
    """
    return (not cfg.encoder_layers and cfg.window == 0
            and all(k == "attn" for k in cfg.block_pattern))


class Server:
    """Holds params + caches; serves fixed-size decode batches."""

    def __init__(self, cfg, mesh=None, max_len: int = 256, batch: int = 4,
                 slow_prefill: bool = False):
        self.cfg = cfg
        self.mesh = mesh or make_smoke_mesh()
        self.max_len = max_len
        self.batch = batch
        self.slow_prefill = slow_prefill
        self.mod = encdec if cfg.encoder_layers else transformer
        shd.install(self.mesh)
        with self.mesh:
            params_a = self.mod.init_abstract(cfg)
            self.p_sh = shd.make_param_shardings(self.mesh, params_a)
            self.params = jax.jit(
                lambda k: self.mod.init_params(k, cfg),
                out_shardings=self.p_sh)(jax.random.PRNGKey(0))
            self.serve_step = jax.jit(
                make_serve_step(cfg), donate_argnums=(1,))

    def parallel_prefill_ok(self) -> bool:
        """See the module-level :func:`parallel_prefill_ok`."""
        return parallel_prefill_ok(self.cfg)

    def prefill(self, tokens: np.ndarray, *, slow: bool | None = None):
        """Warm the cache with the prompt; returns (next_token, caches, pos).

        Default: ONE serve_step call over the whole (B, S) prompt — the
        parallel prefill forward.  ``slow=True`` (or ``slow_prefill`` /
        ``--slow-prefill``, or a config the parallel path cannot serve)
        runs the token-by-token decode loop instead; both paths produce the
        same caches and next token.
        """
        b, s = tokens.shape
        if slow is None:
            slow = self.slow_prefill or not self.parallel_prefill_ok()
        elif not slow and not self.parallel_prefill_ok():
            # recurrent-state / windowed caches update one token at a time;
            # forcing the parallel path would silently corrupt them
            raise ValueError(
                f"{self.cfg.name}: parallel prefill unsupported "
                "(recurrent mixers / sliding window); use slow=True")
        with self.mesh:
            caches = (transformer.init_caches(self.cfg, b, self.max_len)
                      if not self.cfg.encoder_layers else
                      encdec.init_caches(self.cfg, b, self.max_len))
            if not slow:
                batch = {"token": jnp.asarray(tokens, jnp.int32),
                         "cache_pos": jnp.int32(0)}
                tok, caches = self.serve_step(self.params, caches, batch)
                return tok, caches, s
            tok = None
            for t in range(s):
                batch = {"token": jnp.asarray(tokens[:, t:t + 1]),
                         "cache_pos": jnp.int32(t)}
                tok, caches = self.serve_step(self.params, caches, batch)
        return tok, caches, s

    def generate(self, tokens: np.ndarray, gen_len: int):
        tok, caches, pos = self.prefill(tokens)
        out = [np.asarray(tok)]
        with self.mesh:
            for t in range(pos, pos + gen_len - 1):
                batch = {"token": tok, "cache_pos": jnp.int32(t)}
                tok, caches = self.serve_step(self.params, caches, batch)
                out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--slow-prefill", action="store_true",
                    help="prefill token-by-token through the decode step "
                         "instead of one parallel forward")
    args = ap.parse_args()
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    server = Server(cfg, batch=args.batch,
                    max_len=args.prompt_len + args.gen_len + 1,
                    slow_prefill=args.slow_prefill)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    t0 = time.time()
    out = server.generate(prompts, args.gen_len)
    dt = time.time() - t0
    toks = out.size
    print(f"[serve] generated {out.shape} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. prefill)")
    print(out[:, :8])


if __name__ == "__main__":
    main()
