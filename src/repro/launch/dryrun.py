import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh).

NOTE: the two ``os.environ`` lines above MUST stay the first statements —
jax locks the device count at first init.

For each cell, on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh:
  * jit(step).lower(**abstract inputs) -> .compile()  (sharding must be
    coherent; failures here are bugs),
  * print compiled.memory_analysis()  (per-chip HBM proof),
  * print compiled.cost_analysis() flops (XLA's, loop-UNAWARE — recorded for
    reference) and the loop-aware HLO analysis (FLOPs / HBM bytes /
    collective bytes) that feeds EXPERIMENTS.md §Roofline,
  * dump a JSON record per cell under results/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""

import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = "results/dryrun") -> dict:
    import jax

    from repro.configs import get_config
    from repro.distributed import hlo_analysis as ha
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES, cell_supported
    from repro.launch.steps import lower_cell

    cfg = get_config(arch)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok"}
    ok, reason = cell_supported(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    try:
        # explicit interval timestamps: t_lower must not fold mesh
        # construction in, and t_compile must not fold t_lower in — the
        # old running-subtraction form made both easy to get wrong
        t0 = time.time()
        lowered, _ = lower_cell(cfg, shape_name, mesh)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        t_lower, t_compile = t1 - t0, t2 - t1

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        txt = compiled.as_text()
        analysis = ha.analyze(txt)
        terms = ha.roofline_terms(analysis)

        counts = cfg.param_counts()
        cell = SHAPES[shape_name]
        rec.update({
            "chips": n_chips,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_chip_total_gb": round(
                    (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
                    / 2**30, 3),
            },
            "xla_cost_flops_loop_unaware": cost.get("flops", -1.0),
            "hlo": {
                "flops_per_chip": analysis.flops,
                "hbm_bytes_per_chip": analysis.hbm_bytes,
                "collective_operand_bytes": analysis.collective_operand_bytes,
                "collective_wire_bytes": analysis.collective_wire_bytes,
                "collectives": {
                    k: {"count": v.count, "operand_bytes": v.operand_bytes,
                        "wire_bytes": v.wire_bytes}
                    for k, v in analysis.collectives.items()},
            },
            "roofline": terms,
            "params_total": counts["total"],
            "params_active": counts["active"],
            "tokens_per_step": cell.global_batch * (
                cell.seq_len if cell.kind == "train" else 1),
        })
    except Exception as e:  # a failure here is a sharding bug — surface it
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    import os as _os
    _os.makedirs(out_dir, exist_ok=True)
    path = f"{out_dir}/{arch}__{shape_name}__{mesh_name}.json"
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out-dir", default="results/dryrun")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS
    from repro.launch.shapes import SHAPES

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    meshes = [False] if args.single_pod_only else (
        [True] if args.multi_pod else [False, True])

    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out_dir)
            status = rec["status"]
            extra = ""
            if status == "ok":
                extra = (f" mem/chip={rec['memory']['per_chip_total_gb']}GB"
                         f" flops/chip={rec['hlo']['flops_per_chip']:.3g}"
                         f" coll_wire={rec['hlo']['collective_wire_bytes']:.3g}B"
                         f" compile={rec['compile_s']}s")
            elif status == "failed":
                extra = " " + rec["error"][:160]
            elif status == "skipped":
                extra = " " + rec["reason"][:80]
            print(f"[{rec['mesh']}] {arch} x {shape}: {status}{extra}",
                  flush=True)


if __name__ == "__main__":
    main()
