"""Step-function builders: one jitted step per execution context.

Every loop in ``repro.launch`` is "build a pure step function, jit it once,
drive it from a host-side scheduler" — this module holds the builders:

* :func:`make_train_step` — microbatched (grad-accumulation) LM train step;
  driven by ``repro.launch.train`` and the dry-run.
* :func:`make_prefill_step` / :func:`make_serve_step` — LM prefill and
  KV-cached decode; driven by ``repro.launch.serve``.
* :func:`make_gen_step` — one DDIM denoising step over the diffusion U-Net
  decoder denoiser (timestep embedding + decoder forward + DDIM update);
  driven by ``repro.launch.serve_gen``.  Timesteps/activity are *data*, so
  a whole mixed-timestep request batch shares one compiled step.
* :func:`make_gen_scan_step` — ``K`` fused DDIM steps per dispatch via
  ``lax.scan`` over the same body; per-slot trajectories arrive as padded
  ``(B, K)`` timestep matrices, so mixed-step request sets still share one
  compiled step while host dispatch overhead is paid once per ``K`` steps.

The LM builders are shape-polymorphic enough to be used identically by the
dry-run (``jax.jit(fn, ...).lower(*abstract_specs)`` — no allocation) and
the real loops (concrete arrays); see :func:`lower_cell`.

CPU-scale smoke (the loops document their own CLIs):

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --reduced --steps 3
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --reduced
  PYTHONPATH=src python -m repro.launch.serve_gen --smoke
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch.shapes import SHAPES, input_specs
from repro.models import encdec, transformer
from repro.models.config import ModelConfig
from repro.models.layers import chunked_softmax_ce, softmax_cross_entropy
from repro.optim import adamw_init, adamw_update, cosine_schedule

# KV/state-cache sharding rules by leaf name (trailing dims after the stacked
# (repeat,) axis).  Resolution applies divisibility + axis-reuse guards.
_CACHE_RULES = {
    "k": (None, "data_kvseq", "kvseq", "model_kv", None),
    "v": (None, "data_kvseq", "kvseq", "model_kv", None),
    "conv": (None, "data", None, "model"),
    "ssm": (None, "data", "model", None),
    "C": (None, "data", "model", None, None),
    "n": (None, "data", "model", None),
    "m": (None, "data", "model"),
    "c": (None, "data", "model"),
    "h": (None, "data", "model"),
}


def cache_shardings(mesh, caches_abstract):
    def leaf(path, x):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        logical = _CACHE_RULES.get(name, (None,) * x.ndim)
        logical = logical[:x.ndim]
        logical = (None,) * (x.ndim - len(logical)) + tuple(logical)
        return NamedSharding(mesh, shd.resolve_spec(mesh, logical, x.shape))

    return jax.tree_util.tree_map_with_path(leaf, caches_abstract)


def _model_fns(cfg: ModelConfig):
    if cfg.encoder_layers:
        return encdec
    return transformer


def abstract_state(cfg: ModelConfig):
    """(abstract params, abstract optimizer state) — no allocation."""
    mod = _model_fns(cfg)
    params = mod.init_abstract(cfg)
    opt = jax.eval_shape(lambda p: adamw_init(
        p, memory_mode=cfg.opt_memory_mode), params)
    return params, opt


def make_train_step(cfg: ModelConfig, *, lr_peak: float = 3e-4,
                    warmup: int = 2000, total_steps: int = 100_000,
                    microbatches: int = 1):
    """Microbatched (grad-accumulation) train step.

    ``microbatches > 1`` scans the global batch in slices, accumulating f32
    gradients sharded like the parameters — activation memory scales 1/M and
    the gradient all-reduce still happens once per step.
    """
    mod = _model_fns(cfg)

    def loss_fn(p, mb):
        if cfg.encoder_layers:
            logits = mod.forward(p, mb["tokens"], mb["frames"], cfg)
            return softmax_cross_entropy(logits, mb["labels"], mb["mask"])
        hidden = mod.forward(p, mb["tokens"], cfg, return_hidden=True)
        return chunked_softmax_ce(hidden, mod.lm_head(p, cfg),
                                  mb["labels"], mb["mask"])

    # grad-accumulation dtype follows the optimizer memory mode: bf16-state
    # models (398B Jamba) also accumulate in bf16 — halves the accumulator
    # footprint and the cross-pod gradient all-reduce wire.
    acc_dtype = jnp.bfloat16 if cfg.opt_memory_mode == "bf16" else jnp.float32

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                acc_g, acc_l = acc
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dtype), acc_g, g)
                return (acc_g, acc_l + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.float32(0)),
                                           micro)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        lr = cosine_schedule(opt_state.step, warmup, total_steps, lr_peak)
        new_params, new_opt, gnorm = adamw_update(grads, opt_state, params,
                                                  lr=lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    mod = _model_fns(cfg)

    def prefill_step(params, batch):
        if cfg.encoder_layers:
            return mod.forward(params, batch["tokens"], batch["frames"], cfg)
        return mod.forward(params, batch["tokens"], cfg)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, caches, token, pos) -> (token', caches')."""

    def serve_step(params, caches, batch):
        token, pos = batch["token"], batch["cache_pos"]
        if cfg.encoder_layers:
            logits, new_caches = encdec.decode_step(
                params, token, batch["enc_out"], caches, pos, cfg)
        else:
            logits, new_caches = transformer.decode_step(
                params, token, caches, pos, cfg)
        next_token = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return next_token.astype(jnp.int32), new_caches

    return serve_step


# ---------------------------------------------------------------------------
# Generative sampling step (diffusion serving path, DESIGN.md §9)
# ---------------------------------------------------------------------------

#: training-noise schedule length the DDIM trajectories subsample.
DDIM_T_MAX = 1000


def ddim_alpha_bar(t_max: int = DDIM_T_MAX) -> jax.Array:
    """Cumulative signal level ``alpha_bar[t]`` of a linear beta schedule."""
    betas = jnp.linspace(1e-4, 2e-2, t_max, dtype=jnp.float32)
    return jnp.cumprod(1.0 - betas)


def ddim_timesteps(steps: int, t_max: int = DDIM_T_MAX) -> np.ndarray:
    """Host-side decreasing timestep trajectory for a ``steps``-step sample.

    Evenly spaced over ``[t_max - 1, 0]`` — each request carries its own
    trajectory, so requests with different step budgets coexist in one
    device batch (the per-step geometry is identical; only these values
    differ).
    """
    if not 1 <= steps <= t_max:
        raise ValueError(f"steps must be in [1, {t_max}], got {steps}")
    return np.linspace(t_max - 1, 0, steps).round().astype(np.int32)


def make_gen_step(*, t_max: int = DDIM_T_MAX, decomposed: bool = True,
                  backend: str = "xla", interpret: bool | None = None,
                  compute_dtype: str | None = None):
    """One deterministic (eta=0) DDIM step over the U-Net denoiser.

    Returns ``gen_step(params, x, batch) -> x'`` where ``x`` is the noisy
    image batch (B, S, S, C) and ``batch`` carries per-request vectors:

    * ``t``      (B,) int32 — current timestep of each slot;
    * ``t_next`` (B,) int32 — next timestep, ``-1`` meaning "this is the
      final step: land on x0";
    * ``active`` (B,) bool — padding/idle slots pass through unchanged.

    The step embeds ``t`` (:func:`repro.models.common.timestep_embedding`),
    runs the denoiser forward — the transposed-conv decoder on the
    decomposition engine — and applies the DDIM update
    ``x' = sqrt(ab') * x0_pred + sqrt(1 - ab') * eps``.  All timestep
    dependence is data, so one jitted instance serves every request mix;
    the caller donates ``x`` (``jax.jit(..., donate_argnums=(1,))``).

    ``compute_dtype`` (e.g. ``"bf16"``) runs the denoiser forward in the
    compute dtype; the DDIM update itself is evaluated in fp32 (the
    schedule coefficients span ~1e-4 .. 1) and the result cast back to
    ``x.dtype`` — without the cast the fp32 ``alpha_bar`` gather would
    silently promote a bf16 lane back to fp32 on the first step.
    """
    from repro.models import unet_decoder

    alpha_bar = ddim_alpha_bar(t_max)

    def gen_step(params, x, batch):
        t, t_next, active = batch["t"], batch["t_next"], batch["active"]
        eps = unet_decoder.denoise(params, x, t, decomposed=decomposed,
                                   backend=backend, interpret=interpret,
                                   compute_dtype=compute_dtype)
        ab_t = alpha_bar[t][:, None, None, None]
        ab_n = jnp.where(t_next >= 0, alpha_bar[jnp.maximum(t_next, 0)],
                         1.0)[:, None, None, None]
        xf = x.astype(jnp.float32)
        ef = eps.astype(jnp.float32)
        x0 = (xf - jnp.sqrt(1.0 - ab_t) * ef) * jax.lax.rsqrt(ab_t)
        x_new = (jnp.sqrt(ab_n) * x0
                 + jnp.sqrt(1.0 - ab_n) * ef).astype(x.dtype)
        return jnp.where(active[:, None, None, None], x_new, x)

    return gen_step


def make_gen_scan_step(scan_steps: int, *, t_max: int = DDIM_T_MAX,
                       decomposed: bool = True, backend: str = "xla",
                       interpret: bool | None = None,
                       compute_dtype: str | None = None):
    """``scan_steps`` fused DDIM steps per dispatch (``lax.scan``).

    Returns ``gen_scan_step(params, x, batch) -> x'`` where ``batch`` carries
    padded per-slot trajectory *matrices* instead of vectors:

    * ``t``      (B, K) int32 — timestep of slot ``b`` at substep ``j``;
    * ``t_next`` (B, K) int32 — next timestep (``-1`` = land on x0);
    * ``active`` (B, K) bool  — padding columns (a slot with fewer than ``K``
      remaining steps, or an empty slot) pass through bit-exactly.

    The scan body is exactly the single-step :func:`make_gen_step` closure,
    so a ``K``-fused dispatch is bitwise-equal on xla to ``K`` separate
    dispatches of the same trajectory — mixed-step request sets share one
    compiled step, and the host pays one dispatch per ``K`` denoising steps
    (the amortisation ``cycle_model.serve_report(scan_steps=...)`` models).
    ``scan_steps=1`` degenerates to the single-step form (still scanned, so
    the compiled artifact is shape-stable in ``K``).
    """
    if scan_steps < 1:
        raise ValueError(f"scan_steps must be >= 1, got {scan_steps}")
    step = make_gen_step(t_max=t_max, decomposed=decomposed, backend=backend,
                         interpret=interpret, compute_dtype=compute_dtype)

    def gen_scan_step(params, x, batch):
        # (B, K) -> (K, B): scan iterates substeps, each seeing one column
        subs = {k: jnp.moveaxis(v, 0, 1) for k, v in batch.items()}

        def body(carry, sub):
            return step(params, carry, sub), None

        x, _ = jax.lax.scan(body, x, subs)
        return x

    return gen_scan_step


def default_microbatches(cfg: ModelConfig) -> int:
    """Grad-accumulation depth scaled to model size (activation pressure)."""
    total = cfg.param_counts()["total"]
    if total > 100e9:
        return 8
    if total > 20e9:
        return 4
    return 2


def lower_cell(cfg: ModelConfig, shape_name: str, mesh, *,
               include_opt: bool = True, microbatches: int | None = None):
    """Lower the cell's step on ``mesh``; returns (lowered, aux dict)."""
    if microbatches is None:
        microbatches = default_microbatches(cfg)
    cell = SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)
    params_a = _model_fns(cfg).init_abstract(cfg)
    p_sh = shd.make_param_shardings(mesh, params_a)
    batch_leaf_sh = {
        k: NamedSharding(mesh, shd.resolve_spec(
            mesh, ("data",) + (None,) * (v.ndim - 1), v.shape))
        for k, v in specs.items()
    }
    rep = NamedSharding(mesh, P())

    with shd.use_mesh(mesh):
        if cell.kind == "train":
            opt_a = jax.eval_shape(lambda p: adamw_init(
                p, memory_mode=cfg.opt_memory_mode), params_a)
            o_sh = _opt_shardings(mesh, opt_a, p_sh)
            fn = make_train_step(cfg, microbatches=microbatches)
            jitted = jax.jit(fn, in_shardings=(p_sh, o_sh, batch_leaf_sh),
                             out_shardings=(p_sh, o_sh,
                                            {"loss": rep, "grad_norm": rep,
                                             "lr": rep}),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_a, opt_a, specs)
            return lowered, {"params": params_a, "opt": opt_a}
        if cell.kind == "prefill":
            fn = make_prefill_step(cfg)
            jitted = jax.jit(fn, in_shardings=(p_sh, batch_leaf_sh))
            lowered = jitted.lower(params_a, specs)
            return lowered, {"params": params_a}
        # decode
        if cfg.encoder_layers:
            caches_a = jax.eval_shape(
                lambda: encdec.init_caches(cfg, cell.global_batch,
                                           cell.seq_len))
        else:
            caches_a = jax.eval_shape(
                lambda: transformer.init_caches(cfg, cell.global_batch,
                                                cell.seq_len))
        c_sh = cache_shardings(mesh, caches_a)
        fn = make_serve_step(cfg)
        jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, batch_leaf_sh),
                         out_shardings=(batch_leaf_sh["token"], c_sh),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_a, caches_a, specs)
        return lowered, {"params": params_a, "caches": caches_a}


def _opt_shardings(mesh, opt_abstract, param_shardings):
    """Optimizer state shardings: master/moments mirror the params."""
    rep = NamedSharding(mesh, P())
    from repro.optim.adamw import AdamWState

    return AdamWState(
        step=rep,
        master=None if opt_abstract.master is None else param_shardings,
        mu=param_shardings,
        nu=param_shardings,
    )
