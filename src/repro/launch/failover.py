"""Multi-host serving pool with heartbeat-driven lane failover (DESIGN.md §13).

PR 8 made ONE server survive its own faults (retry/degrade ladder, snapshot
restore, corruption re-runs).  This module is the next rung up: several
*hosts*, each running a :class:`repro.launch.serve_gen.GenServer` (its lanes
can span a device mesh), watched by the crash-safe
:class:`repro.distributed.fault_tolerance.Heartbeat` monitor.  When a host
stops proving liveness — its heartbeat goes stale, truncated, or vanishes —
the pool reassigns every request the dead host had not finished to a
surviving host and the drain completes.

Correctness leans on the same property every fault path in this repo leans
on: a request's sample is a pure function of ``(workload, steps, seed)`` and
the xla drain is deterministic, so a request re-run on a different host (or
a different mesh) produces the bit-identical image.  The chaos drill in
``tests/test_chaos.py`` pins a killed-host drain against the no-fault run
bitwise.

On a real fleet the heartbeat directory is a distributed KV prefix and the
reassignment is done by the job controller; the *logic* — beat, detect
stale, requeue the dead host's inventory, keep draining — is exactly what
runs here.
"""

from __future__ import annotations

import time

import numpy as np

from repro.distributed.fault_tolerance import Heartbeat
from repro.launch.serve_gen import GenServer


class _Host:
    """One pool member: a server plus its liveness marker."""

    def __init__(self, host_id: int, heartbeat_dir: str, server: GenServer):
        self.host_id = host_id
        self.server = server
        self.heart = Heartbeat(heartbeat_dir, host_id)
        self.alive = True           # in-process stand-in for "process exists"


class FailoverPool:
    """Round-robin request pool over N heartbeat-monitored serving hosts.

    ``server_factory(host_id) -> GenServer`` builds each member (tests pass
    tiny-width servers; every host must be built identically for bitwise
    reassignment).  ``timeout_s`` is the staleness bound handed to
    :meth:`Heartbeat.dead_hosts` — hosts whose last beat is older are
    declared dead on the next :meth:`step` and their unfinished requests
    requeue onto survivors.

    :meth:`kill_host` is the chaos hook: it stops the host's stepping *and*
    beating, exactly what a died process looks like from the monitor's side
    — reassignment is triggered by the stale heartbeat, never by the kill
    call itself.
    """

    def __init__(self, heartbeat_dir: str, *, hosts: int = 2,
                 timeout_s: float = 0.25, server_factory=None,
                 server_kw: dict | None = None):
        if hosts < 1:
            raise ValueError(f"hosts must be >= 1, got {hosts}")
        if server_factory is None:
            kw = dict(server_kw or {})
            server_factory = lambda host_id: GenServer(**kw)  # noqa: E731
        self.heartbeat_dir = heartbeat_dir
        self.timeout_s = timeout_s
        self.hosts = [
            _Host(i, heartbeat_dir, server_factory(i)) for i in range(hosts)
        ]
        self._tick = 0
        self._next_token = 0
        self._rr = 0                                # round-robin cursor
        # token -> (workload, steps, seed, submit kwargs) — enough to re-run
        # the request bit-identically anywhere
        self._spec: dict[int, tuple] = {}
        self._where: dict[int, tuple[int, int]] = {}    # token -> (host, rid)
        self._results: dict[int, np.ndarray] = {}
        self._dead: set[int] = set()
        #: (token, from_host, to_host) reassignments, in detection order
        self.failovers: list[tuple[int, int, int]] = []
        for h in self.hosts:
            h.heart.beat(0)         # a fresh pool is all-alive by definition

    # ------------------------------------------------------------- submit --
    def _alive_hosts(self) -> list[_Host]:
        return [h for h in self.hosts if h.alive and h.host_id not in
                self._dead]

    def _place(self, token: int, exclude: int | None = None) -> None:
        candidates = [h for h in self._alive_hosts() if h.host_id != exclude]
        if not candidates:
            candidates = self._alive_hosts()
        if not candidates:
            raise RuntimeError("no live hosts left in the pool")
        host = candidates[self._rr % len(candidates)]
        self._rr += 1
        workload, steps, seed, kw = self._spec[token]
        rid = host.server.submit(workload, steps=steps, seed=seed, **kw)
        self._where[token] = (host.host_id, rid)

    def submit(self, workload: str, *, steps: int = 1, seed: int = 0,
               **kw) -> int:
        """Enqueue on the next live host round-robin; returns a pool token
        (stable across failovers, unlike the per-server rid)."""
        token = self._next_token
        self._next_token += 1
        self._spec[token] = (workload, steps, seed, dict(kw))
        self._place(token)
        return token

    # -------------------------------------------------------------- chaos --
    def kill_host(self, host_id: int) -> None:
        """Simulate host death: no more beats, no more ticks.  The monitor
        notices once the last beat goes stale; nothing is reassigned here."""
        self.hosts[host_id].alive = False

    # -------------------------------------------------------------- drain --
    def _collect(self, host: _Host, done) -> None:
        by_rid = {rid: t for t, (hid, rid) in self._where.items()
                  if hid == host.host_id}
        for req in done:
            token = by_rid.get(req.rid)
            if token is not None and token not in self._results:
                self._results[token] = req.result

    def _check_failover(self) -> None:
        for host_id in Heartbeat.dead_hosts(self.heartbeat_dir,
                                            self.timeout_s):
            if host_id in self._dead or host_id >= len(self.hosts):
                continue
            self._dead.add(host_id)
            # requeue everything the dead host had not delivered
            for token, (hid, _) in sorted(self._where.items()):
                if hid != host_id or token in self._results:
                    continue
                self._place(token, exclude=host_id)
                self.failovers.append(
                    (token, host_id, self._where[token][0]))

    def step(self) -> int:
        """One pool tick: step every live host, collect completions, then
        beat and run the heartbeat monitor (detect dead hosts, reassign
        their inventory).  Beats land AFTER the serving work — a tick can
        take seconds under first-touch compilation, so beating first would
        let a slow sibling age every other host's beat past ``timeout_s``
        and false-positive the whole pool.  Returns the number of newly
        collected results."""
        before = len(self._results)
        self._tick += 1
        for host in self._alive_hosts():
            srv = host.server
            if srv._pending or any(l.busy for l in srv._lanes.values()):
                self._collect(host, srv.step())
        for host in self._alive_hosts():
            host.heart.beat(self._tick)
        self._check_failover()
        return len(self._results) - before

    def drain(self, *, max_idle_s: float = 30.0) -> dict[int, np.ndarray]:
        """Step until every token has a result.  ``max_idle_s`` bounds the
        wait for a failover detection (stale heartbeats only age with wall
        time); exceeding it raises rather than spinning forever."""
        last_progress = time.perf_counter()
        while len(self._results) < len(self._spec):
            if self.step() > 0:
                last_progress = time.perf_counter()
            elif time.perf_counter() - last_progress > max_idle_s:
                missing = sorted(set(self._spec) - set(self._results))
                raise RuntimeError(
                    f"pool drain stalled: {len(missing)} request(s) "
                    f"unfinished ({missing[:8]}...) with no progress for "
                    f"{max_idle_s}s")
        return dict(sorted(self._results.items()))

    # -------------------------------------------------------------- stats --
    def stats(self) -> dict[str, float]:
        return {
            "hosts": float(len(self.hosts)),
            "dead_hosts": float(len(self._dead)),
            "failovers": float(len(self.failovers)),
            "requests": float(len(self._spec)),
            "completed": float(len(self._results)),
            "ticks": float(self._tick),
        }


__all__ = ["FailoverPool"]
