"""Generative serving loop: continuous-batching iterative decoder sampling
on the decomposition engine (DESIGN.md §9).

Requests arrive as ``(workload, steps, seed)`` and are packed into
fixed-size device batches.  Diffusion requests iterate the DDIM step built
by :func:`repro.launch.steps.make_gen_step` — timestep embedding + U-Net
decoder forward through the fused transposed-conv kernels + DDIM update —
one jitted call per scheduler tick with the image state donated.  Because
the transposed-conv geometry is timestep-*invariant* (the timestep enters
only as an embedded value), in-flight requests sitting at different
denoising timesteps share a batch and one compiled step serves the whole
queue; a slot that finishes is refilled from the queue on the next tick
while its neighbours keep denoising.  DCGAN requests are single-shot: one
tick through the k=4/s=2 generator completes every active slot.

This mirrors the LM path (``repro.launch.serve``): the scheduler is
host-side and dumb, the device step is pure and compiled once.  The image
state takes its sharding from :func:`repro.distributed.sharding.image_sharding`
(batch over the data axes, optionally spatial rows over the model axis).

CPU-scale usage:

  PYTHONPATH=src python -m repro.launch.serve_gen --smoke
  PYTHONPATH=src python -m repro.launch.serve_gen --requests 6 \
      --steps 8,5,3 --batch 4 --backend xla
"""

from __future__ import annotations

import argparse
import time
from collections import deque
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cycle_model as cm
from repro.core.gen_spec import GEN_WORKLOADS, UNET_WIDTHS
from repro.distributed import sharding as shd
from repro.launch.steps import DDIM_T_MAX, ddim_timesteps, make_gen_step
from repro.models import dcgan, unet_decoder


def init_noise(seed: int, shape: tuple[int, ...]) -> jax.Array:
    """Seeded x_T (or latent) — shared by the server and the reference loop
    so a served request is bit-for-bit reproducible from its seed."""
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@dataclass
class GenRequest:
    """One sampling request; ticks are scheduler steps, not wall time."""
    rid: int
    workload: str
    steps: int
    seed: int
    submit_tick: int
    admit_tick: int = -1
    done_tick: int = -1
    result: np.ndarray | None = None
    # calibrated host-time admission estimate (us) for the whole request, or
    # None when the server has no calibration fitted for this layer mix
    est_us: float | None = None

    @property
    def wait_ticks(self) -> int:
        return self.admit_tick - self.submit_tick


class _DiffusionLane:
    """Fixed-size batch of diffusion slots over one compiled DDIM step."""

    def __init__(self, params: dict, *, batch: int, widths: tuple[int, ...],
                 hw: int, out_ch: int, backend: str,
                 interpret: bool | None, decomposed: bool, mesh=None,
                 spatial: bool = False):
        size = hw * 2 ** len(widths)
        self.image_shape = (size, size, out_ch)
        self.params = params
        step = make_gen_step(decomposed=decomposed, backend=backend,
                             interpret=interpret)
        x = jnp.zeros((batch,) + self.image_shape, jnp.float32)
        if mesh is not None:
            sh = shd.image_sharding(mesh, x.shape, spatial=spatial)
            self.params = jax.device_put(params, shd.replicated(mesh))
            x = jax.device_put(x, sh)
            self._step = jax.jit(step, donate_argnums=(1,), out_shardings=sh)
        else:
            self._step = jax.jit(step, donate_argnums=(1,))
        self.x = x
        self.slots: list[GenRequest | None] = [None] * batch
        self._traj: list[np.ndarray | None] = [None] * batch
        self._pos = [0] * batch
        self.t = np.zeros(batch, np.int32)
        self.t_next = np.full(batch, -1, np.int32)
        self.active = np.zeros(batch, bool)
        self.device_steps = 0

    @property
    def busy(self) -> bool:
        return self.active.any()

    def free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def admit(self, req: GenRequest, slot: int) -> None:
        traj = ddim_timesteps(req.steps)
        self.slots[slot] = req
        self._traj[slot] = traj
        self._pos[slot] = 0
        self.t[slot] = traj[0]
        self.t_next[slot] = traj[1] if req.steps > 1 else -1
        self.active[slot] = True
        self.x = self.x.at[slot].set(init_noise(req.seed, self.image_shape))

    def tick(self) -> list[GenRequest]:
        batch = {"t": jnp.asarray(self.t), "t_next": jnp.asarray(self.t_next),
                 "active": jnp.asarray(self.active)}
        self.x = self._step(self.params, self.x, batch)
        self.device_steps += 1
        done = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self._pos[i] += 1
            traj = self._traj[i]
            if self._pos[i] == len(traj):          # landed on x0
                req.result = np.asarray(self.x[i])
                done.append(req)
                self.slots[i] = self._traj[i] = None
                self.active[i] = False
            else:
                self.t[i] = traj[self._pos[i]]
                self.t_next[i] = (traj[self._pos[i] + 1]
                                  if self._pos[i] + 1 < len(traj) else -1)
        return done


class _DCGANLane:
    """Single-shot generation: one tick drains every active latent slot."""

    def __init__(self, params: dict, *, batch: int, nz: int, backend: str,
                 interpret: bool | None, decomposed: bool):
        self.params = params
        self.nz = nz
        self._fwd_kw = dict(decomposed=decomposed, backend=backend,
                            interpret=interpret)
        self.z = jnp.zeros((batch, nz), jnp.float32)
        self.slots: list[GenRequest | None] = [None] * batch
        self.active = np.zeros(batch, bool)
        self.device_steps = 0

    @property
    def busy(self) -> bool:
        return self.active.any()

    def free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def admit(self, req: GenRequest, slot: int) -> None:
        self.slots[slot] = req
        self.active[slot] = True
        self.z = self.z.at[slot].set(init_noise(req.seed, (self.nz,)))

    def tick(self) -> list[GenRequest]:
        imgs = np.asarray(dcgan.forward(self.params, self.z, **self._fwd_kw))
        self.device_steps += 1
        done = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.result = imgs[i]
            done.append(req)
            self.slots[i] = None
            self.active[i] = False
        return done


class GenServer:
    """Continuous-batching generative server over the decomposition engine.

    One lane (fixed-size device batch + compiled step) per workload, built
    lazily on the first request for it.  ``submit`` enqueues, ``step`` runs
    one scheduler tick (admit into free slots, then one device step per busy
    lane), ``run`` drains the queue and returns ``rid -> image``.

    Admission is FIFO per workload — a request never overtakes an earlier
    request for the same lane, and a full lane never blocks another lane —
    so no request starves (pinned in ``tests/test_serve_gen.py``).

    ``params`` overrides model parameters per workload name (tests and the
    smoke paths pass tiny-width denoisers); otherwise lanes initialise
    canonical-width parameters from ``param_seed``.
    """

    def __init__(self, *, batch: int = 4, backend: str = "xla",
                 interpret: bool | None = None, decomposed: bool = True,
                 mesh=None, spatial: bool = False,
                 unet_widths: tuple[int, ...] = UNET_WIDTHS, unet_hw: int = 8,
                 out_ch: int = 3, dcgan_nz: int = 100, dcgan_ngf: int = 64,
                 params: dict | None = None, param_seed: int = 0,
                 calibration=None):
        self.batch = batch
        self.backend = backend
        self.interpret = interpret
        self.decomposed = decomposed
        self.mesh = mesh
        self.spatial = spatial
        self.unet_widths, self.unet_hw, self.out_ch = unet_widths, unet_hw, out_ch
        self.dcgan_nz, self.dcgan_ngf = dcgan_nz, dcgan_ngf
        self._params = dict(params or {})
        self._param_seed = param_seed
        self.calibration = calibration
        self._lanes: dict[str, _DiffusionLane | _DCGANLane] = {}
        self._pending: deque[GenRequest] = deque()
        self._done: dict[int, GenRequest] = {}
        self._tick = 0
        self._next_rid = 0
        self._t0: float | None = None

    # -------------------------------------------------------------- lanes --
    def _lane(self, workload: str):
        lane = self._lanes.get(workload)
        if lane is not None:
            return lane
        kw = dict(backend=self.backend, interpret=self.interpret,
                  decomposed=self.decomposed)
        if workload == "unet_dec":
            p = self._params.get(workload) or unet_decoder.init_denoiser_params(
                jax.random.PRNGKey(self._param_seed), widths=self.unet_widths,
                out_ch=self.out_ch)
            lane = _DiffusionLane(p, batch=self.batch, widths=self.unet_widths,
                                  hw=self.unet_hw, out_ch=self.out_ch,
                                  mesh=self.mesh, spatial=self.spatial, **kw)
        elif workload in ("dcgan64", "dcgan128"):
            size = int(workload[5:])
            p = self._params.get(workload) or dcgan.init_params(
                jax.random.PRNGKey(self._param_seed), size=size,
                nz=self.dcgan_nz, ngf=self.dcgan_ngf, out_ch=self.out_ch)
            lane = _DCGANLane(p, batch=self.batch, nz=self.dcgan_nz, **kw)
        else:
            raise ValueError(f"unknown workload {workload!r}; "
                             f"known: {sorted(GEN_WORKLOADS)}")
        self._lanes[workload] = lane
        return lane

    # ---------------------------------------------------------- scheduling --
    def admission_estimate(self, workload: str, steps: int = 1) -> float | None:
        """Calibrated host-time estimate (us) for one request: the fitted
        per-kind cycles->us mapping applied to the workload's canonical layer
        table x DDIM ``steps``.  None without a calibration, or when the
        calibration lacks a fitted key for one of the workload's layer kinds
        on this server's backend — callers must treat that as "no estimate",
        not zero cost."""
        if self.calibration is None:
            return None
        us = self.calibration.predict_layers(GEN_WORKLOADS[workload](),
                                             backend=self.backend)
        return None if us is None else us * max(steps, 1)

    def submit(self, workload: str, *, steps: int = 1, seed: int = 0) -> int:
        """Enqueue a request; returns its id.  DCGAN is single-shot
        (``steps`` is forced to 1); diffusion runs a ``steps``-step DDIM
        trajectory."""
        self._lane(workload)        # fail fast on unknown workloads
        if workload != "unet_dec":
            steps = 1
        req = GenRequest(self._next_rid, workload, steps, seed, self._tick)
        req.est_us = self.admission_estimate(workload, steps)
        self._next_rid += 1
        self._pending.append(req)
        return req.rid

    def _admit(self) -> None:
        kept: deque[GenRequest] = deque()
        while self._pending:
            req = self._pending.popleft()
            lane = self._lane(req.workload)
            # same-lane FIFO: once one request for a lane waits, later
            # requests for that lane wait behind it
            slot = None if any(k.workload == req.workload for k in kept) \
                else lane.free_slot()
            if slot is None:
                kept.append(req)
            else:
                req.admit_tick = self._tick
                lane.admit(req, slot)
        self._pending = kept

    def step(self) -> list[GenRequest]:
        """One scheduler tick; returns the requests completed by it."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        self._admit()
        done: list[GenRequest] = []
        for lane in self._lanes.values():
            if lane.busy:
                done.extend(lane.tick())
        self._tick += 1
        for req in done:
            req.done_tick = self._tick
            self._done[req.rid] = req
        return done

    def run(self) -> dict[int, np.ndarray]:
        """Drain queue + in-flight work; returns ``rid -> image``."""
        while self._pending or any(l.busy for l in self._lanes.values()):
            self.step()
        return {rid: r.result for rid, r in sorted(self._done.items())}

    # ------------------------------------------------------------- metrics --
    @property
    def completed(self) -> dict[int, GenRequest]:
        return dict(self._done)

    def stats(self) -> dict[str, float]:
        wall = (time.perf_counter() - self._t0) if self._t0 else 0.0
        dev_steps = sum(l.device_steps for l in self._lanes.values())
        n = len(self._done)
        waits = [r.wait_ticks for r in self._done.values()]
        return {
            "requests": n,
            "ticks": self._tick,
            "device_steps": dev_steps,
            "wall_s": wall,
            "images_per_s": n / wall if wall else 0.0,
            "steps_per_s": dev_steps / wall if wall else 0.0,
            "mean_wait_ticks": float(np.mean(waits)) if waits else 0.0,
            "max_wait_ticks": float(np.max(waits)) if waits else 0.0,
        }


def reference_sample(params: dict, *, steps: int, seed: int, image_size: int,
                     out_ch: int = 3, backend: str = "xla",
                     interpret: bool | None = None, decomposed: bool = True,
                     t_max: int = DDIM_T_MAX) -> np.ndarray:
    """Unbatched single-request DDIM loop — the parity oracle the served
    (mixed-timestep, continuously batched) path must match to <= 1e-5."""
    step = jax.jit(make_gen_step(t_max=t_max, decomposed=decomposed,
                                 backend=backend, interpret=interpret),
                   donate_argnums=(1,))
    traj = ddim_timesteps(steps, t_max)
    x = init_noise(seed, (image_size, image_size, out_ch))[None]
    for i, t in enumerate(traj):
        nxt = int(traj[i + 1]) if i + 1 < len(traj) else -1
        batch = {"t": jnp.full((1,), int(t), jnp.int32),
                 "t_next": jnp.full((1,), nxt, jnp.int32),
                 "active": jnp.ones((1,), bool)}
        x = step(params, x, batch)
    return np.asarray(x)[0]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", default="unet_dec",
                    choices=sorted(GEN_WORKLOADS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--steps", default="8,5,3",
                    help="comma list of diffusion step budgets, cycled")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--backend", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny widths (CI): 16x16 images, small DCGAN")
    ns = ap.parse_args()

    from repro.core import calibrate as cal

    kw: dict = dict(batch=ns.batch, backend=ns.backend)
    if ns.smoke or (ns.backend == "pallas" and jax.default_backend() == "cpu"):
        # interpret-mode pallas needs tiny widths to stay tractable on CPU
        kw.update(unet_widths=(8, 8), unet_hw=4, dcgan_nz=16, dcgan_ngf=4)
    cache = cal.default_cache_path()
    if cache.exists():          # host-grounded admission estimates when a
        kw["calibration"] = cal.Calibration.load(cache)  # table was captured
    server = GenServer(**kw)
    step_list = [int(s) for s in ns.steps.split(",")]
    for i in range(ns.requests):
        server.submit(ns.workload, steps=step_list[i % len(step_list)],
                      seed=ns.seed + i)
    images = server.run()
    st = server.stats()
    print(f"[serve_gen] {st['requests']} requests "
          f"({ns.workload}, steps {ns.steps}) in {st['wall_s']:.2f}s over "
          f"{st['ticks']} ticks / {st['device_steps']} device steps: "
          f"{st['images_per_s']:.2f} img/s, {st['steps_per_s']:.1f} steps/s")
    shp = next(iter(images.values())).shape
    print(f"[serve_gen] image shape {shp}; "
          f"mean wait {st['mean_wait_ticks']:.1f} ticks "
          f"(max {st['max_wait_ticks']:.0f})")
    rep = cm.serve_report(GEN_WORKLOADS[ns.workload](),
                          steps=max(step_list),
                          calibration=server.calibration,
                          backend=ns.backend)
    print(f"[serve_gen] cycle model ({ns.workload}, canonical widths, "
          f"{max(step_list)} steps/sample): "
          f"{rep['images_per_s_ours']:.1f} img/s decomposed vs "
          f"{rep['images_per_s_naive']:.1f} naive "
          f"({rep['serve_speedup_vs_naive']:.2f}x)")
    if "calibrated_us_per_image" in rep:
        print(f"[serve_gen] calibrated host estimate: "
              f"{rep['calibrated_us_per_image']:.0f} us/image "
              f"({rep['calibrated_images_per_s']:.2f} img/s on this host)")


if __name__ == "__main__":
    main()
