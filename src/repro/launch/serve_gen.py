"""Generative serving loop: continuous-batching iterative decoder sampling
on the decomposition engine (DESIGN.md §9).

Requests arrive as ``(workload, steps, seed, slo)`` and are packed into
per-workload device batches (*lanes*).  Diffusion requests iterate the DDIM
step — timestep embedding + U-Net decoder forward through the fused
transposed-conv kernels + DDIM update — and each scheduler tick is ONE
jitted call that fuses up to ``scan_steps`` DDIM steps via ``lax.scan``
(:func:`repro.launch.steps.make_gen_scan_step`): per-slot trajectories are
padded into ``(B, K)`` timestep matrices, so mixed-step requests still
share one compiled step while host dispatch overhead is paid once per
``K`` steps.  Because the transposed-conv geometry is timestep-*invariant*
(the timestep enters only as an embedded value), in-flight requests sitting
at different denoising timesteps share a batch; a slot that finishes is
refilled from the queue on the next tick while its neighbours keep
denoising.  DCGAN requests are single-shot: one tick through the k=4/s=2
generator completes every active slot.

The scheduler is SLO-aware (DESIGN.md §9): every request carries an
:class:`SLOClass` (priority rank + optional latency target + optional
timeout).  Admission per lane orders by ``(class rank, deadline, arrival)``
— strict priority across classes, FIFO within a class (same-class deadlines
are arrival-ordered by construction), with an aging bound so no class
starves — and *acts* on the calibrated ``est_us`` stamped at submit:
a request whose remaining deadline budget cannot cover its estimated
service time is shed at admission instead of wasting a slot.  Requests can
be cancelled (or time out) both queued and mid-flight; a vacated slot is
reusable on the next tick.  Under ``autoscale=True`` each lane grows and
shrinks its device batch between compiled sizes as its backlog moves
(``jax.jit`` caches one executable per batch shape, so revisited sizes
redispatch without recompiling).

The server is fault-tolerant (DESIGN.md §11).  Every ``snapshot_every``
ticks (and on demand via :meth:`GenServer.snapshot`) the full
scheduler-visible state — per-slot image tensors, trajectory cursors,
per-request seeds and SLO metadata, the admission queue, and completed
results — is written through the atomic manifest+COMMITTED checkpoint
layout (``repro.checkpoint``); :meth:`GenServer.restore` resumes a killed
drain mid-flight, and because the mixed-timestep step is timestep-*data*
driven the recovered drain is bitwise-identical on xla to an uninterrupted
run.  A dispatch that raises retries with exponential backoff, then the
lane *degrades* in place to the xla engine (the dispatcher routes both)
instead of killing the server; corrupted slots are detected by a
completion-time finiteness check and re-run from their seed; repeated
stuck-tick flags from a :class:`StragglerWatchdog` shed the
lowest-priority pending class first.  ``faults=`` accepts a
:class:`repro.distributed.fault_tolerance.FailureInjector` so chaos drills
drive all of these paths deterministically.

This mirrors the LM path (``repro.launch.serve``): the scheduler is
host-side and dumb, the device step is pure and compiled once.  The image
state takes its sharding from :func:`repro.distributed.sharding.image_sharding`
(batch over the data axes, optionally spatial rows over the model axis).

CPU-scale usage:

  PYTHONPATH=src python -m repro.launch.serve_gen --smoke
  PYTHONPATH=src python -m repro.launch.serve_gen --requests 6 \
      --steps 8,5,3 --batch 4 --backend xla --scan-steps 4 --slo realtime
"""

from __future__ import annotations

import argparse
import functools
import math
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.core import cycle_model as cm
from repro.core.gen_spec import GEN_WORKLOADS, UNET_WIDTHS
from repro.distributed import sharding as shd
from repro.distributed.fault_tolerance import (FailureInjector,
                                               StragglerWatchdog)
from repro.kernels.util import canon_dtype
from repro.launch.steps import (DDIM_T_MAX, ddim_timesteps,
                                make_gen_scan_step)
from repro.models import dcgan, unet_decoder


def init_noise(seed: int, shape: tuple[int, ...]) -> jax.Array:
    """Seeded x_T (or latent) — shared by the server and the reference loop
    so a served request is bit-for-bit reproducible from its seed."""
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# ---------------------------------------------------------------------------
# SLO classes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SLOClass:
    """One service-level class: admission priority + latency contract.

    ``rank`` orders admission (lower admits first).  ``target_us`` is the
    end-to-end latency budget measured from submit; when both it and the
    request's calibrated ``est_us`` are known, a request whose remaining
    budget cannot cover its estimated service time is *shed* at admission
    (status ``"shed"``) instead of occupying a slot it is guaranteed to
    miss in.  ``timeout_ticks`` is the default scheduler-tick lifetime
    (queued + in-flight) for requests of the class; ``None`` never expires.
    """
    name: str
    rank: int
    target_us: float | None = None
    timeout_ticks: int | None = None


#: built-in classes; ``submit(..., slo=...)`` accepts a name here or any
#: ad-hoc :class:`SLOClass` (tests pass tight targets to pin shedding).
SLO_CLASSES = {
    "realtime": SLOClass("realtime", 0, target_us=1e6),
    "standard": SLOClass("standard", 1),
    "batch": SLOClass("batch", 2),
}

#: admission waits longer than this many ticks promote a request to the
#: front regardless of class — the cross-class anti-starvation bound
#: (within a class admission is already FIFO).
DEFAULT_STARVATION_TICKS = 64

#: fused-dispatch depth used when ``scan_steps="auto"`` finds no
#: calibration coverage for the lane's layer mix.
DEFAULT_SCAN_STEPS = 4

#: upper bound for the auto-chosen fused depth: past this the per-dispatch
#: amortisation win is negligible while a tick's latency (and the work
#: wasted by a mid-flight cancel) keeps growing linearly.
MAX_SCAN_STEPS = 8


def choose_scan_steps(calibration, layers, *, backend: str = "xla",
                      batch: int = 1, target_tick_us: float = 50_000.0,
                      max_scan: int = MAX_SCAN_STEPS) -> int:
    """Fused depth K chosen against tick latency (the PR-6 calibration).

    The largest K whose predicted fused-tick wall time — ``batch x K`` per-
    pass compute plus one per-pass dispatch overhead
    (:meth:`Calibration.predict_layers_split`) — stays within
    ``target_tick_us``, clamped to ``[1, max_scan]``.  A longer scan
    amortises host dispatch further but delays scheduler decisions
    (admission, cancel, autoscale all happen between ticks), so the target
    bounds the scheduler's reaction latency.  Without a calibration (or
    without coverage for some layer kind) returns
    :data:`DEFAULT_SCAN_STEPS`.
    """
    if max_scan < 1:
        raise ValueError(f"max_scan must be >= 1, got {max_scan}")
    split = (calibration.predict_layers_split(layers, backend=backend)
             if calibration is not None else None)
    if split is None:
        return min(DEFAULT_SCAN_STEPS, max_scan)
    compute_us, dispatch_us = split
    per_step = batch * compute_us
    if per_step <= 0.0:
        return max_scan
    k = int((target_tick_us - dispatch_us) // per_step)
    return max(1, min(max_scan, k))


# ---------------------------------------------------------------------------
# Requests
# ---------------------------------------------------------------------------

@dataclass
class GenRequest:
    """One sampling request; ticks are scheduler steps, not wall time."""
    rid: int
    workload: str
    steps: int
    seed: int
    submit_tick: int
    slo: SLOClass = SLO_CLASSES["standard"]
    timeout_ticks: int | None = None
    submit_wall: float = field(default_factory=time.perf_counter)
    admit_tick: int = -1
    done_tick: int = -1
    done_wall: float = 0.0
    result: np.ndarray | None = None
    # lifecycle: pending -> active -> done, or a terminal non-result state
    # (cancelled / timeout / shed / corrupt) — terminal states never hold a
    # result
    status: str = "pending"
    # calibrated host-time admission estimate (us) for the whole request, or
    # None when the server has no calibration fitted for this layer mix
    est_us: float | None = None
    # completion-time corruption detections that sent this request back to
    # the queue for a clean re-run (bounded by the server's max_requeues)
    requeues: int = 0

    @property
    def wait_ticks(self) -> int:
        return self.admit_tick - self.submit_tick

    @property
    def latency_s(self) -> float:
        """Submit-to-completion wall latency (0.0 until done)."""
        return (self.done_wall - self.submit_wall) if self.done_wall else 0.0

    def deadline_us(self) -> float:
        """Absolute wall deadline in perf-counter microseconds (inf when the
        class carries no latency target)."""
        if self.slo.target_us is None:
            return math.inf
        return self.submit_wall * 1e6 + self.slo.target_us


# ---------------------------------------------------------------------------
# Lanes
# ---------------------------------------------------------------------------

class _DiffusionLane:
    """Resizable batch of diffusion slots over one compiled K-step scan."""

    kind = "diffusion"

    def __init__(self, params: dict, *, batch: int, widths: tuple[int, ...],
                 hw: int, out_ch: int, backend: str,
                 interpret: bool | None, decomposed: bool, mesh=None,
                 spatial: bool = False, scan_steps: int = 1,
                 compute_dtype: str | None = None):
        size = hw * 2 ** len(widths)
        self.image_shape = (size, size, out_ch)
        self.params = params
        self.scan_steps = scan_steps
        self.backend = backend
        self.decomposed, self.interpret = decomposed, interpret
        self.compute_dtype = compute_dtype
        # lane image state lives in the compute dtype: the fused step's
        # fp32 DDIM update casts back to it, so the slots stay bf16-resident
        # end to end (half the HBM per slot) when the lane opts in
        self._x_dtype = (jnp.float32 if compute_dtype is None
                         else canon_dtype(compute_dtype))
        self.mesh, self.spatial = mesh, spatial
        self._raw_step = make_gen_scan_step(scan_steps, decomposed=decomposed,
                                            backend=backend,
                                            interpret=interpret,
                                            compute_dtype=compute_dtype)
        if mesh is not None:
            self.params = jax.device_put(params, shd.replicated(mesh))
        self.device_steps = 0       # host dispatches (one per busy tick)
        self.substeps = 0           # active trajectory steps actually taken
        self.compiled_sizes: set[int] = set()
        self._alloc(batch)

    def set_backend(self, backend: str) -> None:
        """Swap the dispatch backend in place (graceful degradation,
        DESIGN.md §11): the compiled step is rebuilt, every slot's image
        state, trajectory cursor, and request stay exactly where they are —
        the DDIM update is backend-invariant data flow, so a degraded lane
        continues the same trajectories on the fallback engine."""
        if backend == self.backend:
            return
        self.backend = backend
        self._raw_step = make_gen_scan_step(
            self.scan_steps, decomposed=self.decomposed, backend=backend,
            interpret=self.interpret, compute_dtype=self.compute_dtype)
        self._step, _ = self._jit_step(self.batch)
        self.compiled_sizes = set()

    def corrupt(self, slot: int) -> None:
        """Chaos hook: poison one slot's image state with NaNs (the
        completion-time finiteness check must catch and re-run it)."""
        self.x = self.x.at[slot % self.batch].set(jnp.nan)

    def state_arrays(self) -> dict[str, np.ndarray]:
        """Device state for a lane snapshot (everything non-reconstructible:
        slot metadata travels in the manifest extra instead)."""
        return {"x": np.asarray(self.x)}

    def load_state(self, arrays: dict[str, np.ndarray]) -> None:
        x = jnp.asarray(arrays["x"])
        self.x = x if self.mesh is None else jax.device_put(
            x, shd.image_sharding(self.mesh, x.shape, spatial=self.spatial))

    def _jit_step(self, batch: int):
        """One jitted K-step scan per mesh sharding; ``jax.jit`` itself
        caches one executable per batch shape, so lanes revisiting a size
        after autoscaling redispatch without recompiling."""
        if self.mesh is not None:
            sh = shd.image_sharding(self.mesh, (batch,) + self.image_shape,
                                    spatial=self.spatial)
            return jax.jit(self._raw_step, donate_argnums=(1,),
                           out_shardings=sh), sh
        return jax.jit(self._raw_step, donate_argnums=(1,)), None

    def _alloc(self, batch: int) -> None:
        self.batch = batch
        self._step, sh = self._jit_step(batch)
        x = jnp.zeros((batch,) + self.image_shape, self._x_dtype)
        self.x = x if sh is None else jax.device_put(x, sh)
        self.slots: list[GenRequest | None] = [None] * batch
        self._traj: list[np.ndarray | None] = [None] * batch
        self._pos = [0] * batch
        self.active = np.zeros(batch, bool)

    @property
    def busy(self) -> bool:
        return self.active.any()

    @property
    def active_count(self) -> int:
        return int(self.active.sum())

    def free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def admit(self, req: GenRequest, slot: int) -> None:
        traj = ddim_timesteps(req.steps)
        self.slots[slot] = req
        self._traj[slot] = traj
        self._pos[slot] = 0
        self.active[slot] = True
        self.x = self.x.at[slot].set(
            init_noise(req.seed, self.image_shape).astype(self.x.dtype))

    def release(self, slot: int) -> None:
        """Vacate a slot mid-flight (cancel/timeout): the slot is reusable
        on the next admission pass; the stale image rows are inert (the
        active mask keeps them out of every future scan substep)."""
        self.slots[slot] = self._traj[slot] = None
        self._pos[slot] = 0
        self.active[slot] = False

    def resize(self, new_batch: int) -> None:
        """Re-pack occupied slots into a ``new_batch``-sized lane.

        Occupied slots compact to the front in slot order; every request's
        trajectory position and image state move with it, so a resize never
        perturbs a sample (pinned bitwise in ``tests/test_serve_gen.py``).
        """
        occ = [i for i, s in enumerate(self.slots) if s is not None]
        if len(occ) > new_batch:
            raise ValueError(
                f"cannot shrink to {new_batch}: {len(occ)} slots occupied")
        if new_batch == self.batch:
            return
        old = (self.x, [self.slots[i] for i in occ],
               [self._traj[i] for i in occ], [self._pos[i] for i in occ])
        self._alloc(new_batch)
        x_old, slots, trajs, poss = old
        if occ:
            self.x = self.x.at[:len(occ)].set(
                x_old[jnp.asarray(occ, jnp.int32)])
        for i, (s, tr, p) in enumerate(zip(slots, trajs, poss)):
            self.slots[i], self._traj[i], self._pos[i] = s, tr, p
            self.active[i] = True

    def tick(self) -> list[GenRequest]:
        b, k = self.batch, self.scan_steps
        t = np.zeros((b, k), np.int32)
        t_next = np.full((b, k), -1, np.int32)
        act = np.zeros((b, k), bool)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            traj, p = self._traj[i], self._pos[i]
            take = min(k, len(traj) - p)
            for j in range(take):
                t[i, j] = traj[p + j]
                if p + j + 1 < len(traj):
                    t_next[i, j] = traj[p + j + 1]
                act[i, j] = True
        if self.batch not in self.compiled_sizes:
            self.compiled_sizes.add(self.batch)
        batch = {"t": jnp.asarray(t), "t_next": jnp.asarray(t_next),
                 "active": jnp.asarray(act)}
        self.x = self._step(self.params, self.x, batch)
        self.device_steps += 1
        self.substeps += int(act.sum())
        done = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self._pos[i] += int(act[i].sum())
            if self._pos[i] == len(self._traj[i]):        # landed on x0
                req.result = np.asarray(self.x[i])
                done.append(req)
                self.release(i)
        return done


class _DCGANLane:
    """Single-shot generation: one tick drains every active latent slot.

    The generator forward is jitted ONCE here (with the static backend
    arguments closed over), not re-entered through the module-level wrapper
    every tick — one compile per batch size, then pure dispatch (warm-tick
    dispatch count pinned in ``tests/test_serve_gen.py``).
    """

    kind = "dcgan"
    scan_steps = 1

    def __init__(self, params: dict, *, batch: int, nz: int, backend: str,
                 interpret: bool | None, decomposed: bool, mesh=None,
                 compute_dtype: str | None = None):
        self.params = params
        self.nz = nz
        self.backend = backend
        self.decomposed, self.interpret = decomposed, interpret
        self.compute_dtype = compute_dtype
        self.mesh = mesh
        if mesh is not None:
            self.params = jax.device_put(params, shd.replicated(mesh))
        self._step = jax.jit(functools.partial(
            dcgan.forward, decomposed=decomposed, backend=backend,
            interpret=interpret, compute_dtype=compute_dtype))
        self.device_steps = 0
        self.substeps = 0
        self.compiled_sizes: set[int] = set()
        self._alloc(batch)

    def set_backend(self, backend: str) -> None:
        if backend == self.backend:
            return
        self.backend = backend
        self._step = jax.jit(functools.partial(
            dcgan.forward, decomposed=self.decomposed, backend=backend,
            interpret=self.interpret, compute_dtype=self.compute_dtype))
        self.compiled_sizes = set()

    def corrupt(self, slot: int) -> None:
        self.z = self.z.at[slot % self.batch].set(jnp.nan)

    def state_arrays(self) -> dict[str, np.ndarray]:
        return {"z": np.asarray(self.z)}

    def load_state(self, arrays: dict[str, np.ndarray]) -> None:
        self.z = self._place(jnp.asarray(arrays["z"]))

    def _place(self, z: jax.Array) -> jax.Array:
        """Latent slots shard over the mesh's data axes (lanes span the
        mesh like the diffusion lane's image state; the generator's
        transposed-conv parity planes are batch-parallel)."""
        if self.mesh is None:
            return z
        return jax.device_put(z, shd.image_sharding(self.mesh, z.shape))

    def _alloc(self, batch: int) -> None:
        self.batch = batch
        self.z = self._place(jnp.zeros((batch, self.nz), jnp.float32))
        self.slots: list[GenRequest | None] = [None] * batch
        self.active = np.zeros(batch, bool)

    @property
    def busy(self) -> bool:
        return self.active.any()

    @property
    def active_count(self) -> int:
        return int(self.active.sum())

    def free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def admit(self, req: GenRequest, slot: int) -> None:
        self.slots[slot] = req
        self.active[slot] = True
        self.z = self.z.at[slot].set(init_noise(req.seed, (self.nz,)))

    def release(self, slot: int) -> None:
        self.slots[slot] = None
        self.active[slot] = False

    def resize(self, new_batch: int) -> None:
        occ = [i for i, s in enumerate(self.slots) if s is not None]
        if len(occ) > new_batch:
            raise ValueError(
                f"cannot shrink to {new_batch}: {len(occ)} slots occupied")
        if new_batch == self.batch:
            return
        z_old, slots = self.z, [self.slots[i] for i in occ]
        self._alloc(new_batch)
        if occ:
            self.z = self.z.at[:len(occ)].set(
                z_old[jnp.asarray(occ, jnp.int32)])
        for i, s in enumerate(slots):
            self.slots[i] = s
            self.active[i] = True

    def tick(self) -> list[GenRequest]:
        if self.batch not in self.compiled_sizes:
            self.compiled_sizes.add(self.batch)
        imgs = np.asarray(self._step(self.params, self.z))
        self.device_steps += 1
        done = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.substeps += 1
            req.result = imgs[i]
            done.append(req)
            self.release(i)
        return done


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------

class GenServer:
    """Continuous-batching generative server over the decomposition engine.

    One lane (device batch + compiled K-step scan) per workload, built
    lazily on the first request for it.  ``submit`` enqueues, ``step`` runs
    one scheduler tick (expire timeouts, autoscale, admit into free slots,
    then one fused device dispatch per busy lane), ``run`` drains the queue
    and returns ``rid -> image``.

    **Admission** (DESIGN.md §9): per lane, pending requests order by
    ``(SLO rank, deadline, arrival)`` — strict priority across classes,
    FIFO within a class (a class's deadlines are arrival-ordered because
    the latency target is a constant offset), and any request waiting
    longer than ``starvation_ticks`` is promoted to the front, so no class
    starves.  A full lane never blocks another lane.  When a request
    carries both a calibrated ``est_us`` stamp and a latency target, an
    admission attempt whose remaining budget is below the estimate *sheds*
    the request (status ``"shed"``) instead of burning a slot on a
    guaranteed SLO miss — the scheduler finally acting on the PR-6
    admission estimates.

    ``scan_steps`` fuses K DDIM steps per dispatch (``"auto"`` sizes K per
    lane from the calibration via :func:`choose_scan_steps`); ``autoscale``
    lets each lane grow/shrink its batch between compiled sizes with its
    backlog.  ``params`` overrides model parameters per workload name
    (tests and the smoke paths pass tiny-width denoisers); otherwise lanes
    initialise canonical-width parameters from ``param_seed``.

    **Fault tolerance** (DESIGN.md §11): a lane dispatch that raises is
    retried ``max_retries`` times with exponential backoff starting at
    ``retry_backoff_s``; a lane still failing on a non-xla backend then
    *degrades* in place to xla and keeps its trajectories.  Results are
    finiteness-checked at completion; a corrupted sample re-runs from its
    seed (at most ``max_requeues`` times, then status ``"corrupt"``).
    ``watchdog`` (a :class:`StragglerWatchdog`) flags stuck ticks;
    ``stuck_shed_after`` consecutive flags shed the lowest-priority pending
    class.  With ``snapshot_dir`` set, :meth:`snapshot` checkpoints the
    full scheduler state (auto every ``snapshot_every`` ticks) and
    :meth:`restore` resumes a killed drain exactly.  ``faults`` accepts a
    :class:`FailureInjector` whose scheduled faults the tick loop consumes
    at fixed points, so chaos drills are deterministic.
    """

    def __init__(self, *, batch: int = 4, backend: str = "xla",
                 interpret: bool | None = None, decomposed: bool = True,
                 mesh=None, spatial: bool = False,
                 unet_widths: tuple[int, ...] = UNET_WIDTHS, unet_hw: int = 8,
                 out_ch: int = 3, dcgan_nz: int = 100, dcgan_ngf: int = 64,
                 params: dict | None = None, param_seed: int = 0,
                 calibration=None, scan_steps: int | str = 1,
                 autoscale: bool = False, min_batch: int = 1,
                 max_batch: int | None = None, shrink_patience: int = 2,
                 starvation_ticks: int = DEFAULT_STARVATION_TICKS,
                 faults: FailureInjector | None = None,
                 watchdog: StragglerWatchdog | None = None,
                 max_retries: int = 3, retry_backoff_s: float = 0.05,
                 stuck_shed_after: int = 3, max_requeues: int = 1,
                 snapshot_dir: str | None = None, snapshot_every: int = 0,
                 snapshot_keep: int = 3,
                 compute_dtype: str | None = None):
        if isinstance(scan_steps, str):
            if scan_steps != "auto":
                raise ValueError(
                    f"scan_steps must be an int >= 1 or 'auto', "
                    f"got {scan_steps!r}")
        elif scan_steps < 1:
            raise ValueError(f"scan_steps must be >= 1, got {scan_steps}")
        self.batch = batch
        self.backend = backend
        self.interpret = interpret
        self.decomposed = decomposed
        self.compute_dtype = compute_dtype
        self.mesh = mesh
        self.spatial = spatial
        self.unet_widths, self.unet_hw, self.out_ch = unet_widths, unet_hw, out_ch
        self.dcgan_nz, self.dcgan_ngf = dcgan_nz, dcgan_ngf
        self._params = dict(params or {})
        self._param_seed = param_seed
        self.calibration = calibration
        self.scan_steps = scan_steps
        self.autoscale = autoscale
        self.min_batch = max(1, min_batch)
        self.max_batch = max(batch, max_batch or batch * 4)
        self.shrink_patience = shrink_patience
        self.starvation_ticks = starvation_ticks
        self.faults = faults
        self.watchdog = watchdog
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.stuck_shed_after = max(1, stuck_shed_after)
        self.max_requeues = max_requeues
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        self.snapshot_keep = snapshot_keep
        # fault-tolerance counters (surfaced by stats(), DESIGN.md §11)
        self._degraded: dict[str, str] = {}   # workload -> fallback backend
        self._retries = 0
        self._recoveries = 0
        self._snapshots = 0
        self._stuck = 0                       # consecutive stuck-tick flags
        self._lanes: dict[str, _DiffusionLane | _DCGANLane] = {}
        self._idle_ticks: dict[str, int] = {}
        self._pending: list[GenRequest] = []
        self._done: dict[int, GenRequest] = {}
        self._requests: dict[int, GenRequest] = {}
        self._tick = 0
        self._next_rid = 0
        self._t0: float | None = None
        # per-tick log: (wall_s, dispatches, completions, substeps, cold) —
        # cold = a lane compiled a new batch shape inside the tick, so warm
        # throughput can be reported without the compile wall (stats())
        self._tick_log: list[tuple[float, int, int, int, bool]] = []

    # -------------------------------------------------------------- lanes --
    def _workload_layers(self, workload: str):
        """Layer table of the geometry this server will actually execute.

        The canonical ``GEN_WORKLOADS`` tables assume canonical widths; a
        server constructed with overrides (``--smoke``, tests,
        ``unet_widths``/``unet_hw``) runs a different geometry, and an
        admission estimate priced off the canonical table would not match
        what executes — so the table is derived from the lane parameters.
        """
        from repro.core import gen_spec

        if workload == "unet_dec":
            return gen_spec.unet_decoder_layers(
                tuple(self.unet_widths), hw=self.unet_hw, out_ch=self.out_ch)
        if workload in ("dcgan64", "dcgan128"):
            return gen_spec.dcgan_layers(
                int(workload[5:]), nz=self.dcgan_nz, ngf=self.dcgan_ngf,
                out_ch=self.out_ch)
        raise ValueError(f"unknown workload {workload!r}; "
                         f"known: {sorted(GEN_WORKLOADS)}")

    def _lane_scan_steps(self, workload: str) -> int:
        if workload != "unet_dec":
            return 1            # single-shot lanes have no trajectory to fuse
        if self.scan_steps == "auto":
            return choose_scan_steps(self.calibration,
                                     self._workload_layers(workload),
                                     backend=self.backend, batch=self.batch)
        return int(self.scan_steps)

    def _init_params(self, workload: str) -> dict:
        """Lane parameters: the per-workload override if given, else a
        deterministic init from ``param_seed`` (also the structural template
        restore() unflattens snapshotted parameter leaves into)."""
        if workload == "unet_dec":
            return self._params.get(workload) or \
                unet_decoder.init_denoiser_params(
                    jax.random.PRNGKey(self._param_seed),
                    widths=self.unet_widths, out_ch=self.out_ch)
        if workload in ("dcgan64", "dcgan128"):
            return self._params.get(workload) or dcgan.init_params(
                jax.random.PRNGKey(self._param_seed), size=int(workload[5:]),
                nz=self.dcgan_nz, ngf=self.dcgan_ngf, out_ch=self.out_ch)
        raise ValueError(f"unknown workload {workload!r}; "
                         f"known: {sorted(GEN_WORKLOADS)}")

    def _lane(self, workload: str, *, batch: int | None = None,
              scan_steps: int | None = None):
        """The lane for ``workload``, built on first use.  ``batch`` /
        ``scan_steps`` override the configured sizing — restore() passes the
        snapshotted values so a recovered lane compiles the exact geometry
        that was running."""
        lane = self._lanes.get(workload)
        if lane is not None:
            return lane
        p = self._init_params(workload)
        kw = dict(backend=self.backend, interpret=self.interpret,
                  decomposed=self.decomposed, batch=batch or self.batch,
                  compute_dtype=self.compute_dtype)
        if workload == "unet_dec":
            lane = _DiffusionLane(
                p, widths=self.unet_widths, hw=self.unet_hw,
                out_ch=self.out_ch, mesh=self.mesh, spatial=self.spatial,
                scan_steps=(scan_steps if scan_steps is not None
                            else self._lane_scan_steps(workload)), **kw)
        else:
            lane = _DCGANLane(p, nz=self.dcgan_nz, mesh=self.mesh, **kw)
        self._lanes[workload] = lane
        self._idle_ticks[workload] = 0
        return lane

    # ---------------------------------------------------------- scheduling --
    def admission_estimate(self, workload: str, steps: int = 1) -> float | None:
        """Calibrated host-time estimate (us) for one request: the fitted
        per-kind cycles->us mapping applied to the layer table of the
        geometry THIS server executes (``_workload_layers`` — canonical only
        when the server runs canonical widths) x DDIM ``steps``.  None
        without a calibration, or when the calibration lacks a fitted key
        for one of the workload's layer kinds on this server's backend —
        callers must treat that as "no estimate", not zero cost."""
        if self.calibration is None:
            return None
        dtype = ("float32" if self.compute_dtype is None
                 else canon_dtype(self.compute_dtype).name)
        us = self.calibration.predict_layers(self._workload_layers(workload),
                                             backend=self.backend,
                                             dtype=dtype)
        return None if us is None else us * max(steps, 1)

    def submit(self, workload: str, *, steps: int = 1, seed: int = 0,
               slo: str | SLOClass = "standard",
               timeout_ticks: int | None = None) -> int:
        """Enqueue a request; returns its id.  DCGAN is single-shot
        (``steps`` is forced to 1); diffusion runs a ``steps``-step DDIM
        trajectory.  ``slo`` is a name from :data:`SLO_CLASSES` or an
        ad-hoc :class:`SLOClass`; ``timeout_ticks`` overrides the class
        default lifetime."""
        self._lane(workload)        # fail fast on unknown workloads
        if isinstance(slo, str):
            try:
                slo = SLO_CLASSES[slo]
            except KeyError:
                raise ValueError(f"unknown SLO class {slo!r}; known: "
                                 f"{sorted(SLO_CLASSES)}") from None
        if workload != "unet_dec":
            steps = 1
        req = GenRequest(self._next_rid, workload, steps, seed, self._tick,
                         slo=slo,
                         timeout_ticks=(slo.timeout_ticks
                                        if timeout_ticks is None
                                        else timeout_ticks))
        req.est_us = self.admission_estimate(workload, steps)
        self._next_rid += 1
        self._pending.append(req)
        self._requests[req.rid] = req
        return req.rid

    def cancel(self, rid: int, status: str = "cancelled") -> bool:
        """Cancel a request wherever it lives.

        Queued requests leave the queue; in-flight requests vacate their
        slot (reusable on the next tick; the lane's active mask keeps the
        stale image rows out of every future substep).  Terminal requests
        (done or already cancelled) are left untouched.  Returns whether
        anything was cancelled.  No result is ever recorded for a cancelled
        request.
        """
        req = self._requests.get(rid)
        if req is None or req.status in ("done", "cancelled", "timeout",
                                         "shed", "corrupt"):
            return False
        if req.status == "pending":
            self._pending.remove(req)
        else:                                   # active: vacate the slot
            lane = self._lanes[req.workload]
            lane.release(lane.slots.index(req))
        req.status = status
        return True

    def _expire(self) -> None:
        """Time out requests (queued or in-flight) past their tick budget."""
        for req in list(self._requests.values()):
            if req.status not in ("pending", "active"):
                continue
            if req.timeout_ticks is None:
                continue
            if self._tick - req.submit_tick >= req.timeout_ticks:
                self.cancel(req.rid, status="timeout")

    def _admission_key(self, req: GenRequest):
        """Priority ordering: aged requests first (cross-class starvation
        bound), then SLO rank, then deadline (FIFO within a class — equal
        targets make deadline order arrival order), then arrival."""
        aged = (self._tick - req.submit_tick) >= self.starvation_ticks
        return (0 if aged else 1, req.slo.rank, req.deadline_us(), req.rid)

    def _admit(self) -> None:
        now_us = time.perf_counter() * 1e6
        by_lane: dict[str, list[GenRequest]] = {}
        for req in self._pending:
            by_lane.setdefault(req.workload, []).append(req)
        for workload, reqs in by_lane.items():
            lane = self._lane(workload)
            for req in sorted(reqs, key=self._admission_key):
                # deadline-infeasible: the stamped estimate says the SLO is
                # already unmeetable — shed rather than burn the slot
                if (req.est_us is not None
                        and req.deadline_us() - now_us < req.est_us):
                    self._pending.remove(req)
                    req.status = "shed"
                    continue
                slot = lane.free_slot()
                if slot is None:
                    break               # lane full; later classes wait too
                req.admit_tick = self._tick
                req.status = "active"
                lane.admit(req, slot)
                self._pending.remove(req)

    def _autoscale(self) -> None:
        """Grow a backlogged lane / shrink an underused one, one ladder
        rung (x2 / ÷2) per tick, within ``[min_batch, max_batch]``.  Policy
        is a pure function of queue state, so a given request sequence
        always produces the same batch trajectory (pinned in tests)."""
        backlog: dict[str, int] = {}
        for req in self._pending:
            backlog[req.workload] = backlog.get(req.workload, 0) + 1
        for workload, lane in self._lanes.items():
            want = backlog.get(workload, 0)
            free = lane.batch - lane.active_count
            if want > free and lane.batch < self.max_batch:
                lane.resize(min(lane.batch * 2, self.max_batch))
                self._idle_ticks[workload] = 0
                continue
            half = lane.batch // 2
            if (want == 0 and half >= self.min_batch
                    and lane.active_count <= half):
                self._idle_ticks[workload] += 1
                if self._idle_ticks[workload] >= self.shrink_patience:
                    lane.resize(half)
                    self._idle_ticks[workload] = 0
            else:
                self._idle_ticks[workload] = 0

    # ------------------------------------------------------ fault handling --
    def _lane_tick(self, workload: str, lane) -> list[GenRequest]:
        """One lane dispatch behind the retry/degrade ladder (DESIGN.md §11).

        A raise is retried up to ``max_retries`` times with exponential
        backoff; a lane that keeps failing on a non-xla backend then
        degrades in place to xla (``set_backend`` keeps every trajectory
        where it is) and the ladder restarts on the fallback engine.  An
        xla lane that exhausts its retries propagates — there is no lower
        rung.  Injected ``raise`` faults fire *before* the device call, so
        a retried tick re-enters with untouched lane state (matching the
        real failure mode: pallas errors surface at trace/lower/launch
        time, before the donated image buffer is consumed).
        """
        backoff = self.retry_backoff_s
        attempts, failed = 0, False
        while True:
            try:
                if self.faults is not None and self.faults.take(
                        self._tick, kind="raise", target=workload,
                        backend=lane.backend):
                    raise RuntimeError(
                        f"injected {lane.backend} dispatch failure on lane "
                        f"{workload!r} at tick {self._tick}")
                done = lane.tick()
            except Exception:
                failed = True
                attempts += 1
                if attempts <= self.max_retries:
                    self._retries += 1
                    if backoff > 0:
                        time.sleep(backoff)
                    backoff *= 2
                    continue
                if lane.backend != "xla":
                    lane.set_backend("xla")
                    self._degraded[workload] = "xla"
                    attempts, backoff = 0, self.retry_backoff_s
                    continue
                raise
            if failed:
                self._recoveries += 1
            return done

    def _result_ok(self, req: GenRequest) -> bool:
        """Completion-time corruption gate: a non-finite sample is never
        surfaced.  The request re-runs from its seed (bitwise-correct on a
        clean pass) up to ``max_requeues`` times, then lands terminal as
        ``"corrupt"``."""
        if req.result is not None and np.isfinite(req.result).all():
            return True
        req.result = None
        if req.requeues < self.max_requeues:
            req.requeues += 1
            req.status = "pending"
            req.admit_tick = -1
            self._pending.append(req)
            self._recoveries += 1
        else:
            req.status = "corrupt"
        return False

    def _shed_lowest_class(self) -> None:
        """Stuck-tick load shedding: drop every *pending* request of the
        lowest-priority class present (highest SLO rank) — the PR-7 ladder
        applied as back-pressure relief.  In-flight work is never shed."""
        if not self._pending:
            return
        worst = max(r.slo.rank for r in self._pending)
        for req in [r for r in self._pending if r.slo.rank == worst]:
            self._pending.remove(req)
            req.status = "shed"

    def step(self) -> list[GenRequest]:
        """One scheduler tick; returns the requests completed by it.

        Fault-plane injection points, in tick order: ``kill`` (raised
        before any state mutates — simulates the process dying; recovery
        is :meth:`restore` from the last snapshot), ``slow`` (stall inside
        the timed window, seen by the watchdog), ``corrupt`` (poisons a
        lane slot, caught by the completion gate), ``raise`` (inside
        :meth:`_lane_tick`'s retry/degrade ladder).
        """
        t_start = time.perf_counter()
        if self._t0 is None:
            self._t0 = t_start
        inj = self.faults
        if inj is not None and inj.take(self._tick, kind="kill"):
            raise RuntimeError(f"injected server kill at tick {self._tick}")
        self._expire()
        if self.autoscale:
            self._autoscale()
        self._admit()
        if inj is not None:
            stall = inj.sleep_faults(self._tick)
            if stall > 0:
                time.sleep(stall)
            for f in inj.take(self._tick, kind="corrupt"):
                lane = (self._lanes.get(f.target) if f.target is not None
                        else next((l for l in self._lanes.values()
                                   if l.busy), None))
                if lane is not None:
                    lane.corrupt(f.slot)
        done: list[GenRequest] = []
        dispatches = substeps = 0
        cold = False
        for workload, lane in self._lanes.items():
            if lane.busy:
                cold = cold or lane.batch not in lane.compiled_sizes
                sub0 = lane.substeps
                done.extend(self._lane_tick(workload, lane))
                dispatches += 1
                substeps += lane.substeps - sub0
        self._tick += 1
        t_end = time.perf_counter()
        done = [r for r in done if self._result_ok(r)]
        for req in done:
            req.done_tick = self._tick
            req.done_wall = t_end
            req.status = "done"
            self._done[req.rid] = req
        self._tick_log.append(
            (t_end - t_start, dispatches, len(done), substeps, cold))
        if self.watchdog is not None and dispatches:
            self._stuck = (self._stuck + 1 if self.watchdog.observe(
                self._tick - 1, t_end - t_start) else 0)
            if self._stuck >= self.stuck_shed_after:
                self._shed_lowest_class()
                self._stuck = 0
        if (self.snapshot_dir is not None and self.snapshot_every > 0
                and self._tick % self.snapshot_every == 0):
            self.snapshot()
        return done

    def run(self) -> dict[int, np.ndarray]:
        """Drain queue + in-flight work; returns ``rid -> image`` for the
        requests that completed (cancelled/timed-out/shed requests are
        absent — their status lives on ``server.request(rid)``)."""
        while self._pending or any(l.busy for l in self._lanes.values()):
            self.step()
        return {rid: r.result for rid, r in sorted(self._done.items())}

    # ---------------------------------------------------- snapshot/restore --
    _CONFIG_ATTRS = ("batch", "backend", "interpret", "decomposed", "spatial",
                     "unet_hw", "out_ch", "dcgan_nz", "dcgan_ngf",
                     "scan_steps", "autoscale", "min_batch", "max_batch",
                     "shrink_patience", "starvation_ticks", "max_retries",
                     "retry_backoff_s", "stuck_shed_after", "max_requeues",
                     "snapshot_every", "snapshot_keep", "compute_dtype")

    def _snapshot_config(self) -> dict:
        cfg = {k: getattr(self, k) for k in self._CONFIG_ATTRS}
        cfg["unet_widths"] = list(self.unet_widths)
        cfg["param_seed"] = self._param_seed
        if self.mesh is not None:
            # geometry only — devices are process-relative.  restore()
            # rebuilds the same (shape, axes) mesh over whatever devices
            # exist, or reshapes onto a mesh override (resharded restore).
            cfg["mesh"] = {"shape": [int(self.mesh.shape[a])
                                     for a in self.mesh.axis_names],
                           "axes": list(self.mesh.axis_names)}
        return cfg

    @staticmethod
    def _req_meta(req: GenRequest) -> dict:
        """JSON form of everything about a request except its image payload
        (results ride as ``done:<rid>`` arrays; in-flight image state lives
        in the lane arrays).  Wall-clock fields are deliberately absent:
        ``perf_counter`` is process-relative, so restore() re-bases every
        live request to one common "now" — deadline order within a class
        falls back to rid, which *is* arrival order."""
        return {"rid": req.rid, "workload": req.workload, "steps": req.steps,
                "seed": req.seed, "submit_tick": req.submit_tick,
                "slo": {"name": req.slo.name, "rank": req.slo.rank,
                        "target_us": req.slo.target_us,
                        "timeout_ticks": req.slo.timeout_ticks},
                "timeout_ticks": req.timeout_ticks,
                "admit_tick": req.admit_tick, "done_tick": req.done_tick,
                "status": req.status, "est_us": req.est_us,
                "requeues": req.requeues}

    @staticmethod
    def _req_from_meta(m: dict, now: float) -> GenRequest:
        s = m["slo"]
        req = GenRequest(m["rid"], m["workload"], m["steps"], m["seed"],
                         m["submit_tick"],
                         slo=SLOClass(s["name"], s["rank"],
                                      target_us=s["target_us"],
                                      timeout_ticks=s["timeout_ticks"]),
                         timeout_ticks=m["timeout_ticks"])
        req.submit_wall = now
        req.admit_tick = m["admit_tick"]
        req.done_tick = m["done_tick"]
        req.status = m["status"]
        req.est_us = m["est_us"]
        req.requeues = m["requeues"]
        if req.status == "done":
            req.done_wall = now
        return req

    def snapshot(self, directory: str | None = None) -> str:
        """Checkpoint the full scheduler-visible state atomically.

        Everything a restored server needs to finish the drain exactly —
        per-slot image tensors and lane parameters (arrays), trajectory
        cursors, request/SLO metadata, the admission queue, completed
        results, and the fault-tolerance counters (manifest ``extra``) —
        goes through the ``repro.checkpoint`` manifest+COMMITTED layout, so
        a crash mid-snapshot leaves the previous snapshot intact.
        """
        directory = directory or self.snapshot_dir
        if directory is None:
            raise ValueError("snapshot() needs a directory argument or a "
                             "server constructed with snapshot_dir=")
        arrays: dict[str, np.ndarray] = {}
        lanes_meta: dict[str, dict] = {}
        for wl, lane in self._lanes.items():
            lm = {"kind": lane.kind, "batch": lane.batch,
                  "backend": lane.backend, "scan_steps": lane.scan_steps,
                  "device_steps": lane.device_steps,
                  "substeps": lane.substeps,
                  "idle_ticks": self._idle_ticks[wl],
                  "slots": [None if s is None else self._req_meta(s)
                            for s in lane.slots]}
            if lane.kind == "diffusion":
                lm["pos"] = [int(p) for p in lane._pos]
            lanes_meta[wl] = lm
            for k, v in lane.state_arrays().items():
                arrays[f"lane:{wl}:{k}"] = v
            leaves, _ = jax.tree_util.tree_flatten(lane.params)
            for i, leaf in enumerate(leaves):
                arrays[f"param:{wl}:{i:05d}"] = np.asarray(
                    jax.device_get(leaf))
        done_meta, dropped_meta = [], []
        for req in self._requests.values():
            if req.status == "done":
                done_meta.append(self._req_meta(req))
                arrays[f"done:{req.rid:08d}"] = req.result
            elif req.status in ("cancelled", "timeout", "shed", "corrupt"):
                dropped_meta.append(self._req_meta(req))
        meta = {"tick": self._tick, "next_rid": self._next_rid,
                "config": self._snapshot_config(), "lanes": lanes_meta,
                "pending": [self._req_meta(r) for r in self._pending],
                "done": done_meta, "dropped": dropped_meta,
                "degraded": dict(self._degraded), "retries": self._retries,
                "recoveries": self._recoveries,
                "snapshots": self._snapshots + 1}
        ckpt.save_checkpoint(directory, self._tick, arrays,
                             keep=self.snapshot_keep, extra=meta)
        self._snapshots += 1
        return directory

    @classmethod
    def restore(cls, directory: str, *, step: int | None = None,
                **overrides) -> "GenServer":
        """Rebuild a server from the latest (or given) snapshot and resume.

        The drain continues exactly where the snapshot left it: because the
        mixed-timestep scan is timestep-*data* driven and the image state
        round-trips bitwise through the checkpoint, a restored drain on xla
        reproduces the uninterrupted run sample-for-sample (pinned in
        ``tests/test_chaos.py``).  Work that completed *after* the snapshot
        in the killed process is simply recomputed — deterministically, to
        the same images.  ``overrides`` are constructor keywords (pass
        ``calibration=``/``mesh=``/``faults=`` here; they are not
        serialized).
        """
        if step is None:
            step = ckpt.latest_step(directory)
        if step is None:
            raise FileNotFoundError(
                f"no committed snapshot under {directory!r}")
        arrays, meta = ckpt.load_flat(directory, step)
        cfg = dict(meta["config"])
        cfg["unet_widths"] = tuple(cfg["unet_widths"])
        mesh_cfg = cfg.pop("mesh", None)
        if mesh_cfg is not None and "mesh" not in overrides:
            # same-geometry restore: rebuild the snapshotted mesh over this
            # process's devices.  A *resharded* restore (different device
            # count) passes mesh= in overrides instead; the lane state is
            # re-placed through image_sharding either way, so the drain is
            # bitwise regardless of the mesh it resumes on.
            shape = tuple(mesh_cfg["shape"])
            if math.prod(shape) > len(jax.devices()):
                raise ValueError(
                    f"snapshot took a {shape} mesh but only "
                    f"{len(jax.devices())} devices exist; pass mesh= to "
                    f"restore() to reshard")
            cfg["mesh"] = jax.make_mesh(shape, tuple(mesh_cfg["axes"]))
        kw = dict(cfg, snapshot_dir=directory)
        kw.update(overrides)
        server = cls(**kw)
        now = time.perf_counter()
        server._tick = meta["tick"]
        server._next_rid = meta["next_rid"]
        server._degraded = dict(meta["degraded"])
        server._retries = meta["retries"]
        server._recoveries = meta["recoveries"] + 1  # this restore is one
        server._snapshots = meta["snapshots"]
        for wl, lm in meta["lanes"].items():
            lane = server._lane(wl, batch=lm["batch"],
                                scan_steps=lm["scan_steps"])
            if lm["backend"] != lane.backend:
                lane.set_backend(lm["backend"])
            prefix = f"param:{wl}:"
            leaves = [jnp.asarray(arrays[k])
                      for k in sorted(k for k in arrays
                                      if k.startswith(prefix))]
            _, treedef = jax.tree_util.tree_flatten(lane.params)
            params = jax.tree_util.tree_unflatten(treedef, leaves)
            lane.params = params if server.mesh is None else jax.device_put(
                params, shd.replicated(server.mesh))
            sp = f"lane:{wl}:"
            lane.load_state({k[len(sp):]: v for k, v in arrays.items()
                             if k.startswith(sp)})
            lane.device_steps = lm["device_steps"]
            lane.substeps = lm["substeps"]
            for i, sm in enumerate(lm["slots"]):
                if sm is None:
                    continue
                req = cls._req_from_meta(sm, now)
                lane.slots[i] = req
                lane.active[i] = True
                if lane.kind == "diffusion":
                    lane._traj[i] = ddim_timesteps(req.steps)
                server._requests[req.rid] = req
            if lane.kind == "diffusion":
                lane._pos = list(lm["pos"])
            server._idle_ticks[wl] = lm["idle_ticks"]
        for m in meta["pending"]:
            req = cls._req_from_meta(m, now)
            server._pending.append(req)
            server._requests[req.rid] = req
        for m in meta["done"]:
            req = cls._req_from_meta(m, now)
            req.result = arrays[f"done:{req.rid:08d}"]
            server._done[req.rid] = req
            server._requests[req.rid] = req
        for m in meta["dropped"]:
            server._requests[m["rid"]] = cls._req_from_meta(m, now)
        return server

    # ------------------------------------------------------------- metrics --
    @property
    def completed(self) -> dict[int, GenRequest]:
        return dict(self._done)

    def request(self, rid: int) -> GenRequest:
        """Any submitted request by id (whatever its lifecycle state)."""
        return self._requests[rid]

    def stats(self) -> dict[str, float]:
        wall = (time.perf_counter() - self._t0) if self._t0 else 0.0
        dev_steps = sum(l.device_steps for l in self._lanes.values())
        substeps = sum(l.substeps for l in self._lanes.values())
        n = len(self._done)
        waits = [r.wait_ticks for r in self._done.values()]
        lats = sorted(r.latency_s for r in self._done.values())
        statuses = [r.status for r in self._requests.values()]
        # warm-steady window: ticks in which no lane compiled a new batch
        # shape — first-tick (and resize-tick) jit compiles are excluded the
        # same way ``kernels.util.time_call`` excludes compile from every
        # other timed region in the repo
        warm = [t for t in self._tick_log if not t[4]]
        warm_wall = sum(t[0] for t in warm)
        warm_imgs = sum(t[2] for t in warm)
        warm_sub = sum(t[3] for t in warm)
        pct = (lambda p: cm.np_percentile(lats, p)) if lats else (lambda p: 0.0)
        return {
            "requests": n,
            "ticks": self._tick,
            "device_steps": dev_steps,
            "substeps": substeps,
            "wall_s": wall,
            # whole-window throughput (includes first-tick compile — kept
            # for trajectory continuity with pre-fix revisions)
            "images_per_s": n / wall if wall else 0.0,
            "steps_per_s": dev_steps / wall if wall else 0.0,
            # warm-steady throughput: compile ticks excluded
            "warm_wall_s": warm_wall,
            "warm_images_per_s": warm_imgs / warm_wall if warm_wall else 0.0,
            "warm_steps_per_s": warm_sub / warm_wall if warm_wall else 0.0,
            "latency_p50_s": pct(50.0),
            "latency_p99_s": pct(99.0),
            "mean_wait_ticks": float(np.mean(waits)) if waits else 0.0,
            "max_wait_ticks": float(np.max(waits)) if waits else 0.0,
            "cancelled": float(statuses.count("cancelled")),
            "timeout": float(statuses.count("timeout")),
            "shed": float(statuses.count("shed")),
            # fault-tolerance counters (DESIGN.md §11)
            "degraded": float(len(self._degraded)),
            "retries": float(self._retries),
            "recoveries": float(self._recoveries),
            "corrupt": float(statuses.count("corrupt")),
            "snapshots": float(self._snapshots),
        }


def reference_sample(params: dict, *, steps: int, seed: int, image_size: int,
                     out_ch: int = 3, backend: str = "xla",
                     interpret: bool | None = None, decomposed: bool = True,
                     t_max: int = DDIM_T_MAX) -> np.ndarray:
    """Unbatched single-request DDIM loop — the parity oracle the served
    (mixed-timestep, continuously batched, K-step fused) path must match
    bitwise on xla / <= 1e-5 across backends.  Deliberately K=1: the fused
    scan must reproduce the one-step-at-a-time trajectory exactly."""
    step = jax.jit(make_gen_scan_step(1, t_max=t_max, decomposed=decomposed,
                                      backend=backend, interpret=interpret),
                   donate_argnums=(1,))
    traj = ddim_timesteps(steps, t_max)
    x = init_noise(seed, (image_size, image_size, out_ch))[None]
    for i, t in enumerate(traj):
        nxt = int(traj[i + 1]) if i + 1 < len(traj) else -1
        batch = {"t": jnp.full((1, 1), int(t), jnp.int32),
                 "t_next": jnp.full((1, 1), nxt, jnp.int32),
                 "active": jnp.ones((1, 1), bool)}
        x = step(params, x, batch)
    return np.asarray(x)[0]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", default="unet_dec",
                    choices=sorted(GEN_WORKLOADS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--steps", default="8,5,3",
                    help="comma list of diffusion step budgets, cycled")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--backend", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scan-steps", default="auto",
                    help="DDIM steps fused per dispatch (int or 'auto': "
                         "sized against tick latency from the calibration)")
    ap.add_argument("--slo", default="standard", choices=sorted(SLO_CLASSES),
                    help="SLO class stamped on every submitted request")
    ap.add_argument("--timeout-ticks", type=int, default=None,
                    help="per-request scheduler-tick timeout")
    ap.add_argument("--autoscale", action="store_true",
                    help="grow/shrink lane batches with backlog")
    ap.add_argument("--devices", type=int, default=1,
                    help="span the lanes over a mesh of this many devices "
                         "(DESIGN.md §13; simulate on CPU with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    ap.add_argument("--spatial", action="store_true",
                    help="also shard image rows over the mesh's model axis")
    ap.add_argument("--snapshot-dir", default=None,
                    help="checkpoint scheduler state here (DESIGN.md §11); "
                         "with an existing committed snapshot the server "
                         "restores and resumes the drain")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="auto-snapshot every N ticks (0: on demand only)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny widths (CI): 16x16 images, small DCGAN")
    ns = ap.parse_args()

    from repro.core import calibrate as cal

    scan: int | str = ns.scan_steps if ns.scan_steps == "auto" \
        else int(ns.scan_steps)
    kw: dict = dict(batch=ns.batch, backend=ns.backend, scan_steps=scan,
                    autoscale=ns.autoscale,
                    snapshot_dir=ns.snapshot_dir,
                    snapshot_every=ns.snapshot_every)
    if ns.devices > 1:
        from repro.launch.mesh import make_smoke_mesh

        if ns.devices > len(jax.devices()):
            raise SystemExit(
                f"--devices {ns.devices} but only {len(jax.devices())} "
                f"devices exist (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count=N to simulate)")
        kw.update(mesh=make_smoke_mesh(ns.devices), spatial=ns.spatial)
    if ns.smoke or (ns.backend == "pallas" and jax.default_backend() == "cpu"):
        # interpret-mode pallas needs tiny widths to stay tractable on CPU
        kw.update(unet_widths=(8, 8), unet_hw=4, dcgan_nz=16, dcgan_ngf=4)
    cache = cal.default_cache_path()
    if cache.exists():          # host-grounded admission estimates when a
        kw["calibration"] = cal.Calibration.load(cache)  # table was captured
    step_list = [int(s) for s in ns.steps.split(",")]
    if ns.snapshot_dir and ckpt.latest_step(ns.snapshot_dir) is not None:
        server = GenServer.restore(
            ns.snapshot_dir, snapshot_every=ns.snapshot_every,
            calibration=kw.get("calibration"))
        print(f"[serve_gen] restored tick {server._tick} from "
              f"{ns.snapshot_dir} — resuming drain")
    else:
        server = GenServer(**kw)
        for i in range(ns.requests):
            server.submit(ns.workload, steps=step_list[i % len(step_list)],
                          seed=ns.seed + i, slo=ns.slo,
                          timeout_ticks=ns.timeout_ticks)
    images = server.run()
    st = server.stats()
    lane = server._lanes.get(ns.workload)
    print(f"[serve_gen] {st['requests']} requests "
          f"({ns.workload}, steps {ns.steps}, slo={ns.slo}, "
          f"scan_steps={getattr(lane, 'scan_steps', 1)}) in "
          f"{st['wall_s']:.2f}s over {st['ticks']} ticks / "
          f"{st['device_steps']} dispatches ({st['substeps']} substeps): "
          f"{st['images_per_s']:.2f} img/s "
          f"(warm {st['warm_images_per_s']:.2f}), "
          f"p50 {st['latency_p50_s'] * 1e3:.0f} ms / "
          f"p99 {st['latency_p99_s'] * 1e3:.0f} ms")
    if st["degraded"] or st["retries"] or st["recoveries"] or st["snapshots"]:
        print(f"[serve_gen] fault plane: {st['degraded']:.0f} degraded "
              f"lane(s), {st['retries']:.0f} retries, "
              f"{st['recoveries']:.0f} recoveries, "
              f"{st['snapshots']:.0f} snapshots")
    dropped = int(st["cancelled"] + st["timeout"] + st["shed"] +
                  st["corrupt"])
    if dropped:
        print(f"[serve_gen] dropped {dropped} request(s): "
              f"{st['cancelled']:.0f} cancelled, {st['timeout']:.0f} "
              f"timed out, {st['shed']:.0f} shed at admission")
    if images:
        shp = next(iter(images.values())).shape
        print(f"[serve_gen] image shape {shp}; "
              f"mean wait {st['mean_wait_ticks']:.1f} ticks "
              f"(max {st['max_wait_ticks']:.0f})")
    rep = cm.serve_report(GEN_WORKLOADS[ns.workload](),
                          steps=max(step_list),
                          scan_steps=getattr(lane, "scan_steps", 1),
                          steps_list=[step_list[i % len(step_list)]
                                      for i in range(ns.requests)],
                          calibration=server.calibration,
                          backend=ns.backend, devices=max(ns.devices, 1))
    print(f"[serve_gen] cycle model ({ns.workload}, canonical widths, "
          f"{max(step_list)} steps/sample, "
          f"{rep['dispatches_per_image']:.0f} dispatches/image): "
          f"{rep['images_per_s_ours']:.1f} img/s decomposed vs "
          f"{rep['images_per_s_naive']:.1f} naive "
          f"({rep['serve_speedup_vs_naive']:.2f}x); modeled drain "
          f"p50 {rep['latency_p50_ms']:.1f} ms / "
          f"p99 {rep['latency_p99_ms']:.1f} ms")
    if "calibrated_us_per_image" in rep:
        print(f"[serve_gen] calibrated host estimate: "
              f"{rep['calibrated_us_per_image']:.0f} us/image "
              f"({rep['calibrated_images_per_s']:.2f} img/s on this host)")


if __name__ == "__main__":
    main()
