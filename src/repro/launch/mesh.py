"""Mesh factories: one place every loop gets its device mesh from.

``make_production_mesh`` builds the 256-chip single-pod / 512-chip two-pod
meshes the dry-run and sharding rules target; ``make_smoke_mesh`` builds a
small ``(data, model)`` mesh over whatever devices exist — 1 CPU device in
the tests, 8 fake devices under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
— so the same loop code runs at every scale.  Both are FUNCTIONS: importing
this module never touches jax device state (the dry-run must set XLA_FLAGS
before the first jax init).

CPU-scale smoke (any launch loop picks the mesh up automatically):

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --reduced
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --reduced --steps 3 --batch 8 --seq 32
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(devices: int | None = None, model: int = 2):
    """Small mesh over however many (possibly fake) devices exist."""
    n = devices or len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_train_mesh(devices: int | None = None):
    """1-D ``(data,)`` mesh for the sharded conv train step (DESIGN.md §13).

    The sharded recipes chunk the batch over ``data`` only; a model axis
    would just replicate, so the whole device count goes to data.
    """
    n = devices or len(jax.devices())
    return jax.make_mesh((n,), ("data",))
