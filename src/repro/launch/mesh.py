"""Production mesh construction (a FUNCTION — importing never touches jax
device state; the dry-run sets XLA_FLAGS before first jax init)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(devices: int | None = None, model: int = 2):
    """Small mesh over however many (possibly fake) devices exist."""
    n = devices or len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
