"""Whisper audio frontend on the repo's conv engine (arXiv 2212.04356 §2).

The transformer stack of whisper-small is config-stubbed per the assignment
(``repro.configs.whisper_small`` — ``input_specs`` supplies precomputed
frame embeddings), but the real model's two-conv mel frontend is exactly the
kind of op this repo executes: two 1-D convolutions over time, expressed as
``(H=1)`` 2-D convolutions through :func:`repro.core.decompose.conv2d`:

    mel (B, T, n_mels)
      -> conv k=3 s=1 SAME -> gelu        (B, T,    d_model)
      -> conv k=3 s=2 SAME -> gelu        (B, T/2,  d_model)

Stride-2 output length follows the engine's SAME convention
(``ceil(T / 2)``), matching Whisper's ``Conv1d(..., stride=2, padding=1)``
for the canonical even ``T=3000``.  Parity against
``lax.conv_general_dilated`` is pinned in ``tests/test_whisper_frontend.py``;
``examples/whisper_frontend_demo.py`` drives it end to end (tier-1 CI runs
the ``--smoke`` variant).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.decompose import conv2d

#: canonical whisper-small frontend geometry (mel bins, frames, d_model)
N_MELS, N_FRAMES, D_MODEL = 80, 3000, 768


def init_frontend_params(key, n_mels: int = N_MELS, d_model: int = D_MODEL,
                         dtype=jnp.float32) -> dict:
    """Fan-in-normal weights for the two temporal convs (no biases — the
    stub pipeline folds them into the downstream embedding layernorm)."""
    k1, k2 = jax.random.split(key)
    return {
        "conv1": (jax.random.normal(k1, (1, 3, n_mels, d_model), jnp.float32)
                  * (2.0 / (3 * n_mels)) ** 0.5).astype(dtype),
        "conv2": (jax.random.normal(k2, (1, 3, d_model, d_model), jnp.float32)
                  * (2.0 / (3 * d_model)) ** 0.5).astype(dtype),
    }


@functools.partial(jax.jit, static_argnames=("backend", "interpret"))
def frontend(params: dict, mel: jax.Array, backend: str = "xla",
             interpret: bool | None = None) -> jax.Array:
    """mel (B, T, n_mels) -> frame embeddings (B, ceil(T/2), d_model).

    1-D convs ride the dense engine as ``(B, 1, T, C)`` with ``k=(1, 3)``
    — the H axis is a degenerate single row, so the row-tiled kernels see a
    1 x T image and the time axis lands on the lane dimension.
    """
    x = mel[:, None]                                 # (B, 1, T, n_mels)
    kw = dict(backend=backend, interpret=interpret)
    h = jax.nn.gelu(conv2d(x, params["conv1"], **kw))
    h = jax.nn.gelu(conv2d(h, params["conv2"], stride=2, **kw))
    return h[:, 0]                                   # (B, ceil(T/2), d_model)


def frontend_reference(params: dict, mel: jax.Array) -> jax.Array:
    """Same frontend straight through ``lax.conv_general_dilated`` — the
    parity oracle for :func:`frontend` (no repo engine code on this path).

    Padding is the explicit symmetric ``(1, 1)`` of Whisper's
    ``Conv1d(..., padding=1)`` — note lax's ``"SAME"`` *string* would pad
    ``(0, 1)`` at stride 2 (it balances low to hit ``ceil(T/s)`` exactly),
    which samples the other time phase; same shape, different values."""
    x = mel[:, None]
    pads = [(0, 0), (1, 1)]
    h = jax.lax.conv_general_dilated(
        x, params["conv1"], window_strides=(1, 1), padding=pads,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = jax.nn.gelu(h)
    h = jax.lax.conv_general_dilated(
        h, params["conv2"], window_strides=(1, 2), padding=pads,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.gelu(h)[:, 0]
