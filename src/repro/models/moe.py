"""Mixture-of-Experts FFN: top-k routing with grouped capacity dispatch.

The dispatch follows the production "dropped-token" einsum scheme (t5x /
MaxText style): tokens are processed in groups of ``group_size`` with a
per-group expert capacity ``C = ceil(group_size * top_k / E * cf)``; dispatch
and combine are one-hot einsums, so everything shards cleanly — experts over
the ``model`` ("expert") mesh axis, groups over ``data``.  Tokens exceeding
capacity are dropped (standard at cf=1.25; recorded in DESIGN.md).

An optional shared expert (Llama-4 style) runs densely alongside.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, lc


def moe_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    m = cfg.moe
    d, e, f = cfg.d_model, m.num_experts, m.d_ff_expert
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(kr, d, e, jnp.float32),
        "we_gate": (jax.random.normal(k1, (e, d, f), jnp.float32) * d ** -0.5
                   ).astype(dtype),
        "we_up": (jax.random.normal(k2, (e, d, f), jnp.float32) * d ** -0.5
                 ).astype(dtype),
        "we_down": (jax.random.normal(k3, (e, f, d), jnp.float32) * f ** -0.5
                   ).astype(dtype),
    }
    if m.shared_expert_ff:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(ks, d, m.shared_expert_ff, dtype)
    return p


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).

    Groups are formed WITHIN the sequence when S >= group_size so the
    (batch, group) dims keep their (data, seq/model) shardings — merging a
    batch-sharded dim with a sequence-sharded dim forces GSPMD to replicate
    (observed: a 20 GB f32 materialisation on the multi-pod prefill).
    Short-sequence calls (decode) group across the batch instead.
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    if s >= m.group_size and s % m.group_size == 0:
        g = m.group_size
        xt = x.reshape(b, s // g, g, d)
        xt = lc(xt, ("data", "seq", None, None))
        lead = (b, s // g)
    else:
        tokens = b * s
        g = min(m.group_size, tokens)
        assert tokens % g == 0, (tokens, g)
        xt = x.reshape(1, tokens // g, g, d)
        xt = lc(xt, (None, "data", None, None))
        lead = (1, tokens // g)
    cap = max(1, int(-(-g * k // e) * m.capacity_factor))

    logits = xt.astype(jnp.float32) @ p["router"]           # (B, G, g, E)
    gates, idx = jax.lax.top_k(logits, k)                   # (B, G, g, K)
    gates = jax.nn.softmax(gates, axis=-1)

    # position of each (token, slot) within its expert's capacity buffer
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)        # (B, G, g, K, E)
    flat = onehot.reshape(*lead, g * k, e)
    pos = jnp.cumsum(flat, axis=2) - 1                      # (B, G, g*K, E)
    pos = (pos * flat).sum(-1).reshape(*lead, g, k)
    expert_pos = pos
    keep = expert_pos < cap

    # dispatch tensor: (B, G, g, E, C) — contraction over the K slot axis
    # stays inside the einsum (no (g, K, E, C) outer product).
    oh_e = jax.nn.one_hot(idx, e, dtype=x.dtype)            # (B, G, g, K, E)
    oh_c = jax.nn.one_hot(jnp.where(keep, expert_pos, cap), cap + 1,
                          dtype=x.dtype)[..., :cap]         # (B, G, g, K, C)
    disp = jnp.einsum("bgtke,bgtkc->bgtec", oh_e, oh_c)

    xe = jnp.einsum("bgtec,bgtd->begcd", disp, xt)          # (B, E, G, C, D)
    xe = lc(xe, ("data", "expert", None, None, None))
    h = jax.nn.silu(jnp.einsum("begcd,edf->begcf", xe, p["we_gate"])) \
        * jnp.einsum("begcd,edf->begcf", xe, p["we_up"])
    ye = jnp.einsum("begcf,efd->begcd", h, p["we_down"])    # (B, E, G, C, D)
    ye = lc(ye, ("data", "expert", None, None, None))

    # combine: weight each dispatched copy by its (kept) gate
    gated = jnp.einsum("bgtke,bgtkc->bgtec", oh_e * (gates * keep
                       ).astype(x.dtype)[..., None], oh_c)
    out = jnp.einsum("bgtec,begcd->bgtd", gated, ye)        # (B, G, g, D)

    if "shared" in p:
        from repro.models.layers import mlp
        out = out + mlp(p["shared"], xt.reshape(lead[0], lead[1] * g, d)
                        ).reshape(*lead, g, d)
    return out.reshape(b, s, d)


def aux_load_balance_loss(logits: jax.Array, idx: jax.Array, e: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    probs = jax.nn.softmax(logits, axis=-1)                 # (G, g, E)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return e * jnp.sum(frac_tokens * frac_probs)
