"""ENet segmentation network in JAX, built on the paper's decomposition.

Every dilated convolution runs through ``core.dilated`` (input decomposition)
and every transposed convolution through ``core.transposed`` (weight
decomposition) — the technique is the execution engine, not a demo.  Layer
inventory matches ``core.enet_spec`` (the cycle-model workload table).

Every BN/PReLU/residual that used to follow a convolution as separate
elementwise HBM passes is emitted as a *fused epilogue spec* instead
(DESIGN.md §7): BN is carried in folded ``scale``/``shift`` form
(``common.fold_bn``), PReLU and the bottleneck residual add ride the same
kernel output pass.  The 5x1/1x5 asymmetric pair runs through the engine's
rectangular-kernel dense path (no more silent lax fallback under
``backend='pallas'``).

This is the paper's own workload: ``examples/train_enet.py`` trains it end to
end on synthetic Cityscapes-like data.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.decompose import conv2d
from repro.kernels.epilogue import EpilogueSpec
from repro.models.common import bn_init as _bn_init
from repro.models.common import conv_init, fold_bn

# the two epilogue shapes ENet uses: BN+PReLU after reduce/mid convs, and
# BN + residual-add + PReLU closing every bottleneck
_EP_BN_ACT = EpilogueSpec(bn=True, prelu=True)
_EP_BN_RES_ACT = EpilogueSpec(bn=True, prelu=True, residual="pre_act")


def _conv_init(key, k: int, cin: int, cout: int, dtype=jnp.float32):
    return conv_init(key, k, k, cin, cout, dtype)


def _bottleneck_init(key, c: int, kind: str = "regular", cin: int | None = None,
                     asym: int = 5, dtype=jnp.float32) -> dict:
    cin = c if cin is None else cin
    ci = max(c // 4, 1)
    ks = jax.random.split(key, 6)
    p = {"a1": jnp.full((1,), 0.25, dtype), "a2": jnp.full((1,), 0.25, dtype),
         "a3": jnp.full((1,), 0.25, dtype),
         "bn1": _bn_init(ci, dtype), "bn2": _bn_init(ci, dtype),
         "bn3": _bn_init(c, dtype)}
    # folded BN does not re-normalise per batch, so the residual cascade
    # would double activation variance per bottleneck; zero-init the closing
    # scale (ResNet "zero-init residual") so each block starts as identity
    p["bn3"]["g"] = jnp.zeros((c,), dtype)
    if kind == "down":
        p["reduce"] = _conv_init(ks[0], 2, cin, ci, dtype)
        p["conv"] = _conv_init(ks[1], 3, ci, ci, dtype)
    elif kind == "up":
        p["reduce"] = _conv_init(ks[0], 1, cin, ci, dtype)
        p["deconv"] = _conv_init(ks[1], 3, ci, ci, dtype)
        p["skip"] = _conv_init(ks[3], 1, cin, c, dtype)
    elif kind == "asym":
        p["reduce"] = _conv_init(ks[0], 1, cin, ci, dtype)
        p["conv_v"] = (jax.random.normal(ks[1], (asym, 1, ci, ci), jnp.float32)
                       * (2.0 / (asym * ci)) ** 0.5).astype(dtype)
        p["conv_h"] = (jax.random.normal(ks[4], (1, asym, ci, ci), jnp.float32)
                       * (2.0 / (asym * ci)) ** 0.5).astype(dtype)
    else:  # regular / dilated
        p["reduce"] = _conv_init(ks[0], 1, cin, ci, dtype)
        p["conv"] = _conv_init(ks[1], 3, ci, ci, dtype)
    p["expand"] = _conv_init(ks[2], 1, ci, c, dtype)
    return p


def _bottleneck(p: dict, x: jax.Array, kind: str, c: int, dilation: int = 1,
                decomposed: bool = True, strategy: str = "batched",
                backend: str = "xla", compute_dtype=None) -> jax.Array:
    """kind: regular | dilated | asym | down | up."""
    cd = compute_dtype
    s1, b1 = fold_bn(p["bn1"])
    ep1 = dict(epilogue=_EP_BN_ACT, scale=s1, shift=b1, alpha=p["a1"])
    if kind == "down":
        h = conv2d(x, p["reduce"], stride=2, padding=0, backend=backend,
                   compute_dtype=cd, **ep1)
        skip = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                     (1, 2, 2, 1), "VALID")
        pad_c = c - x.shape[-1]
        skip = jnp.pad(skip, ((0, 0), (0, 0), (0, 0), (0, pad_c)))
    elif kind == "up":
        h = conv2d(x, p["reduce"], backend=backend, compute_dtype=cd, **ep1)
        skip = conv2d(x, p["skip"], backend=backend, compute_dtype=cd)
        # nearest-neighbour unpool stand-in for max-unpool indices
        skip = jnp.repeat(jnp.repeat(skip, 2, axis=1), 2, axis=2)
    else:
        h = conv2d(x, p["reduce"], backend=backend, compute_dtype=cd, **ep1)
        skip = x

    s2, b2 = fold_bn(p["bn2"])
    ep2 = dict(epilogue=_EP_BN_ACT, scale=s2, shift=b2, alpha=p["a2"])
    if kind == "asym":
        # 5x1/1x5 pair: rectangular kernels through the engine's dense path
        # (SAME pads one dim only); BN2/PReLU fuse into the second conv
        h = conv2d(h, p["conv_v"], backend=backend, compute_dtype=cd)
        h = conv2d(h, p["conv_h"], backend=backend, compute_dtype=cd, **ep2)
    elif kind == "up":
        h = conv2d(h, p["deconv"], stride=2, transposed=True,
                   output_padding=1, decomposed=decomposed, backend=backend,
                   compute_dtype=cd, **ep2)
    elif kind == "dilated":
        h = conv2d(h, p["conv"], dilation=dilation, decomposed=decomposed,
                   strategy=strategy, backend=backend, compute_dtype=cd,
                   **ep2)
    else:
        h = conv2d(h, p["conv"], backend=backend, compute_dtype=cd, **ep2)

    # expand projection closes the bottleneck: BN3, +skip, PReLU — one pass
    s3, b3 = fold_bn(p["bn3"])
    return conv2d(h, p["expand"], backend=backend, epilogue=_EP_BN_RES_ACT,
                  scale=s3, shift=b3, alpha=p["a3"], residual=skip,
                  compute_dtype=cd)


# stage layout: (name, kind, channels, dilation)
_STAGE2 = [("reg", 1), ("dil", 2), ("asym", 1), ("dil", 4),
           ("reg", 1), ("dil", 8), ("asym", 1), ("dil", 16)]


def init_params(key, num_classes: int = 19, dtype=jnp.float32) -> dict:
    ks = iter(jax.random.split(key, 64))
    p = {"initial": _conv_init(next(ks), 3, 3, 13, dtype)}
    p["b1_0"] = _bottleneck_init(next(ks), 64, "down", cin=16, dtype=dtype)
    for i in range(1, 5):
        p[f"b1_{i}"] = _bottleneck_init(next(ks), 64, dtype=dtype)
    p["b2_0"] = _bottleneck_init(next(ks), 128, "down", cin=64, dtype=dtype)
    for stage in (2, 3):
        for i, (kind, _) in enumerate(_STAGE2, start=1):
            p[f"b{stage}_{i}"] = _bottleneck_init(
                next(ks), 128, "asym" if kind == "asym" else "regular",
                dtype=dtype)
    p["b4_0"] = _bottleneck_init(next(ks), 64, "up", cin=128, dtype=dtype)
    for i in range(1, 3):
        p[f"b4_{i}"] = _bottleneck_init(next(ks), 64, dtype=dtype)
    p["b5_0"] = _bottleneck_init(next(ks), 16, "up", cin=64, dtype=dtype)
    p["b5_1"] = _bottleneck_init(next(ks), 16, dtype=dtype)
    p["fullconv"] = _conv_init(next(ks), 3, 16, num_classes, dtype)
    return p


@functools.partial(jax.jit,
                   static_argnames=("decomposed", "strategy", "backend",
                                    "compute_dtype"))
def forward(params: dict, x: jax.Array, decomposed: bool = True,
            strategy: str = "batched", backend: str = "xla",
            compute_dtype: str | None = None) -> jax.Array:
    """x: (N, H, W, 3) -> logits (N, H, W, classes).

    ``backend='pallas'`` executes every conv through the fused Pallas engine
    (:mod:`repro.kernels`) instead of composed XLA convs — including the 1x1
    reduce/expand projections, the stem/head, and the rectangular 5x1/1x5
    asymmetric pair — so a pallas forward is all-pallas, with BN/PReLU/
    residual epilogues fused into the kernels (DESIGN.md §7).  The whole
    forward is differentiable on both backends (DESIGN.md §6).

    ``compute_dtype`` (e.g. ``"bf16"``; static — pass the string form) casts
    the input once and every conv per-layer, so activations flow in the
    compute dtype end to end while params stay fp32 masters and the kernels
    accumulate in fp32 (DESIGN.md §12); the logits come back in it.
    """
    cd = compute_dtype
    if cd is not None:
        from repro.kernels.util import canon_dtype

        x = x.astype(canon_dtype(cd))
    h = conv2d(x, params["initial"], stride=2, backend=backend,
               compute_dtype=cd)
    pool = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")
    h = jnp.concatenate([h, pool], axis=-1)          # (N, H/2, W/2, 16)

    h = _bottleneck(params["b1_0"], h, "down", 64, backend=backend,
                    compute_dtype=cd)
    for i in range(1, 5):
        h = _bottleneck(params[f"b1_{i}"], h, "regular", 64, backend=backend,
                        compute_dtype=cd)
    h = _bottleneck(params["b2_0"], h, "down", 128, backend=backend,
                    compute_dtype=cd)
    for stage in (2, 3):
        for i, (kind, d) in enumerate(_STAGE2, start=1):
            k = {"reg": "regular", "dil": "dilated", "asym": "asym"}[kind]
            h = _bottleneck(params[f"b{stage}_{i}"], h, k, 128, dilation=d,
                            decomposed=decomposed, strategy=strategy,
                            backend=backend, compute_dtype=cd)
    h = _bottleneck(params["b4_0"], h, "up", 64, decomposed=decomposed,
                    backend=backend, compute_dtype=cd)
    for i in range(1, 3):
        h = _bottleneck(params[f"b4_{i}"], h, "regular", 64, backend=backend,
                        compute_dtype=cd)
    h = _bottleneck(params["b5_0"], h, "up", 16, decomposed=decomposed,
                    backend=backend, compute_dtype=cd)
    h = _bottleneck(params["b5_1"], h, "regular", 16, backend=backend,
                    compute_dtype=cd)
    return conv2d(h, params["fullconv"], stride=2, transposed=True,
                  output_padding=1, decomposed=decomposed, backend=backend,
                  compute_dtype=cd)
