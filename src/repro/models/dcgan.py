"""DCGAN-style generator built on the paper's weight decomposition.

The generator (Radford et al. 2016) is the canonical transposed-conv-heavy
workload: a latent projection to ``4x4 x C`` followed by a chain of ``k=4,
s=2`` transposed convolutions that double resolution and halve channels each
stage, closed by a tanh head — >99% of its MACs are transposed convolution,
against ENet's ~7% decoder tail.  Every upsampling stage runs through the
weight decomposition (:mod:`repro.core.transposed` on xla, the fused parity
kernel of :mod:`repro.kernels.transposed_conv` on pallas), so this model is
the stress workload for the even-kernel (k=4) parity schedules and the
``p_lo=2`` (non-default) padding geometry — the PyTorch
``ConvTranspose2d(4, stride=2, padding=1)`` exact-2x form.

BN/ReLU after each stage is emitted as a fused epilogue spec (DESIGN.md §7):
BN in folded scale/shift form (``common.fold_bn``), ReLU as PReLU with a
fixed zero slope.  The projection is a dense matmul (not a conv) so its
BN/ReLU runs as the same epilogue oracle in one pass.

Layer inventory matches :func:`repro.core.gen_spec.dcgan_layers` (the
cycle-model workload table).  Differentiable on both backends via the
engine's custom VJPs (DESIGN.md §6); see ``examples/generate_dcgan.py``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.decompose import conv2d
from repro.kernels.epilogue import EpilogueSpec, apply_reference
from repro.models.common import bn_init as _bn_init
from repro.models.common import fold_bn as _fold_bn
from repro.models.common import tconv_init as _tconv_init

_EP_BN_ACT = EpilogueSpec(bn=True, prelu=True)
#: PReLU slope 0 == ReLU (the DCGAN generator's activation); a traced
#: constant, not a parameter — the slope is not learnable.
_RELU_SLOPE = (0.0,)


def n_stages(size: int) -> int:
    """Number of stride-2 stages (incl. head) from 4x4 to ``size``."""
    if size not in (64, 128):
        raise ValueError(f"DCGAN generator sizes are 64/128, got {size}")
    return int(math.log2(size // 4))


def init_params(key, size: int = 64, nz: int = 100, ngf: int = 64,
                out_ch: int = 3, dtype=jnp.float32) -> dict:
    """Generator parameters for a ``size x size`` output (64 or 128).

    ``ngf`` scales every width (the canonical net is ngf=64: 512 channels at
    4x4 for the 64x64 generator, 1024 for 128x128); tests shrink it.
    """
    n_up = n_stages(size)
    c = ngf * (size // 8)
    ks = jax.random.split(key, n_up + 1)
    p = {
        # fan-in-normal projection: z (nz) -> 4*4*c, reshaped to (4, 4, c)
        "proj": (jax.random.normal(ks[0], (nz, 4 * 4 * c), jnp.float32)
                 * (2.0 / nz) ** 0.5).astype(dtype),
        "proj_bn": _bn_init(c, dtype),
    }
    for i in range(1, n_up):
        p[f"up{i}"] = _tconv_init(ks[i], 4, 4, c, c // 2, stride=2,
                                  dtype=dtype)
        p[f"bn{i}"] = _bn_init(c // 2, dtype)
        c //= 2
    p["head"] = _tconv_init(ks[n_up], 4, 4, c, out_ch, stride=2, dtype=dtype)
    return p


@functools.partial(jax.jit,
                   static_argnames=("decomposed", "backend", "interpret",
                                    "compute_dtype"))
def forward(params: dict, z: jax.Array, decomposed: bool = True,
            backend: str = "xla", interpret: bool | None = None,
            compute_dtype: str | None = None) -> jax.Array:
    """z: (N, nz) latents -> (N, size, size, out_ch) images in (-1, 1).

    Every stage is ``k=4, s=2, p_lo=2, output_padding=0`` (exact 2x); the
    BN/ReLU epilogue is fused into the transposed kernel's output pass.
    ``decomposed=False`` is the measured zero-laden baseline (xla only).

    ``compute_dtype`` (static, e.g. ``"bf16"``): the latent projection and
    every transposed stage run in the compute dtype while params stay fp32
    masters (DESIGN.md §12); the tanh image comes back in it.
    """
    cd = compute_dtype
    n_up = 1 + sum(1 for k in params if k.startswith("up"))
    alpha = jnp.asarray(_RELU_SLOPE, jnp.float32)
    if cd is not None:
        from repro.kernels.util import canon_dtype

        z = z.astype(canon_dtype(cd))
    # latent projection: a matmul, recorded as the 1x1-conv-equivalent
    # workload in gen_spec; its BN/ReLU runs as the epilogue oracle.  The
    # matmul casts the fp32 master to z.dtype so bf16 z is not promoted.
    h = (z @ params["proj"].astype(z.dtype)).reshape(z.shape[0], 4, 4, -1)
    sc, sh = _fold_bn(params["proj_bn"])
    h = apply_reference(_EP_BN_ACT, h, (sc, sh, alpha))
    kw = dict(stride=2, transposed=True, padding=2, output_padding=0,
              decomposed=decomposed, backend=backend, interpret=interpret,
              compute_dtype=cd)
    for i in range(1, n_up):
        sc, sh = _fold_bn(params[f"bn{i}"])
        h = conv2d(h, params[f"up{i}"], epilogue=_EP_BN_ACT, scale=sc,
                   shift=sh, alpha=alpha, **kw)
    return jnp.tanh(conv2d(h, params["head"], **kw))
