"""Model configuration dataclasses shared by every architecture."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert_ff: int = 0      # 0 -> no shared expert
    every_n_layers: int = 1        # MoE FFN every n-th layer (1 = all)
    group_size: int = 512          # dispatch group size (tokens)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    m_proj_factor: float = 2.0     # mLSTM up-projection
    s_ff_factor: float = 1.3334    # sLSTM feed-forward
    conv_kernel: int = 4


@dataclass(frozen=True)
class ModelConfig:
    """One architecture. ``block_pattern`` x ``repeat`` defines the stack.

    ``block_pattern`` entries: 'attn' | 'attn_local' | 'mamba' | 'mlstm' |
    'slstm'.  The stack scans over ``repeat`` copies of the pattern
    (homogeneous superblocks -> compact HLO).  FFN kind per layer is derived
    from ``moe.every_n_layers`` (dense FFN otherwise, none if d_ff == 0).
    """
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    block_pattern: tuple[str, ...] = ("attn",)
    qk_norm: bool = False
    rope: bool = True              # False -> NoPE (Jamba)
    rope_theta: float = 10000.0
    window: int = 0                # sliding-window size for 'attn_local'
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    xlstm: XLSTMConfig | None = None
    encoder_layers: int = 0        # >0 -> encoder-decoder (whisper)
    encoder_ctx: int = 1500        # stub frontend frames
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    opt_memory_mode: str = "fp32"  # "bf16": no fp32 master, bf16 moments
    remat_policy: str = "nothing"  # "nothing" | "dots" (save matmul outputs)
    # which shape cells are runnable (see DESIGN.md §4)
    supports_long_context: bool = False
    decode_supported: bool = True
    remat: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_layers % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers {self.num_layers} not a multiple of "
                f"pattern {len(self.block_pattern)}")

    @property
    def repeat(self) -> int:
        return self.num_layers // len(self.block_pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (drives roofline MODEL_FLOPS = 6*N*D) ----
    def param_counts(self) -> dict[str, float]:
        d, hd = self.d_model, self.head_dim
        q_dim, kv_dim = self.num_heads * hd, self.kv_heads * hd
        attn = d * q_dim + 2 * d * kv_dim + q_dim * d
        mamba = 0.0
        if self.mamba is not None:
            di = self.mamba.expand * d
            dtr = self.mamba.dt_rank or -(-d // 16)
            mamba = (d * 2 * di + di * self.mamba.d_conv
                     + di * (dtr + 2 * self.mamba.d_state) + dtr * di
                     + di * self.mamba.d_state + di + di * d)
        mlstm = slstm = 0.0
        if self.xlstm is not None:
            di = int(self.xlstm.m_proj_factor * d)
            mlstm = d * 2 * di + di * self.xlstm.conv_kernel + 3 * di * di // 4 \
                + di * d  # qkv heads projections approximated at hd blocks
            dff = int(self.xlstm.s_ff_factor * d)
            slstm = 4 * d * d + 2 * d * dff
        dense_ffn = 3 * d * self.d_ff if self.d_ff else 0

        n_att = sum(p.startswith("attn") for p in self.block_pattern) * self.repeat
        n_mam = sum(p == "mamba" for p in self.block_pattern) * self.repeat
        n_ml = sum(p == "mlstm" for p in self.block_pattern) * self.repeat
        n_sl = sum(p == "slstm" for p in self.block_pattern) * self.repeat

        total_attn = n_att * attn + n_mam * mamba + n_ml * mlstm + n_sl * slstm
        active_ffn = total_ffn = 0.0
        if self.moe is not None:
            n_moe = self.num_layers // self.moe.every_n_layers
            n_dense = self.num_layers - n_moe
            e_ffn = 3 * d * self.moe.d_ff_expert
            shared = 3 * d * self.moe.shared_expert_ff if self.moe.shared_expert_ff else 0
            total_ffn = (n_moe * (self.moe.num_experts * e_ffn + shared)
                         + n_dense * dense_ffn)
            active_ffn = (n_moe * (self.moe.top_k * e_ffn + shared)
                          + n_dense * dense_ffn)
        else:
            total_ffn = active_ffn = self.num_layers * dense_ffn

        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        enc = self.encoder_layers * (attn + dense_ffn) if self.encoder_layers else 0
        # decoder cross-attention adds one attn-sized block per layer
        cross = self.num_layers * attn if self.encoder_layers else 0
        total = total_attn + total_ffn + embed + enc + cross
        active = total_attn + active_ffn + embed + enc + cross
        return {"total": total, "active": active}
