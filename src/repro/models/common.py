"""Shared building blocks for the segmentation model zoo (ENet, ESPNet).

Batch norm uses batch statistics (training form, as in the ENet paper);
PReLU carries a single learnable slope per layer.  Kept in one place so a
change (e.g. the planned fused BN/PReLU epilogues, ROADMAP) hits every
model at once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv_init(key, kh: int, kw: int, cin: int, cout: int, dtype=jnp.float32):
    """He-normal HWIO kernel init."""
    fan_in = kh * kw * cin
    return (jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
            * (2.0 / fan_in) ** 0.5).astype(dtype)


def prelu(a, x):
    return jnp.where(x >= 0, x, a * x)


def bn_init(c: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((c,), dtype), "b": jnp.zeros((c,), dtype)}


def bn(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Batch norm with batch statistics (training form)."""
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


__all__ = ["conv_init", "prelu", "bn_init", "bn"]
