"""Shared building blocks for the segmentation model zoo (ENet, ESPNet).

BN in the model zoo is carried in *folded* form (DESIGN.md §7): the
parameters fold — optionally together with fixed statistics — into a single
per-channel ``scale``/``shift`` multiply-add (:func:`fold_bn`), which is
what the fused conv epilogues consume.  Batch-statistics normalisation
(:func:`bn`, the ENet paper's training form) is kept as a reference op, but
it cannot be fused into a single output pass — its statistics are a function
of the very output being produced — so the models emit epilogue specs
instead of calling it post-hoc.

PReLU carries a single learnable slope per layer.  Kept in one place so a
change hits every model at once.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def conv_init(key, kh: int, kw: int, cin: int, cout: int, dtype=jnp.float32):
    """He-normal HWIO kernel init."""
    fan_in = kh * kw * cin
    return (jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
            * (2.0 / fan_in) ** 0.5).astype(dtype)


def tconv_init(key, kh: int, kw: int, cin: int, cout: int, stride: int = 2,
               dtype=jnp.float32):
    """He-normal init for a transposed conv's HWIO kernel.

    A stride-``s`` transposed conv spreads its ``k*k`` taps over ``s*s``
    output parities, so each output pixel accumulates only ``~k*k/s**2``
    taps — that is the effective fan-in (exactly the parity sub-kernel sizes
    of the weight decomposition, DESIGN.md §3).  Using the dense-conv fan-in
    would shrink activations by ``s`` per upsampling stage, which a deep
    generator chain (DCGAN stacks 4-5 of them) turns into vanishing scale.
    """
    fan_in = max(kh * kw * cin // (stride * stride), 1)
    return (jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
            * (2.0 / fan_in) ** 0.5).astype(dtype)


def prelu(a, x):
    return jnp.where(x >= 0, x, a * x)


def bn_init(c: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((c,), dtype), "b": jnp.zeros((c,), dtype)}


def bn(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Batch norm with batch statistics (training form; reference only)."""
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def fold_bn(p: dict, mu: jax.Array | None = None,
            var: jax.Array | None = None,
            eps: float = 1e-5) -> tuple[jax.Array, jax.Array]:
    """Fold BN params (+ optional fixed statistics) to ``(scale, shift)``.

    ``y = x * scale + shift`` — the single multiply-add the fused conv
    epilogues consume (DESIGN.md §7).  With ``mu``/``var`` given (running
    statistics at inference) the fold is the classic
    ``scale = g / sqrt(var + eps)``, ``shift = b - mu * scale``; without
    them the fold is the pure learnable affine (identity statistics), which
    is how the model zoo trains.
    """
    g, b = p["g"], p["b"]
    if mu is None:
        return g, b
    scale = g * jax.lax.rsqrt(var + eps)
    return scale, b - mu * scale


def gn_init(c: int, dtype=jnp.float32) -> dict:
    """GroupNorm parameters: per-channel affine (diffusion U-Net blocks)."""
    return {"g": jnp.ones((c,), dtype), "b": jnp.zeros((c,), dtype)}


def group_norm(p: dict, x: jax.Array, groups: int = 8,
               eps: float = 1e-5) -> jax.Array:
    """GroupNorm with live statistics (reference only, like :func:`bn`).

    Statistics are per-sample per-group — a function of the very activation
    being produced — so, exactly like batch-statistics BN, they cannot fuse
    into a single conv output pass.  The model zoo carries GroupNorm in
    *folded* form instead (:func:`fold_gn`); this op is the oracle the fold
    is tested against.
    """
    n, h, w, c = x.shape
    if c % groups:
        raise ValueError(f"channels {c} not divisible by groups {groups}")
    xg = x.reshape(n, h, w, groups, c // groups)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return xg.reshape(n, h, w, c) * p["g"] + p["b"]


def timestep_embedding(t: jax.Array, dim: int,
                       max_period: float = 10000.0) -> jax.Array:
    """Sinusoidal diffusion-timestep embedding (Ho et al. 2020 / transformer
    positional form).  ``t`` (B,) integer timesteps -> (B, dim) float32.

    The embedding is the only place the timestep enters the denoiser, and it
    enters as a *value*, never a shape: every sampling step runs the same
    convolution geometry, which is what lets the generative server batch
    requests sitting at different timesteps through one compiled step
    (DESIGN.md §9).
    """
    if dim % 2:
        raise ValueError(f"embedding dim must be even, got {dim}")
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def fold_gn(p: dict) -> tuple[jax.Array, jax.Array]:
    """Fold GroupNorm to the ``(scale, shift)`` the fused epilogues consume.

    Mirrors :func:`fold_bn` with identity statistics: the learnable affine
    ``y = x * g + b`` rides the conv kernel's BN epilogue slots (DESIGN.md
    §8).  Unlike BN there is no running-statistics variant to fold at
    inference — GroupNorm statistics are per-sample, so a live-stats fold
    would need a per-sample scale the (cout,)-vector epilogue cannot carry.
    """
    return p["g"], p["b"]


__all__ = ["conv_init", "tconv_init", "prelu", "bn_init", "bn", "fold_bn",
           "gn_init", "group_norm", "fold_gn", "timestep_embedding"]
