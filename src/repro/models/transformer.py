"""Decoder-only LM assembly: superblock scan over heterogeneous layer stacks.

The layer stack is ``repeat`` copies of ``cfg.block_pattern`` (e.g. Jamba's
``(mamba, mamba, mamba, attn, mamba, mamba, mamba, mamba)``); parameters are
stacked on a leading ``repeat`` axis per pattern position, and the stack runs
as ONE ``lax.scan`` over superblocks — compact HLO regardless of depth, which
keeps 512-device dry-run compiles fast and lets the XLA latency-hiding
scheduler pipeline per-layer collectives.

Each layer = sequence mixer (attn / mamba / mlstm / slstm) + FFN
(dense / MoE / none), both pre-norm residual.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xlstm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (dense_init, lc, mlp, mlp_init, rmsnorm,
                                 rmsnorm_init)


def remat_policy(cfg: ModelConfig):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _ffn_kind(cfg: ModelConfig, layer_idx: int) -> str:
    if cfg.moe is not None and (layer_idx + 1) % cfg.moe.every_n_layers == 0:
        return "moe"
    if cfg.d_ff > 0:
        return "dense"
    return "none"


def _mixer_init(key, cfg: ModelConfig, kind: str, dtype):
    if kind in ("attn", "attn_local"):
        return attn_mod.attn_init(key, cfg, dtype)
    if kind == "mamba":
        return mamba_mod.mamba_init(key, cfg, dtype)
    if kind == "mlstm":
        return xlstm_mod.mlstm_init(key, cfg, dtype)
    if kind == "slstm":
        return xlstm_mod.slstm_init(key, cfg, dtype)
    raise ValueError(kind)


def layer_init(key, cfg: ModelConfig, pattern_idx: int, layer_idx: int,
               dtype) -> dict:
    kind = cfg.block_pattern[pattern_idx]
    k1, k2 = jax.random.split(key)
    p = {
        "mixer": _mixer_init(k1, cfg, kind, dtype),
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
    }
    fk = _ffn_kind(cfg, layer_idx)
    if fk == "moe":
        p["ffn"] = moe_mod.moe_init(k2, cfg, dtype)
    elif fk == "dense":
        p["ffn"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    """Parameters with per-pattern-position stacks of shape (repeat, ...)."""
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 3 + cfg.num_layers)
    blocks = []
    for pi in range(len(cfg.block_pattern)):
        per_repeat = []
        for r in range(cfg.repeat):
            li = r * len(cfg.block_pattern) + pi
            per_repeat.append(layer_init(keys[3 + li], cfg, pi, li, dtype))
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_repeat))
    params = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * cfg.d_model ** -0.5
                  ).astype(dtype),
        "blocks": blocks,
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)
    return params


def init_abstract(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct pytree with the same structure (dry-run, no alloc)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


def apply_layer(p: dict, x: jax.Array, cfg: ModelConfig, kind: str,
                ffn_kind: str, positions, cache=None, cache_pos=None):
    """One (mixer + FFN) layer.  Returns (y, new_cache)."""
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "attn_local"):
        mixed, new_cache = attn_mod.attention(
            p["mixer"], h, cfg, kind=kind, positions=positions,
            kv_cache=cache, cache_pos=cache_pos)
    elif kind == "mamba":
        mixed, new_cache = mamba_mod.mamba_block(p["mixer"], h, cfg,
                                                 cache=cache)
    elif kind == "mlstm":
        mixed, new_cache = xlstm_mod.mlstm_block(p["mixer"], h, cfg,
                                                 cache=cache)
    elif kind == "slstm":
        mixed, new_cache = xlstm_mod.slstm_block(p["mixer"], h, cfg,
                                                 cache=cache)
    else:
        raise ValueError(kind)
    x = x + mixed
    if ffn_kind == "moe":
        x = x + moe_mod.moe_ffn(p["ffn"], rmsnorm(p["norm2"], x, cfg.norm_eps),
                                cfg)
    elif ffn_kind == "dense":
        x = x + mlp(p["ffn"], rmsnorm(p["norm2"], x, cfg.norm_eps))
    # sequence-parallel residual stream: the per-layer saved activation is
    # 1/model_size of the full (B, S, D) tensor (Megatron-SP layout).
    x = lc(x, ("data", "seq", None))
    return x, new_cache


def _superblock(cfg: ModelConfig, block_params: list, x, positions,
                caches=None, cache_pos=None, first_layer_idx: int = 0):
    """Apply one copy of the pattern.  block_params: per-position params.

    Each layer is itself checkpointed (nested inside the superblock-level
    checkpoint): the superblock's backward recompute holds only layer
    boundaries, and each layer's internals are rematerialised one layer at a
    time — essential for wide multi-layer patterns (Jamba's 8-layer period).
    """
    new_caches = []
    for pi, kind in enumerate(cfg.block_pattern):
        li = first_layer_idx + pi
        fk = _ffn_kind(cfg, li)
        cache = None if caches is None else caches[pi]
        x, nc = apply_layer(block_params[pi], x, cfg, kind, fk, positions,
                            cache=cache, cache_pos=cache_pos)
        new_caches.append(nc)
    return x, new_caches


def lm_head(params: dict, cfg: ModelConfig) -> jax.Array:
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T.astype(jnp.dtype(cfg.dtype))
    return head


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            embeddings: jax.Array | None = None,
            return_hidden: bool = False) -> jax.Array:
    """Training/prefill forward.  tokens (B, S) -> logits (B, S, V).

    ``embeddings`` overrides token embedding (stub modality frontends).
    ``return_hidden`` skips the LM head (training uses the chunked CE).
    """
    x = (params["embed"][tokens] if embeddings is None else embeddings
         ).astype(jnp.dtype(cfg.dtype))
    x = lc(x, ("data", "seq", None))
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def scan_body(x, rep_params):
        # NOTE: ffn kinds depend only on position within the pattern because
        # every config aligns moe.every_n_layers with the pattern length.
        y, _ = _superblock(cfg, rep_params, x, positions)
        return y, None

    body = scan_body
    if cfg.remat:
        body = jax.checkpoint(scan_body, policy=remat_policy(cfg))
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x
    logits = x @ lm_head(params, cfg)
    return lc(logits, ("data", None, "model"))


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> list:
    """Per-pattern-position stacked caches with leading (repeat,) axis."""
    caches = []
    for pi, kind in enumerate(cfg.block_pattern):
        if kind in ("attn", "attn_local"):
            one = attn_mod.init_kv_cache(cfg, batch, max_len, kind,
                                         jnp.dtype(cfg.dtype))
        elif kind == "mamba":
            one = mamba_mod.init_mamba_cache(cfg, batch, jnp.dtype(cfg.dtype))
        elif kind == "mlstm":
            one = xlstm_mod.init_mlstm_cache(cfg, batch)
        elif kind == "slstm":
            one = xlstm_mod.init_slstm_cache(cfg, batch)
        else:
            raise ValueError(kind)
        caches.append(jax.tree.map(
            lambda a: jnp.zeros((cfg.repeat,) + a.shape, a.dtype), one))
    return caches


def decode_step(params: dict, token: jax.Array, caches: list,
                cache_pos: jax.Array, cfg: ModelConfig
                ) -> tuple[jax.Array, list]:
    """One decode step.  token (B, 1) -> (logits (B, 1, V), new caches)."""
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    b = x.shape[0]

    def scan_body(x, rep):
        rep_params, rep_caches = rep
        y, ncs = _superblock(cfg, rep_params, x, None, caches=rep_caches,
                             cache_pos=cache_pos)
        return y, ncs

    x, new_caches = jax.lax.scan(scan_body, x, (params["blocks"], caches))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T.astype(x.dtype)
    logits = x @ head
    return lc(logits, ("data", None, "model")), new_caches
