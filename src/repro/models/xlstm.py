"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, recurrent) — Beck et al. 2024, arXiv:2405.04517.

mLSTM trains with a quadratic-form parallel formulation (decayed attention
matrix) chunked over queries like attention; decode keeps a matrix state
``(B, H, Dh, Dh)`` — O(1) per token, which is why xLSTM runs the 500k cell.
sLSTM is inherently sequential across time; we scan it (its width is small).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, lc

M_CHUNK = 512


def _dims(cfg: ModelConfig):
    x = cfg.xlstm
    d_in = int(x.m_proj_factor * cfg.d_model)
    hd = d_in // cfg.num_heads
    return x, d_in, hd


# ------------------------------------------------------------------ mLSTM --

def mlstm_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    x, d_in, hd = _dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(ks[0], cfg.d_model, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (x.conv_kernel, d_in), jnp.float32)
                   * x.conv_kernel ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": dense_init(ks[2], d_in, d_in, dtype),
        "wk": dense_init(ks[3], d_in, d_in, dtype),
        "wv": dense_init(ks[4], d_in, d_in, dtype),
        "w_if": dense_init(ks[5], d_in, 2 * cfg.num_heads, jnp.float32),
        "out_proj": dense_init(ks[6], d_in, cfg.d_model, dtype),
    }


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> dict:
    x, d_in, hd = _dims(cfg)
    return {
        "C": jnp.zeros((batch, cfg.num_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, cfg.num_heads, hd), jnp.float32),
        "m": jnp.full((batch, cfg.num_heads), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, x.conv_kernel, d_in), jnp.float32),
    }


def _mlstm_parallel(q, k, v, i_gate, f_gate):
    """Stabilised decayed-attention form.  q/k/v: (B, H, S, Dh)."""
    b, h, s, hd = q.shape
    logf = jax.nn.log_sigmoid(f_gate)                       # (B, H, S)
    cum = jnp.cumsum(logf, axis=-1)
    # D[t, u] = sum_{j=u+1..t} logf_j + logi_u   (u <= t)
    dmat = cum[:, :, :, None] - cum[:, :, None, :] + i_gate[:, :, None, :]
    causal = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(causal, dmat, -jnp.inf)
    m = jnp.max(dmat, axis=-1, keepdims=True)               # (B, H, S, 1)
    dexp = jnp.exp(dmat - m)
    scores = jnp.einsum("bhsd,bhud->bhsu", q, k) * (hd ** -0.5) * dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(scores, axis=-1, keepdims=True)),
                       jnp.exp(-m))
    return jnp.einsum("bhsu,bhud->bhsd", scores / norm, v)


def _mlstm_chunkwise(q, k, v, i_gate, f_gate, chunk: int):
    """Chunk-recurrent mLSTM: O(S/L) sequential chunks, parallel inside.

    Carries the stabilised matrix state (C, n, m) across chunks so long
    sequences never materialise an (S, S) decay matrix (32k prefill fits).
    q/k/v: (B, H, S, Dh) f32; gates (B, H, S) f32.
    """
    b, h, s, hd = q.shape
    nc = s // chunk
    scale = hd ** -0.5

    def split(t):
        return t.reshape(b, h, nc, chunk, -1).transpose(2, 0, 1, 3, 4)

    qc, kc, vc = split(q), split(k), split(v)                # (nc,B,H,L,Dh)
    ic = i_gate.reshape(b, h, nc, chunk).transpose(2, 0, 1, 3)
    fc = jax.nn.log_sigmoid(f_gate).reshape(b, h, nc, chunk
                                            ).transpose(2, 0, 1, 3)

    def body(carry, blk):
        C, n, m = carry                                       # (B,H,Dh,Dh) ...
        qb, kb, vb, ib, fb = blk
        bcum = jnp.cumsum(fb, axis=-1)                        # (B,H,L)
        btot = bcum[..., -1:]
        # intra-chunk decay matrix D[t,u] = bcum_t - bcum_u + i_u (u <= t)
        dmat = bcum[..., :, None] - bcum[..., None, :] + ib[..., None, :]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(causal, dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=-1)                      # (B,H,L)
        m_t = jnp.maximum(bcum + m[..., None], m_intra)
        inter_w = jnp.exp(bcum + m[..., None] - m_t)          # (B,H,L)
        dexp = jnp.exp(dmat - m_t[..., None])
        sc = jnp.einsum("bhld,bhud->bhlu", qb, kb) * scale * dexp
        num = (jnp.einsum("bhlu,bhud->bhld", sc, vb)
               + inter_w[..., None] * jnp.einsum("bhld,bhde->bhle", qb, C)
               * scale)
        den_vec = (jnp.einsum("bhlu->bhl", sc)
                   + inter_w * jnp.einsum("bhld,bhd->bhl", qb, n) * scale)
        den = jnp.maximum(jnp.abs(den_vec), jnp.exp(-m_t))[..., None]
        yb = num / den
        # state update to end of chunk
        m_state = jnp.maximum(btot[..., 0] + m,
                              jnp.max(btot - bcum + ib, axis=-1))
        w_old = jnp.exp(btot[..., 0] + m - m_state)           # (B,H)
        w_new = jnp.exp(btot - bcum + ib - m_state[..., None])  # (B,H,L)
        C = (w_old[..., None, None] * C
             + jnp.einsum("bhu,bhud,bhue->bhde", w_new, kb, vb))
        n = w_old[..., None] * n + jnp.einsum("bhu,bhud->bhd", w_new, kb)
        return (C, n, m_state), yb

    C0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    _, ys = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, ic, fc))
    return ys.transpose(1, 2, 0, 3, 4).reshape(b, h, s, hd)


def mlstm_block(p: dict, x: jax.Array, cfg: ModelConfig, *,
                cache: dict | None = None) -> tuple[jax.Array, dict | None]:
    xcfg, d_in, hd = _dims(cfg)
    b, s, _ = x.shape
    nh = cfg.num_heads
    up = x @ p["up_proj"]
    up = lc(up, ("data", None, "model"))
    xr, z = jnp.split(up, 2, axis=-1)
    xr = lc(xr, ("data", None, "model"))
    z = lc(z, ("data", None, "model"))

    new_cache = None
    if cache is None:
        k_ = xcfg.conv_kernel
        xc = sum(jnp.pad(xr, ((0, 0), (k_ - 1 - i, 0), (0, 0)))[:, :s]
                 * p["conv_w"][i] for i in range(k_)) + p["conv_b"]
        xc = jax.nn.silu(xc)
        q = (xc @ p["wq"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        k = (xc @ p["wk"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        v = (xr @ p["wv"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        gates = xc.astype(jnp.float32) @ p["w_if"]          # (B, S, 2H)
        i_g, f_g = jnp.split(gates.transpose(0, 2, 1), 2, axis=1)  # (B,H,S)
        if s > M_CHUNK and s % M_CHUNK == 0:
            y = _mlstm_chunkwise(q.astype(jnp.float32), k.astype(jnp.float32),
                                 v.astype(jnp.float32), i_g, f_g, M_CHUNK)
        else:
            y = _mlstm_parallel(q.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), i_g, f_g)
        y = y.transpose(0, 2, 1, 3).reshape(b, s, d_in).astype(x.dtype)
    else:
        conv = jnp.concatenate([cache["conv"][:, 1:], xr.astype(jnp.float32)],
                               axis=1)
        xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", conv,
                                    p["conv_w"].astype(jnp.float32))
                         + p["conv_b"].astype(jnp.float32))
        q = (xc @ p["wq"].astype(jnp.float32)).reshape(b, nh, hd)
        k = (xc @ p["wk"].astype(jnp.float32)).reshape(b, nh, hd)
        v = (xr[:, 0].astype(jnp.float32) @ p["wv"].astype(jnp.float32)
             ).reshape(b, nh, hd)
        gates = xc @ p["w_if"]
        i_g, f_g = gates[:, :nh], gates[:, nh:]
        logf = jax.nn.log_sigmoid(f_g)
        m_new = jnp.maximum(logf + cache["m"], i_g)
        fi = jnp.exp(logf + cache["m"] - m_new)[..., None, None]
        ii = jnp.exp(i_g - m_new)[..., None, None]
        C = fi * cache["C"] + ii * jnp.einsum("bhd,bhe->bhde", v, k)
        n = fi[..., 0] * cache["n"] + ii[..., 0] * k
        num = jnp.einsum("bhde,bhe->bhd", C, q) * (hd ** -0.5)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q))
                          * (hd ** -0.5), jnp.exp(-m_new))[..., None]
        y = (num / den).reshape(b, 1, d_in).astype(x.dtype)
        new_cache = {"C": C, "n": n, "m": m_new, "conv": conv}

    y = y * jax.nn.silu(z)
    y = lc(y, ("data", None, "model"))
    return y @ p["out_proj"], new_cache


# ------------------------------------------------------------------ sLSTM --

def slstm_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    dff = int(cfg.xlstm.s_ff_factor * d)
    ks = jax.random.split(key, 4)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, dtype),      # i, f, z, o
        "r_gates": dense_init(ks[1], d, 4 * d, dtype),      # recurrent
        "ff_up": dense_init(ks[2], d, dff, dtype),
        "ff_down": dense_init(ks[3], dff, d, dtype),
    }


def init_slstm_cache(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e30,
                                                  jnp.float32)}


def _slstm_step(p, state, xt):
    """One recurrence step.  xt: (B, 4D) pre-projected gates input."""
    c, n, h, m = state
    gates = xt + h @ p["r_gates"].astype(jnp.float32)
    i_, f_, z_, o_ = jnp.split(gates, 4, axis=-1)
    m_new = jnp.maximum(f_ + m, i_)                          # log-space stab
    i_s = jnp.exp(i_ - m_new)
    f_s = jnp.exp(f_ + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(z_)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(o_) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block(p: dict, x: jax.Array, cfg: ModelConfig, *,
                cache: dict | None = None) -> tuple[jax.Array, dict | None]:
    b, s, d = x.shape
    xg = (x @ p["w_gates"]).astype(jnp.float32)              # (B, S, 4D)

    if cache is None:
        z = jnp.zeros((b, d), jnp.float32)
        state = (z, z, z, jnp.full((b, d), -1e30, jnp.float32))
        state, hs = jax.lax.scan(
            lambda st, xt: _slstm_step(p, st, xt), state,
            xg.transpose(1, 0, 2))
        y = hs.transpose(1, 0, 2).astype(x.dtype)            # (B, S, D)
        new_cache = None
    else:
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
        state, h = _slstm_step(p, state, xg[:, 0])
        y = h[:, None, :].astype(x.dtype)
        new_cache = {"c": state[0], "n": state[1], "h": state[2],
                     "m": state[3]}

    ff = jax.nn.gelu(y @ p["ff_up"]) @ p["ff_down"]
    return ff, new_cache
