"""Mamba-1 selective SSM block (Jamba's sequence mixer).

Training/prefill uses an associative scan over the diagonal SSM recurrence
(h_t = a_t * h_{t-1} + b_t), parallel in O(log S) depth — the TPU-native
replacement for the CUDA selective-scan kernel.  Decode keeps a per-layer
state ``(B, d_inner, d_state)`` and a conv ring of the last ``d_conv``
inputs, giving O(1) work per token — this is why Jamba runs the 500k cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, lc


def _cfg(cfg: ModelConfig):
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    dt_rank = m.dt_rank or -(-cfg.d_model // 16)
    return m, d_in, dt_rank


def mamba_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    m, d_in, dt_rank = _cfg(cfg)
    keys = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(keys[0], cfg.d_model, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(keys[1], (m.d_conv, d_in), jnp.float32)
                   * m.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(keys[2], d_in, dt_rank + 2 * m.d_state, dtype),
        "dt_proj": dense_init(keys[3], dt_rank, d_in, dtype),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, m.d_state + 1, dtype=jnp.float32), (d_in, m.d_state)
        ) + 0.0),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(keys[4], d_in, cfg.d_model, dtype),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    m, d_in, _ = _cfg(cfg)
    return {
        "conv": jnp.zeros((batch, m.d_conv, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, m.d_state), jnp.float32),
    }


def _ssm_params(p, xc, cfg):
    """Input-dependent (dt, B, C) and discretised (a, bx)."""
    m, d_in, dt_rank = _cfg(cfg)
    proj = xc @ p["x_proj"]
    dt, Bc, Cc = jnp.split(proj.astype(jnp.float32),
                           [dt_rank, dt_rank + m.d_state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                   # (d_in, N)
    a = jnp.exp(dt[..., None] * A)                             # (..., d_in, N)
    bx = (dt * xc.astype(jnp.float32))[..., None] * Bc[..., None, :]
    return a, bx, Cc


SCAN_CHUNK = 512


def _selective_scan_chunked(p, xc, cfg):
    """Chunk-recurrent selective scan.

    The (B, S, d_inner, N) discretised-state tensors are the memory hazard of
    a naive parallel scan (f32, d_inner = 2*d_model).  Chunking bounds the
    live set to one chunk: within a chunk an associative scan runs in
    parallel; the carried state enters via the chunk's cumulative decay
    (h_t = local_t + cumprod(a)_t * h_in).  Each chunk body is checkpointed.
    """
    b, s, d_in = xc.shape
    n_state = cfg.mamba.d_state
    chunk = SCAN_CHUNK if s % SCAN_CHUNK == 0 and s > SCAN_CHUNK else s

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    if chunk == s:
        a, bx, Cc = _ssm_params(p, xc, cfg)
        _, hs = jax.lax.associative_scan(combine, (a, bx), axis=1)
        return jnp.einsum("bsdn,bsn->bsd", hs, Cc)

    nc = s // chunk
    xcs = xc.reshape(b, nc, chunk, d_in).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def body(h_in, xblk):                                  # h_in (B,d_in,N)
        a, bx, Cc = _ssm_params(p, xblk, cfg)              # (B,L,d_in,N)
        _, hs_local = jax.lax.associative_scan(combine, (a, bx), axis=1)
        decay = jnp.cumprod(a, axis=1)                     # prod a_1..a_t
        hs = hs_local + decay * h_in[:, None]
        y = jnp.einsum("bldn,bln->bld", hs, Cc)
        return hs[:, -1], y

    h0 = jnp.zeros((b, d_in, n_state), jnp.float32)
    _, ys = jax.lax.scan(body, h0, xcs)
    return ys.transpose(1, 0, 2, 3).reshape(b, s, d_in)


def mamba_block(p: dict, x: jax.Array, cfg: ModelConfig, *,
                cache: dict | None = None) -> tuple[jax.Array, dict | None]:
    """x: (B, S, D) -> (B, S, D).  Decode when ``cache`` is given (S == 1)."""
    m, d_in, _ = _cfg(cfg)
    b, s, _ = x.shape
    xz = x @ p["in_proj"]
    xz = lc(xz, ("data", None, "model"))
    xr, z = jnp.split(xz, 2, axis=-1)                          # (B, S, d_in)
    xr = lc(xr, ("data", None, "model"))
    z = lc(z, ("data", None, "model"))

    new_cache = None
    if cache is None:
        # causal depthwise conv via shifted adds (k is tiny)
        xc = sum(
            jnp.pad(xr, ((0, 0), (m.d_conv - 1 - i, 0), (0, 0)))[:, :s]
            * p["conv_w"][i]
            for i in range(m.d_conv)
        ) + p["conv_b"]
        xc = jax.nn.silu(xc)
        y = _selective_scan_chunked(p, xc, cfg)
        y = y + p["D"] * xc.astype(jnp.float32)
    else:
        conv = jnp.concatenate([cache["conv"][:, 1:], xr], axis=1)
        xc = jnp.einsum("bkd,kd->bd", conv, p["conv_w"]) + p["conv_b"]
        xc = jax.nn.silu(xc)[:, None, :]                       # (B, 1, d_in)
        a, bx, Cc = _ssm_params(p, xc[:, 0], cfg)              # (B, d_in, N)
        h = a * cache["ssm"] + bx
        y = jnp.einsum("bdn,bn->bd", h, Cc)[:, None, :]
        y = y + p["D"] * xc.astype(jnp.float32)
        new_cache = {"conv": conv, "ssm": h}

    y = (y.astype(x.dtype) * jax.nn.silu(z))
    y = lc(y, ("data", None, "model"))
    return y @ p["out_proj"], new_cache
