"""Whisper-style encoder-decoder transformer.

Per the assignment spec the conv/audio frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings (B, T_frames, D).  (The actual Whisper
conv frontend — two 1-D convs — can be built from ``repro.core.decompose``;
see ``examples/whisper_frontend_demo.py``.)  Encoder: bidirectional
self-attention.  Decoder: causal self-attention + cross-attention + FFN.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.config import ModelConfig
from repro.models.layers import (dense_init, layernorm, layernorm_init, lc,
                                 mlp, mlp_init, rmsnorm, rmsnorm_init)


def _enc_layer_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn": attn_mod.attn_init(k1, cfg, dtype),
        "ffn": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
    }


def _dec_layer_init(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_attn": attn_mod.attn_init(k1, cfg, dtype),
        "cross_attn": attn_mod.attn_init(k2, cfg, dtype, cross=True),
        "ffn": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
        "norm3": rmsnorm_init(cfg.d_model, dtype),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    n_enc, n_dec = cfg.encoder_layers, cfg.num_layers
    keys = jax.random.split(key, n_enc + n_dec + 4)
    enc = [_enc_layer_init(keys[i], cfg, dtype) for i in range(n_enc)]
    dec = [_dec_layer_init(keys[n_enc + i], cfg, dtype) for i in range(n_dec)]
    return {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * cfg.d_model ** -0.5
                  ).astype(dtype),
        "enc_pos": (jax.random.normal(keys[-2], (cfg.encoder_ctx, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dtype),
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "dec_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(keys[-3], cfg.d_model, cfg.vocab, dtype),
    }


def init_abstract(cfg: ModelConfig) -> dict:
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


def encode(params: dict, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, T, D) precomputed frontend embeddings (stub)."""
    x = frames.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"][None, :frames.shape[1]]
    x = lc(x, ("data", "seq", None))

    def body(x, p):
        h, _ = attn_mod.attention(p["attn"], rmsnorm(p["norm1"], x,
                                                     cfg.norm_eps),
                                  cfg, causal=False)
        x = x + h
        x = x + mlp(p["ffn"], rmsnorm(p["norm2"], x, cfg.norm_eps))
        return x, None

    if cfg.remat:
        from repro.models.transformer import remat_policy
        body = jax.checkpoint(body, policy=remat_policy(cfg))
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(p, x, enc_out, cfg, positions, cache=None, cache_pos=None):
    h, nc = attn_mod.attention(p["self_attn"], rmsnorm(p["norm1"], x,
                                                       cfg.norm_eps),
                               cfg, positions=positions, kv_cache=cache,
                               cache_pos=cache_pos)
    x = x + h
    h, _ = attn_mod.attention(p["cross_attn"], rmsnorm(p["norm2"], x,
                                                       cfg.norm_eps),
                              cfg, xa=enc_out)
    x = x + h
    x = x + mlp(p["ffn"], rmsnorm(p["norm3"], x, cfg.norm_eps))
    x = lc(x, ("data", "seq", None))
    return x, nc


def forward(params: dict, tokens: jax.Array, frames: jax.Array,
            cfg: ModelConfig) -> jax.Array:
    """Teacher-forced training forward -> logits (B, S, V)."""
    enc_out = encode(params, frames, cfg)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, p):
        y, _ = _dec_layer(p, x, enc_out, cfg, positions)
        return y, None

    if cfg.remat:
        from repro.models.transformer import remat_policy
        body = jax.checkpoint(body, policy=remat_policy(cfg))
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    logits = x @ params["lm_head"]
    return lc(logits, ("data", None, "model"))


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    one = attn_mod.init_kv_cache(cfg, batch, max_len, "attn",
                                 jnp.dtype(cfg.dtype))
    return jax.tree.map(
        lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), one)


def decode_step(params: dict, token: jax.Array, enc_out: jax.Array,
                caches: dict, cache_pos: jax.Array, cfg: ModelConfig):
    """One decode step with self-attn KV cache + cross-attn to enc_out."""
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))

    def body(x, rep):
        p, cache = rep
        y, nc = _dec_layer(p, x, enc_out, cfg, None, cache=cache,
                           cache_pos=cache_pos)
        return y, nc

    x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], caches))
    x = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    return x @ params["lm_head"], new_caches
