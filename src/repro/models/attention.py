"""Attention: MHA/GQA with RoPE, optional qk-norm and sliding window.

Execution contexts:
  * training / prefill: causal (or windowed) attention, **query-chunked** via
    ``lax.scan`` so the score matrix never materialises at (S, S) — the
    live transient is (B, H, TQ, S) per chunk.  This is what lets the 32k
    prefill cells fit HBM in the dry-run; the Pallas flash kernel
    (:mod:`repro.kernels.flash_attention`) is the TPU-native equivalent for
    real execution.
  * decode: single-token query against a KV cache (ring buffer of ``window``
    entries for local layers -> a 500k decode holds only ``window`` keys on
    Gemma-style local layers).
  * cross-attention (``xa`` given): non-causal over encoder output.

Head layout is merged (B, S, H, Dh) with KV repeated to full heads for GQA so
the head axis shards cleanly over the ``model`` mesh axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, lc, rmsnorm, rmsnorm_init, rope

NEG_INF = -2.3819763e38
Q_CHUNK = 512  # query-chunk size for long-sequence attention


def attn_init(key, cfg: ModelConfig, dtype=jnp.bfloat16, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d, cfg.num_heads * hd, dtype),
        "wk": dense_init(kk, d, cfg.kv_heads * hd, dtype),
        "wv": dense_init(kv, d, cfg.kv_heads * hd, dtype),
        "wo": dense_init(ko, cfg.num_heads * hd, d, dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, kind: str,
                  dtype=jnp.bfloat16) -> dict:
    size = min(max_len, cfg.window) if (kind == "attn_local" and cfg.window) \
        else max_len
    shape = (batch, size, cfg.kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, T, KVH, Dh) -> (B, T, KVH*groups, Dh)."""
    if groups == 1:
        return k
    b, t, kvh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, kvh, groups, hd)
                            ).reshape(b, t, kvh * groups, hd)


def _softmax_attend(q, k, v, mask):
    """q (B,H,TQ,Dh), k/v (B,H,T,Dh), mask (B,1|H,TQ,T) -> (B,H,TQ,Dh)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhtd->bhqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqt,bhtd->bhqd", p, v.astype(jnp.float32))


def _chunked_causal(q, k, v, positions, window: int):
    """Query-chunked causal attention.  q/k/v: (B, H, S, Dh)."""
    b, h, s, hd = q.shape
    tq = Q_CHUNK if s % Q_CHUNK == 0 and s > Q_CHUNK else s
    n_chunks = s // tq
    kpos = positions[:, None, None, :]                      # (B,1,1,S)

    if n_chunks == 1:
        qpos = positions[:, None, :, None]
        mask = kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        return _softmax_attend(q, k, v, mask).astype(q.dtype)

    qc = q.reshape(b, h, n_chunks, tq, hd).transpose(2, 0, 1, 3, 4)
    pc = positions.reshape(b, n_chunks, tq).transpose(1, 0, 2)

    # checkpointed per-chunk body: the (TQ, S) score/mask tiles are
    # rematerialised in backward, never stacked across chunks.
    @jax.checkpoint
    def body(_, blk):
        qb, pb = blk                                        # (B,H,TQ,Dh), (B,TQ)
        qpos = pb[:, None, :, None]
        mask = kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        return None, _softmax_attend(qb, k, v, mask).astype(qb.dtype)

    _, out = jax.lax.scan(body, None, (qc, pc))
    out = out.transpose(1, 2, 0, 3, 4).reshape(b, h, s, hd)
    return out.astype(q.dtype)


def attention(p: dict, x: jax.Array, cfg: ModelConfig, *, kind: str = "attn",
              positions: jax.Array | None = None, kv_cache: dict | None = None,
              cache_pos: jax.Array | None = None, causal: bool = True,
              xa: jax.Array | None = None) -> tuple[jax.Array, dict | None]:
    """Returns (output, updated_kv_cache).  x: (B, S, D)."""
    b, s, _ = x.shape
    nh, kvh, hd = cfg.num_heads, cfg.kv_heads, cfg.head_dim
    groups = nh // kvh
    if positions is None:
        # with a cache, token i of the chunk sits at absolute position
        # cache_pos + i — s == 1 is the decode step, s > 1 parallel prefill
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :],
                                     (b, s))
        if cache_pos is not None:
            positions = positions + cache_pos

    q = (x @ p["wq"]).reshape(b, s, nh, hd)
    kv_src = x if xa is None else xa
    sk = kv_src.shape[1]
    k = (kv_src @ p["wk"]).reshape(b, sk, kvh, hd)
    v = (kv_src @ p["wv"]).reshape(b, sk, kvh, hd)

    if cfg.qk_norm and "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if xa is None and cfg.rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None and xa is None:
        size = kv_cache["k"].shape[1]
        idx = jnp.mod(cache_pos, size) if (kind == "attn_local" and cfg.window
                                           ) else cache_pos
        ck = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k, idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v, idx, axis=1)
        new_cache = {"k": ck, "v": cv}
        # sequence axis of the cache shards over 'data' (batch=1 long-decode)
        k = lc(ck, ("data_kvseq", "kvseq", None, None))
        v = lc(cv, ("data_kvseq", "kvseq", None, None))
        sk = size

    kf = _repeat_kv(k, groups).transpose(0, 2, 1, 3)     # (B, H, T, Dh)
    vf = _repeat_kv(v, groups).transpose(0, 2, 1, 3)
    qf = q.transpose(0, 2, 1, 3)                          # (B, H, S, Dh)
    qf = lc(qf, ("data", "model", None, None))
    if kv_cache is None:
        kf = lc(kf, ("data", "model", None, None))
        vf = lc(vf, ("data", "model", None, None))

    if kv_cache is not None and xa is None:
        slot = jnp.arange(sk)
        if kind == "attn_local" and cfg.window and sk <= cfg.window:
            valid = slot[None, None, :] < jnp.minimum(cache_pos + s, sk)
            if s > 1:   # parallel prefill: causal within the written chunk
                valid = valid & (slot[None, None, :] <= positions[:, :, None])
        else:
            # per-query causal bound — for s == 1 this is the classic
            # slot <= cache_pos decode mask, for s > 1 (parallel prefill)
            # query i sees slots up to cache_pos + i
            valid = slot[None, None, :] <= positions[:, :, None]
        mask = valid[:, None, :, :]                       # (B,1,S,T)
        out = _softmax_attend(qf, kf, vf, mask).astype(x.dtype)
    elif xa is not None or not causal:
        mask = jnp.ones((1, 1, 1, sk), bool)
        out = _softmax_attend(qf, kf, vf, mask).astype(x.dtype)
    else:
        win = cfg.window if kind == "attn_local" else 0
        out = _chunked_causal(qf, kf, vf, positions, win)

    out = out.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    out = lc(out, ("data", None, "model"))
    return out @ p["wo"], new_cache
