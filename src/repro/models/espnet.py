"""ESPNet-style segmentation network built on the paper's decomposition.

ESPNet (Mehta et al., 2018) is the canonical *second* workload for the
accelerator: its ESP module is a spatial pyramid of dilated convolutions —
a 1x1 reduce followed by ``K`` parallel 3x3 branches at dilation rates
``1, 2, 4, 8`` whose outputs are fused hierarchically (HFF) to kill gridding
artifacts.  Every dilated branch runs through the input decomposition
(:mod:`repro.core.dilated`), the downsampling ESP modules exercise the
*strided*-dilated output-class schedule (DESIGN.md §2c), and the decoder's
upsampling runs through the weight decomposition — so the whole net, like
ENet, uses the technique as its execution engine.

Layer inventory matches :mod:`repro.core.espnet_spec` (the cycle-model
workload table).  The forward is differentiable on both backends
(DESIGN.md §6): ``jax.grad`` through ``backend='pallas'`` exercises the
custom VJPs of all three fused kernels.  The stem's BN/PReLU and the
decoder's skip-add are emitted as fused epilogue specs (DESIGN.md §7);
the ESP module's post-concat BN/PReLU — which follows the HFF merge, not
any single conv — runs as the same folded-BN oracle in one pass.

This is a compact variant (alpha2=2, alpha3=3, K=4 branches, light deconv
decoder) — the module structure, not the exact ESPNet-C widths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.decompose import conv2d
from repro.kernels.epilogue import EpilogueSpec, apply_reference
from repro.models.common import bn_init as _bn_init
from repro.models.common import conv_init as _conv_init
from repro.models.common import fold_bn as _fold_bn

ESP_DILATIONS = (1, 2, 4, 8)   # K = 4 pyramid branches (d = 2**k)

_EP_BN_ACT = EpilogueSpec(bn=True, prelu=True)
_EP_RES = EpilogueSpec(residual="post_act")


def _esp_init(key, cin: int, cout: int, dtype=jnp.float32) -> dict:
    """ESP module params: 1x1 reduce -> K dilated 3x3 branches -> BN/PReLU."""
    K = len(ESP_DILATIONS)
    if cout % K:
        raise ValueError(f"cout={cout} not divisible by K={K}")
    cb = cout // K
    ks = jax.random.split(key, K + 1)
    p = {"reduce": _conv_init(ks[0], 1, 1, cin, cb, dtype),
         "bn": _bn_init(cout, dtype), "a": jnp.full((1,), 0.25, dtype)}
    # folded BN does not re-normalise per batch; the HFF cumulative sums and
    # the residual grow module variance ~(K+1)/2 + 1 per ESP — scale the
    # folded BN init down so the stack starts at unit activation scale
    p["bn"]["g"] = p["bn"]["g"] / jnp.sqrt((K + 1) / 2 + 1).astype(dtype)
    for i, d in enumerate(ESP_DILATIONS):
        p[f"br{d}"] = _conv_init(ks[i + 1], 3, 3, cb, cb, dtype)
    return p


def _esp(p: dict, x: jax.Array, stride: int = 1, decomposed: bool = True,
         strategy: str = "batched", backend: str = "xla",
         compute_dtype=None) -> jax.Array:
    """ESP module: reduce -> K parallel dilated branches -> HFF -> concat.

    ``stride=2`` is the downsampling ESP: every branch is a *strided* dilated
    convolution through the output-class schedule.  The d=1 branch is a plain
    dense conv (no decomposition to apply).  HFF (hierarchical feature
    fusion) adds branch outputs cumulatively before concatenation.
    """
    cd = compute_dtype
    h = conv2d(x, p["reduce"], backend=backend, compute_dtype=cd)
    outs = []
    for d in ESP_DILATIONS:
        if d == 1:
            outs.append(conv2d(h, p[f"br{d}"], stride=stride, backend=backend,
                               compute_dtype=cd))
        else:
            outs.append(conv2d(h, p[f"br{d}"], dilation=d, stride=stride,
                               decomposed=decomposed, strategy=strategy,
                               backend=backend, compute_dtype=cd))
    acc, fused = outs[0], [outs[0]]
    for o in outs[1:]:              # HFF: cumulative sums de-grid the pyramid
        acc = acc + o
        fused.append(acc)
    y = jnp.concatenate(fused, axis=-1)
    if stride == 1 and x.shape[-1] == y.shape[-1]:
        y = y + x                   # residual (regular ESP only)
    # the module's BN/PReLU sits after the HFF concat, not after any single
    # conv — it cannot fuse into a branch kernel, so it runs as the same
    # folded-BN epilogue oracle in ONE elementwise pass (DESIGN.md §7)
    sc, sh = _fold_bn(p["bn"])
    return apply_reference(_EP_BN_ACT, y, (sc, sh, p["a"]))


def init_params(key, num_classes: int = 19, alpha2: int = 2, alpha3: int = 3,
                dtype=jnp.float32) -> dict:
    ks = iter(jax.random.split(key, 16 + alpha2 + alpha3))
    p = {"stem": _conv_init(next(ks), 3, 3, 3, 16, dtype),
         "stem_bn": _bn_init(16, dtype), "stem_a": jnp.full((1,), 0.25, dtype)}
    p["down1"] = _esp_init(next(ks), 16, 64, dtype)
    for i in range(alpha2):
        p[f"l2_{i}"] = _esp_init(next(ks), 64, 64, dtype)
    p["down2"] = _esp_init(next(ks), 64, 128, dtype)
    for i in range(alpha3):
        p[f"l3_{i}"] = _esp_init(next(ks), 128, 128, dtype)
    p["head"] = _conv_init(next(ks), 1, 1, 128, num_classes, dtype)
    p["skip2"] = _conv_init(next(ks), 1, 1, 64, num_classes, dtype)
    p["up1"] = _conv_init(next(ks), 3, 3, num_classes, num_classes, dtype)
    p["up2"] = _conv_init(next(ks), 3, 3, num_classes, num_classes, dtype)
    p["up3"] = _conv_init(next(ks), 3, 3, num_classes, num_classes, dtype)
    return p


@functools.partial(jax.jit,
                   static_argnames=("decomposed", "strategy", "backend",
                                    "alpha2", "alpha3", "compute_dtype"))
def forward(params: dict, x: jax.Array, decomposed: bool = True,
            strategy: str = "batched", backend: str = "xla",
            alpha2: int = 2, alpha3: int = 3,
            compute_dtype: str | None = None) -> jax.Array:
    """x: (N, H, W, 3) -> logits (N, H, W, classes).  H, W divisible by 8.

    ``compute_dtype`` (static, e.g. ``"bf16"``): activations flow in the
    compute dtype through every ESP branch and decoder deconv while params
    stay fp32 masters (DESIGN.md §12).
    """
    cd = compute_dtype
    if cd is not None:
        from repro.kernels.util import canon_dtype

        x = x.astype(canon_dtype(cd))
    kw = dict(decomposed=decomposed, strategy=strategy, backend=backend,
              compute_dtype=cd)
    sc, sh = _fold_bn(params["stem_bn"])
    h = conv2d(x, params["stem"], stride=2, backend=backend,     # H/2
               epilogue=_EP_BN_ACT, scale=sc, shift=sh,
               alpha=params["stem_a"], compute_dtype=cd)
    h = _esp(params["down1"], h, stride=2, **kw)                 # H/4, 64
    for i in range(alpha2):
        h = _esp(params[f"l2_{i}"], h, **kw)
    skip = conv2d(h, params["skip2"], backend=backend,           # H/4, C
                  compute_dtype=cd)
    h = _esp(params["down2"], h, stride=2, **kw)                 # H/8, 128
    for i in range(alpha3):
        h = _esp(params[f"l3_{i}"], h, **kw)
    h = conv2d(h, params["head"], backend=backend, compute_dtype=cd)  # H/8, C
    # decoder skip-add fuses into the transposed kernel's output pass
    h = conv2d(h, params["up1"], stride=2, transposed=True, output_padding=1,
               decomposed=decomposed, backend=backend,
               epilogue=_EP_RES, residual=skip, compute_dtype=cd)  # H/4
    h = conv2d(h, params["up2"], stride=2, transposed=True, output_padding=1,
               decomposed=decomposed, backend=backend, compute_dtype=cd)  # H/2
    return conv2d(h, params["up3"], stride=2, transposed=True,
                  output_padding=1, decomposed=decomposed, backend=backend,
                  compute_dtype=cd)
