"""Shared neural-net building blocks (pure-JAX, functional, dict params).

Every module is an ``init(key, ...) -> params`` / ``apply(params, x, ...)``
pair.  Parameters are plain pytrees; sharding is attached later by logical
rules over tree paths (``repro.distributed.sharding``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# logical sharding constraint hook — installed by repro.distributed.sharding;
# identity when no mesh context is active (single-device smoke tests).
_CONSTRAINT_FN = None
DISABLE_SEQ_SP = False  # perf-ablation knob (launch.perf variant "nosp")


def set_constraint_fn(fn) -> None:
    global _CONSTRAINT_FN
    _CONSTRAINT_FN = fn


def lc(x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
    """Apply a logical sharding constraint (no-op outside a mesh context)."""
    if _CONSTRAINT_FN is None:
        return x
    if DISABLE_SEQ_SP and "seq" in axes:
        axes = tuple(None if a == "seq" else a for a in axes)
    return _CONSTRAINT_FN(x, axes)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16,
               scale: float | None = None) -> jax.Array:
    scale = (d_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rmsnorm_init(d: int, dtype=jnp.bfloat16) -> jax.Array:
    return jnp.ones((d,), dtype)


def rmsnorm(g: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g


def layernorm_init(d: int, dtype=jnp.bfloat16) -> dict:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["g"]
            + p["b"])


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLP ---

def mlp_init(key, d: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype),
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype),
    }


def mlp(p: dict, x: jax.Array) -> jax.Array:
    """SwiGLU feed-forward."""
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = lc(h, ("data", None, "model"))
    return h @ p["w_down"]


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None) -> jax.Array:
    """Token-mean cross entropy; logits may be sharded on the vocab axis."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_softmax_ce(hidden: jax.Array, head: jax.Array, labels: jax.Array,
                       mask: jax.Array, chunk: int = 512) -> jax.Array:
    """Cross entropy without ever materialising full (B, S, V) logits.

    Scans sequence chunks; each chunk's logits are rematerialised in the
    backward pass (jax.checkpoint), so peak memory is one chunk's logits —
    the standard large-vocab trick (262k-vocab Gemma at 4k seq would
    otherwise dominate the training footprint).
    """
    b, s, d = hidden.shape
    if s % chunk != 0 or s <= chunk:
        logits = hidden @ head
        return softmax_cross_entropy(logits, labels, mask)
    nc = s // chunk
    hs = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, blk):
        h, l, m = blk
        logits = (h @ head).astype(jnp.float32)
        logits = lc(logits, ("data", None, "model"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll_sum, m_sum = carry
        return (nll_sum + jnp.sum((logz - gold) * m), m_sum + jnp.sum(m)), None

    (nll, msum), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                  (hs, ls, ms))
    return nll / jnp.maximum(msum, 1.0)
