"""Diffusion-style U-Net decoder block stack on the decomposition engine.

The decoder half of a diffusion U-Net (Ho et al. 2020 / Ronneberger et al.
2015 lineage) is the second generative transposed-conv workload: each level
concatenates an encoder skip, runs dense 3x3 convs, and upsamples with a
stride-2 transposed convolution.  This stack alternates ``k=4`` and ``k=2``
upsampling (both even-kernel parity schedules with ``p_lo = k//2``,
``output_padding=0`` — exact 2x), so together with DCGAN it covers the
even-(k, s) geometries the segmentation nets never touch.

GroupNorm is carried in *folded* form (``common.fold_gn``, DESIGN.md §8):
its learnable per-channel affine rides the conv kernels' BN epilogue slots,
while live per-sample statistics — which cannot fuse into a single output
pass — stay available as the :func:`repro.models.common.group_norm` oracle.
The activation is PReLU (the engine's fused-epilogue vocabulary; slope 0.2
approximates the SiLU-family smooth gates diffusion nets use).  The
upsampling kernels fuse the PReLU alone.

Layer inventory matches :func:`repro.core.gen_spec.unet_decoder_layers`.
Differentiable on both backends (DESIGN.md §6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.decompose import conv2d
from repro.core.gen_spec import UNET_UP_KERNELS, UNET_WIDTHS
from repro.kernels.epilogue import EpilogueSpec
from repro.models.common import conv_init as _conv_init
from repro.models.common import fold_gn as _fold_gn
from repro.models.common import gn_init as _gn_init
from repro.models.common import tconv_init as _tconv_init
from repro.models.common import timestep_embedding

#: timestep-embedding width of the denoiser (``init_denoiser_params``).
DENOISE_EMB_DIM = 64

_EP_GN_ACT = EpilogueSpec(bn=True, prelu=True)   # folded-GN affine + PReLU
_EP_ACT = EpilogueSpec(prelu=True)


def init_params(key, widths: tuple[int, ...] = UNET_WIDTHS,
                skip_chs: tuple[int, ...] | None = None, out_ch: int = 3,
                dtype=jnp.float32) -> dict:
    """Decoder parameters; level ``i`` consumes a ``skip_chs[i]``-wide skip.

    ``widths`` are the per-level channel counts (the canonical stack is
    (256, 128, 64) from an 8x8 mid-block); tests shrink them.
    """
    skip_chs = tuple(widths) if skip_chs is None else tuple(skip_chs)
    if len(skip_chs) != len(widths):
        raise ValueError(f"{len(skip_chs)} skip widths for {len(widths)} levels")
    ks = iter(jax.random.split(key, 3 * len(widths) + 1))
    p: dict = {}
    for i, (c, cs) in enumerate(zip(widths, skip_chs)):
        k = UNET_UP_KERNELS[i % len(UNET_UP_KERNELS)]
        c_next = widths[i + 1] if i + 1 < len(widths) else widths[-1] // 2
        p[f"l{i}_conv1"] = _conv_init(next(ks), 3, 3, c + cs, c, dtype)
        p[f"l{i}_gn1"] = _gn_init(c, dtype)
        p[f"l{i}_a1"] = jnp.full((1,), 0.2, dtype)
        p[f"l{i}_conv2"] = _conv_init(next(ks), 3, 3, c, c, dtype)
        p[f"l{i}_gn2"] = _gn_init(c, dtype)
        p[f"l{i}_a2"] = jnp.full((1,), 0.2, dtype)
        p[f"l{i}_up"] = _tconv_init(next(ks), k, k, c, c_next, stride=2,
                                    dtype=dtype)
        p[f"l{i}_aup"] = jnp.full((1,), 0.2, dtype)
    p["head"] = _conv_init(next(ks), 3, 3, widths[-1] // 2, out_ch, dtype)
    return p


@functools.partial(jax.jit,
                   static_argnames=("decomposed", "backend", "interpret",
                                    "compute_dtype"))
def forward(params: dict, x: jax.Array, skips: tuple[jax.Array, ...],
            decomposed: bool = True, backend: str = "xla",
            interpret: bool | None = None,
            compute_dtype: str | None = None) -> jax.Array:
    """x: (N, H, W, widths[0]) mid features; skips[i] at level i's extent.

    Per level: skip-concat -> 3x3 conv (folded-GN + PReLU epilogue) -> 3x3
    conv (same) -> even-k stride-2 transposed upsample (PReLU epilogue).
    Returns (N, H * 2**levels, W * 2**levels, out_ch).

    ``compute_dtype`` (static, e.g. ``"bf16"``) casts mid features and every
    skip once; activations then flow in the compute dtype with fp32 masters
    and fp32 kernel accumulators (DESIGN.md §12).
    """
    levels = sum(1 for k in params if k.endswith("_up"))
    if len(skips) != levels:
        raise ValueError(f"{len(skips)} skips for {levels} levels")
    cd = compute_dtype
    h = x
    if cd is not None:
        from repro.kernels.util import canon_dtype

        h = h.astype(canon_dtype(cd))
        skips = tuple(s.astype(canon_dtype(cd)) for s in skips)
    for i in range(levels):
        k = UNET_UP_KERNELS[i % len(UNET_UP_KERNELS)]
        h = jnp.concatenate([h, skips[i]], axis=-1)
        for j in (1, 2):
            sc, sh = _fold_gn(params[f"l{i}_gn{j}"])
            h = conv2d(h, params[f"l{i}_conv{j}"], backend=backend,
                       interpret=interpret, epilogue=_EP_GN_ACT, scale=sc,
                       shift=sh, alpha=params[f"l{i}_a{j}"],
                       compute_dtype=cd)
        h = conv2d(h, params[f"l{i}_up"], stride=2, transposed=True,
                   padding=k // 2, output_padding=0, decomposed=decomposed,
                   backend=backend, interpret=interpret, epilogue=_EP_ACT,
                   alpha=params[f"l{i}_aup"], compute_dtype=cd)
    return conv2d(h, params["head"], backend=backend, interpret=interpret,
                  compute_dtype=cd)


# ---------------------------------------------------------------------------
# Denoiser wrapper: the eps-model a DDIM sampling loop iterates (DESIGN.md §9)
# ---------------------------------------------------------------------------

def _avg_pool(x: jax.Array, factor: int) -> jax.Array:
    """Exact average pooling by an integer factor (NHWC)."""
    if factor == 1:
        return x
    n, h, w, c = x.shape
    return x.reshape(n, h // factor, factor, w // factor, factor, c
                     ).mean(axis=(2, 4))


def init_denoiser_params(key, widths: tuple[int, ...] = UNET_WIDTHS,
                         out_ch: int = 3, emb_dim: int = DENOISE_EMB_DIM,
                         dtype=jnp.float32) -> dict:
    """Denoiser ``eps(x_t, t)`` built around the decoder stack.

    The decoder (`init_params`/:func:`forward`) maps mid features + skips to
    an image; the denoiser closes the loop so the *image itself* can be
    iterated: cheap 1x1-conv encoders project the average-pooled noisy image
    onto the mid features and every skip extent, a two-layer MLP of the
    sinusoidal timestep embedding is broadcast-added to the mid features,
    and the decoder — where all the transposed-conv work lives — predicts
    the noise.  The timestep never changes any convolution geometry, so one
    compiled step serves requests at arbitrary timesteps (DESIGN.md §9).
    """
    kd, kst, kt1, kt2, ks = jax.random.split(key, 5)
    p = {"dec": init_params(kd, widths, out_ch=out_ch, dtype=dtype),
         "stem": _conv_init(kst, 1, 1, out_ch, widths[0], dtype),
         "t_w1": (jax.random.normal(kt1, (emb_dim, emb_dim), jnp.float32)
                  * (2.0 / emb_dim) ** 0.5).astype(dtype),
         "t_w2": (jax.random.normal(kt2, (emb_dim, widths[0]), jnp.float32)
                  * (2.0 / emb_dim) ** 0.5).astype(dtype)}
    for i, (kk, c) in enumerate(zip(jax.random.split(ks, len(widths)),
                                    widths)):
        p[f"enc{i}"] = _conv_init(kk, 1, 1, out_ch, c, dtype)
    return p


@functools.partial(jax.jit,
                   static_argnames=("decomposed", "backend", "interpret",
                                    "compute_dtype"))
def denoise(params: dict, x_t: jax.Array, t: jax.Array,
            decomposed: bool = True, backend: str = "xla",
            interpret: bool | None = None,
            compute_dtype: str | None = None) -> jax.Array:
    """Predict the noise in ``x_t`` (N, S, S, C) at timesteps ``t`` (N,).

    ``S`` must be ``hw * 2**levels`` for the decoder's mid extent ``hw``
    (pooling factors are derived from the shapes).  Returns (N, S, S, C).
    """
    levels = sum(1 for k in params if k.startswith("enc"))
    s = x_t.shape[1]
    hw = s >> levels
    if compute_dtype is not None:
        from repro.kernels.util import canon_dtype

        x_t = x_t.astype(canon_dtype(compute_dtype))
    emb = timestep_embedding(t, params["t_w1"].shape[0])
    # cast the fp32 MLP masters down to x_t's dtype: with a bf16 x_t a
    # bf16 @ fp32 matmul would silently promote cond (and then mid) to fp32
    cond = (jnp.tanh(emb.astype(x_t.dtype) @ params["t_w1"].astype(x_t.dtype))
            @ params["t_w2"].astype(x_t.dtype))
    kw = dict(backend=backend, interpret=interpret,
              compute_dtype=compute_dtype)
    mid = conv2d(_avg_pool(x_t, s // hw), params["stem"], **kw)
    mid = mid + cond[:, None, None, :]
    skips = tuple(
        conv2d(_avg_pool(x_t, s // (hw * 2 ** i)), params[f"enc{i}"], **kw)
        for i in range(levels))
    return forward(params["dec"], mid, skips, decomposed=decomposed,
                   backend=backend, interpret=interpret,
                   compute_dtype=compute_dtype)
