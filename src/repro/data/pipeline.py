"""Deterministic, host-sharded synthetic data pipelines.

Real-cluster shape: each host produces only its addressable shard of the
global batch (``process_index / process_count``), batches are a pure function
of ``(seed, step)`` so restarts and elastic re-sharding reproduce the exact
token stream — the property checkpoint-resume tests rely on.  A background
prefetch thread keeps ``prefetch`` batches ready.
"""

from __future__ import annotations

import queue
import threading

import jax
import numpy as np


class LMDataPipeline:
    """Synthetic LM token stream: (tokens, labels, mask) of (B, S) int32."""

    def __init__(self, global_batch: int, seq_len: int, vocab: int,
                 seed: int = 0, prefetch: int = 2,
                 process_index: int | None = None,
                 process_count: int | None = None):
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.vocab = vocab
        self.seed = seed
        self.pidx = jax.process_index() if process_index is None else process_index
        self.pcount = jax.process_count() if process_count is None else process_count
        assert global_batch % self.pcount == 0
        self.local_batch = global_batch // self.pcount
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Pure function of (seed, step, process) — restart-reproducible."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.pidx]))
        toks = rng.integers(0, self.vocab,
                            (self.local_batch, self.seq_len + 1),
                            dtype=np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((self.local_batch, self.seq_len), np.float32),
        }

    def _producer(self):
        while not self._stop.is_set():
            batch = self.batch_at(self._step)
            try:
                self._q.put((self._step, batch), timeout=1.0)
                self._step += 1
            except queue.Full:
                continue

    def __next__(self):
        return self._q.get()

    def seek(self, step: int):
        """Restart the stream at ``step`` (checkpoint resume)."""
        self._stop.set()
        self._thread.join()
        while not self._q.empty():
            self._q.get_nowait()
        self._step = step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def close(self):
        self._stop.set()


class SegDataPipeline:
    """Synthetic Cityscapes-like segmentation batches for ENet."""

    def __init__(self, batch: int, hw: int = 512, classes: int = 19,
                 seed: int = 0):
        self.batch, self.hw, self.classes, self.seed = batch, hw, classes, seed

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        img = rng.normal(size=(self.batch, self.hw, self.hw, 3)
                         ).astype(np.float32)
        # piecewise-constant label regions (more segmentation-like than iid);
        # region size shrinks with hw so tiny debug inputs still get labels,
        # and the cell count ceils so non-multiples of 32 cover the full map
        cell = min(32, self.hw)
        n_cells = -(-self.hw // cell)
        coarse = rng.integers(0, self.classes, (self.batch, n_cells, n_cells))
        lbl = np.repeat(np.repeat(coarse, cell, axis=1), cell, axis=2)
        return {"image": img, "label": lbl[:, :self.hw, :self.hw].astype(np.int32)}
