from repro.data.pipeline import LMDataPipeline, SegDataPipeline

__all__ = ["LMDataPipeline", "SegDataPipeline"]
