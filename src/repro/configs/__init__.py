"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Every assigned architecture has a module with ``config()`` (the exact
published configuration) and ``reduced()`` (a tiny same-family config for CPU
smoke tests).  ``enet`` is the paper's own workload.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "whisper-small": "repro.configs.whisper_small",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).config()


def get_reduced(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).reduced()
