"""Gemma-3-12B [hf:google/gemma-3-12b-pt; unverified].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144; 5:1 local:global
sliding-window pattern (window 1024), head_dim=256 explicit, tied embeddings,
128k context.  Runs the long_500k cell: 5/6 of layers hold only a
1024-entry ring KV.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense",
        num_layers=48, d_model=3840, num_heads=16, kv_heads=8, head_dim=256,
        d_ff=15360, vocab=262144, window=1024, rope_theta=1e6,
        tie_embeddings=True, qk_norm=True,
        block_pattern=("attn_local", "attn_local", "attn_local",
                       "attn_local", "attn_local", "attn"),
        supports_long_context=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-reduced", family="dense",
        num_layers=6, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, window=16, tie_embeddings=True, qk_norm=True,
        block_pattern=("attn_local", "attn_local", "attn_local",
                       "attn_local", "attn_local", "attn"),
        supports_long_context=True, remat=False,
    )
