"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1 with a
shared expert (Llama-4 MoE = 1 shared + 16 routed, top-1), early fusion.
"""

from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        num_layers=48, d_model=5120, num_heads=40, kv_heads=8, head_dim=128,
        d_ff=0, vocab=202048, rope_theta=5e5,
        moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192,
                      shared_expert_ff=8192),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e-reduced", family="moe",
        num_layers=2, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=0, vocab=256,
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=96,
                      shared_expert_ff=96, group_size=64),
        remat=False,
    )
