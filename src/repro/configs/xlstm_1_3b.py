"""xLSTM-1.3B [arXiv:2405.04517; unverified].

48 blocks, d_model=2048, 4 heads, vocab=50304, d_ff=0 (xLSTM blocks carry
their own projections: mLSTM up-projects 2x, sLSTM has a 4/3 FFN).
Alternating mLSTM/sLSTM pattern.  Recurrent state -> runs the long_500k cell.
"""

from repro.models.config import ModelConfig, XLSTMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        num_layers=48, d_model=2048, num_heads=4, kv_heads=4, head_dim=512,
        d_ff=0, vocab=50304,
        block_pattern=("mlstm", "slstm"),
        xlstm=XLSTMConfig(),
        supports_long_context=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b-reduced", family="ssm",
        num_layers=4, d_model=64, num_heads=2, kv_heads=2, head_dim=32,
        d_ff=0, vocab=256,
        block_pattern=("mlstm", "slstm"),
        xlstm=XLSTMConfig(),
        supports_long_context=True, remat=False,
    )
