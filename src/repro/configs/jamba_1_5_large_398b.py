"""Jamba-1.5-Large (398B total / 94B active) [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Mamba:attention 7:1 interleave (attention at position 3 of each 8-layer
period), MoE every second layer, no positional embeddings (NoPE).
"""

from repro.models.config import MambaConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        num_layers=72, d_model=8192, num_heads=64, kv_heads=8, head_dim=128,
        d_ff=24576, vocab=65536, rope=False,
        block_pattern=("mamba", "mamba", "mamba", "attn",
                       "mamba", "mamba", "mamba", "mamba"),
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                      every_n_layers=2),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        supports_long_context=True,
        # 398B fp32 Adam state cannot fit a single 256-chip v5e pod; bf16
        # moments + no fp32 master (6 B/param) keep the train cell resident.
        opt_memory_mode="bf16",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-reduced", family="hybrid",
        num_layers=8, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, rope=False,
        block_pattern=("mamba", "mamba", "mamba", "attn",
                       "mamba", "mamba", "mamba", "mamba"),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=96,
                      every_n_layers=2, group_size=64),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
        supports_long_context=True, remat=False,
    )
