"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b; unverified].

24L d_model=2048 32H (MHA, kv=32) d_ff=5632 vocab=100352.
Adaptation noted in DESIGN.md: full rotary instead of partial (25 %).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense",
        num_layers=24, d_model=2048, num_heads=32, kv_heads=32,
        d_ff=5632, vocab=100352,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b-reduced", family="dense",
        num_layers=2, d_model=64, num_heads=4, kv_heads=4,
        d_ff=128, vocab=256, remat=False,
    )
