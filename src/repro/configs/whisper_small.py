"""Whisper-small [arXiv:2212.04356; unverified].

12L encoder + 12L decoder, d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
Conv frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed (B, 1500, 768) frame embeddings.  Adaptations recorded in
DESIGN.md: rotary decoder positions and SwiGLU FFN in place of Whisper's
learned positions / GELU (structure-preserving; parameter shapes match).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        num_layers=12, d_model=768, num_heads=12, kv_heads=12,
        d_ff=3072, vocab=51865, encoder_layers=12, encoder_ctx=1500,
        supports_long_context=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-reduced", family="audio",
        num_layers=2, d_model=64, num_heads=4, kv_heads=4,
        d_ff=128, vocab=256, encoder_layers=2, encoder_ctx=32,
        remat=False,
    )
