"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) expert d_ff=768 vocab=151936, MoE 128e top-8.
head_dim=128 explicit (Qwen3 projects 2048 -> 32*128) and qk-norm per Qwen3.
"""

from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=32, kv_heads=4, head_dim=128,
        d_ff=0, vocab=151936, qk_norm=True, rope_theta=1e6,
        moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b-reduced", family="moe",
        num_layers=4, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=0, vocab=256, qk_norm=True,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96, group_size=64),
        remat=False,
    )
