"""Chameleon-34B [arXiv:2405.09818; unverified].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536; early-fusion VLM —
VQ image tokens share the text vocabulary, so the modality frontend is a
STUB per the assignment (``input_specs`` supplies mixed token ids).
qk-norm per the Chameleon paper (their training-stability fix).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        num_layers=48, d_model=8192, num_heads=64, kv_heads=8, head_dim=128,
        d_ff=22016, vocab=65536, qk_norm=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-reduced", family="vlm",
        num_layers=2, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, qk_norm=True, remat=False,
    )
