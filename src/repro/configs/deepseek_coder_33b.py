"""DeepSeek-Coder-33B [arXiv:2401.14196].

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256 (llama architecture).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b", family="dense",
        num_layers=62, d_model=7168, num_heads=56, kv_heads=8, head_dim=128,
        d_ff=19200, vocab=32256, rope_theta=1e5,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b-reduced", family="dense",
        num_layers=2, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, remat=False,
    )
