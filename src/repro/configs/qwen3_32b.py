"""Qwen3-32B [hf:Qwen/Qwen3-32B].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, qk-norm,
head_dim=128 explicit.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        num_layers=64, d_model=5120, num_heads=64, kv_heads=8, head_dim=128,
        d_ff=25600, vocab=151936, qk_norm=True, rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b-reduced", family="dense",
        num_layers=2, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, qk_norm=True, remat=False,
    )
