"""Decomposed dilated-convolution Pallas pipeline (paper §II-B, Fig. 4/8).

TPU-native execution of the paper's input decomposition: the ``d**2`` phase
blocks are stacked on the *batch* axis by a pure layout transform (XLA
reshape/transpose — no FLOPs), then ONE dense Pallas convolution processes
all phases at full MXU occupancy, and the outputs interleave back.  This is
the phase-batched strategy recorded as a beyond-paper optimization in
DESIGN.md §2b: where the paper schedules ragged blocks sequentially on PE
blocks, a wide MXU prefers a single batched dense conv.

The dense conv is the :mod:`repro.kernels.conv2d` Pallas kernel, so the whole
dilated path runs through the same engine the paper's hardware would use.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.conv2d import conv2d as _dense_conv


@functools.partial(jax.jit, static_argnames=("dilation", "th", "tc", "interpret"))
def dilated_conv2d(x: jax.Array, w: jax.Array, dilation: int, *, th: int = 8,
                   tc: int = 128, interpret: bool = True) -> jax.Array:
    """SAME dilated convolution via phase decomposition + dense Pallas conv.

    Args:
      x: (N, H, W, Cin).   w: (k, k, Cin, Cout) compact kernel.
      dilation: step d = D + 1.
    Returns:
      (N, H, W, Cout).
    """
    d = dilation
    n, h, w_in, cin = x.shape
    cout = w.shape[-1]
    if d == 1:
        return _dense_conv(x, w, padding="SAME", th=th, tc=tc,
                           interpret=interpret)

    hp, wp = math.ceil(h / d) * d, math.ceil(w_in / d) * d
    xpad = jnp.pad(x, ((0, 0), (0, hp - h), (0, wp - w_in), (0, 0)))
    # phases -> batch: (N, H/d, d, W/d, d, C) -> (d*d*N, H/d, W/d, C)
    xb = xpad.reshape(n, hp // d, d, wp // d, d, cin)
    xb = xb.transpose(2, 4, 0, 1, 3, 5).reshape(d * d * n, hp // d, wp // d, cin)

    yb = _dense_conv(xb, w, padding="SAME", th=th, tc=tc, interpret=interpret)

    # batch -> phases, then interleave and crop the pad-up rows/cols
    yb = yb.reshape(d, d, n, hp // d, wp // d, cout)
    y = yb.transpose(2, 3, 0, 4, 1, 5).reshape(n, hp, wp, cout)
    return y[:, :h, :w_in, :]
