"""Decomposed dilated-convolution Pallas pipeline (paper §II-B, Fig. 4/8).

TPU-native execution of the paper's input decomposition: the ``d**2`` phase
blocks are stacked on the *batch* axis by a pure layout transform (XLA
reshape/transpose — no FLOPs), then ONE dense Pallas convolution processes
all phases at full MXU occupancy, and the outputs interleave back.  This is
the phase-batched strategy recorded as a beyond-paper optimization in
DESIGN.md §2b: where the paper schedules ragged blocks sequentially on PE
blocks, a wide MXU prefers a single batched dense conv.

``stride > 1`` generalizes the same pipeline: outputs group into
``(d/gcd(s,d))**2`` classes (see :func:`repro.core.dilated.stride_class_schedule`),
each class's phase window is extracted by a layout slice, and all class
windows batch into ONE strided VALID Pallas convolution.

The dense conv is the :mod:`repro.kernels.conv2d` Pallas kernel, so the whole
dilated path runs through the same engine the paper's hardware would use.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.conv2d import conv2d as _dense_conv
from repro.kernels.util import resolve_interpret


@functools.partial(jax.jit,
                   static_argnames=("dilation", "stride", "th", "tc", "interpret"))
def dilated_conv2d(x: jax.Array, w: jax.Array, dilation: int, *,
                   stride: int = 1, th: int = 8, tc: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    """SAME dilated convolution via phase decomposition + dense Pallas conv.

    Differentiable on all paths: the stride-1 path registers a
    ``jax.custom_vjp`` exploiting the adjoint symmetry — the input-gradient
    of a dilated conv is the same dilated conv with the flipped kernel, so
    it re-enters this engine; the weight-gradient is a tap-gather correlation
    at step ``d`` (:mod:`repro.core.adjoints`, DESIGN.md §6).  The ``d = 1``
    and strided paths are compositions over the dense Pallas kernel and
    differentiate through its VJP.

    Args:
      x: (N, H, W, Cin).   w: (k, k, Cin, Cout) compact kernel.
      dilation: step d = D + 1.
      stride: output stride s (output extent ``ceil(H/s)``).
      interpret: None -> auto (interpret on CPU), or an explicit override.
    Returns:
      (N, ceil(H/s), ceil(W/s), Cout).
    """
    interpret = resolve_interpret(interpret)
    d, s = dilation, stride
    if d == 1:
        return _dense_conv(x, w, stride=s, padding="SAME", th=th, tc=tc,
                           interpret=interpret)
    if s != 1:
        return _strided(x, w, d, s, th=th, tc=tc, interpret=interpret)
    if w.shape[0] % 2 == 0:
        # even kernels pad SAME asymmetrically — the symmetry adjoint below
        # assumes odd-k symmetric padding, so differentiate compositionally
        # through the dense kernel's VJP instead
        return _dilated_impl(x, w, d, th, tc, interpret)
    return _dilated_vjp(x, w, d, th, tc, interpret)


def _dilated_impl(x: jax.Array, w: jax.Array, d: int, th: int, tc: int,
                  interpret: bool) -> jax.Array:
    n, h, w_in, cin = x.shape
    cout = w.shape[-1]
    hp, wp = math.ceil(h / d) * d, math.ceil(w_in / d) * d
    xpad = jnp.pad(x, ((0, 0), (0, hp - h), (0, wp - w_in), (0, 0)))
    # phases -> batch: (N, H/d, d, W/d, d, C) -> (d*d*N, H/d, W/d, C)
    xb = xpad.reshape(n, hp // d, d, wp // d, d, cin)
    xb = xb.transpose(2, 4, 0, 1, 3, 5).reshape(d * d * n, hp // d, wp // d, cin)

    yb = _dense_conv(xb, w, padding="SAME", th=th, tc=tc, interpret=interpret)

    # batch -> phases, then interleave and crop the pad-up rows/cols
    yb = yb.reshape(d, d, n, hp // d, wp // d, cout)
    y = yb.transpose(2, 3, 0, 4, 1, 5).reshape(n, hp, wp, cout)
    return y[:, :h, :w_in, :]


# ---------------------------------------------------------------------------
# Custom VJP (DESIGN.md §6): the input-gradient of a SAME dilated conv IS the
# same dilated conv with the flipped kernel — the adjoint re-enters this
# engine; the weight-gradient gathers taps at step ``d`` (one phase block
# per tap) and contracts on the MXU.
# ---------------------------------------------------------------------------

_dilated_vjp = jax.custom_vjp(_dilated_impl, nondiff_argnums=(2, 3, 4, 5))


def _dilated_fwd(x, w, d, th, tc, interpret):
    return _dilated_impl(x, w, d, th, tc, interpret), (x, w)


def _dilated_bwd(d, th, tc, interpret, res, g):
    from repro.core import adjoints

    x, w = res

    def dilated_fn(gg, wf, dd):
        return _dilated_impl(gg, wf, dd, th, tc, interpret)

    dx = adjoints.dilated_conv_dx(g, w, d, dilated_fn)
    dw = adjoints.dilated_conv_dw(x, g, w.shape[0], d)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_dilated_vjp.defvjp(_dilated_fwd, _dilated_bwd)


def _strided(x: jax.Array, w: jax.Array, d: int, s: int, *, th: int, tc: int,
             interpret: bool) -> jax.Array:
    """Class-batched strided-dilated path: q*q class windows, ONE strided conv.

    Shares the schedule/window/stitch implementation with the XLA path —
    only the dense conv engine differs.
    """
    from repro.core.dilated import _dilated_strided_decomposed

    def conv_fn(xb, wt, sb):
        return _dense_conv(xb, wt, stride=sb, padding="VALID", th=th, tc=tc,
                           interpret=interpret)

    return _dilated_strided_decomposed(x, w, d, s, "batched", conv_fn)
