"""Decomposed dilated-convolution Pallas pipeline (paper §II-B, Fig. 4/8).

TPU-native execution of the paper's input decomposition: the ``d**2`` phase
blocks are stacked on the *batch* axis by a pure layout transform (XLA
reshape/transpose — no FLOPs), then ONE dense Pallas convolution processes
all phases at full MXU occupancy, and the outputs interleave back.  This is
the phase-batched strategy recorded as a beyond-paper optimization in
DESIGN.md §2b: where the paper schedules ragged blocks sequentially on PE
blocks, a wide MXU prefers a single batched dense conv.

``stride > 1`` generalizes the same pipeline: outputs group into
``(d/gcd(s,d))**2`` classes (see :func:`repro.core.dilated.stride_class_schedule`),
each class's phase window is extracted by a layout slice, and all class
windows batch into ONE strided VALID Pallas convolution.

The dense conv is the :mod:`repro.kernels.conv2d` Pallas kernel, so the whole
dilated path runs through the same engine the paper's hardware would use.
Fused epilogues (DESIGN.md §7) ride the same pipeline: because the phase
transform is a pure relabeling of output pixels, the per-channel BN/PReLU
ops commute with it, and the residual is carried through the *same* phase
transform so the add happens inside the dense kernel.  The strided
output-class path applies the epilogue after the stitch instead — its class
windows have uneven output extents, so a per-window residual transform
would not be a pure relabeling (recorded fallback, numerics identical).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.conv2d import conv2d as _dense_conv
from repro.kernels.epilogue import EpilogueSpec, apply_reference, pack_args
from repro.kernels.util import resolve_interpret

_NO_EP = EpilogueSpec()


@functools.partial(jax.jit,
                   static_argnames=("dilation", "stride", "th", "tc",
                                    "interpret", "epilogue"))
def dilated_conv2d(x: jax.Array, w: jax.Array, dilation: int, *,
                   stride: int = 1, th: int = 8, tc: int = 128,
                   interpret: bool | None = None,
                   epilogue: EpilogueSpec | None = None,
                   scale: jax.Array | None = None,
                   shift: jax.Array | None = None,
                   alpha: jax.Array | None = None,
                   residual: jax.Array | None = None) -> jax.Array:
    """SAME dilated convolution via phase decomposition + dense Pallas conv.

    Differentiable on all paths: the stride-1 path registers a
    ``jax.custom_vjp`` exploiting the adjoint symmetry — the input-gradient
    of a dilated conv is the same dilated conv with the flipped kernel, so
    it re-enters this engine; the weight-gradient is a tap-gather correlation
    at step ``d`` (:mod:`repro.core.adjoints`, DESIGN.md §6).  The ``d = 1``
    and strided paths are compositions over the dense Pallas kernel and
    differentiate through its VJP — as does the fused-epilogue path, whose
    epilogue runs inside the dense kernel on the phase-batched layout.

    Args:
      x: (N, H, W, Cin).   w: (k, k, Cin, Cout) compact kernel.
      dilation: step d = D + 1.
      stride: output stride s (output extent ``ceil(H/s)``).
      interpret: None -> auto (interpret on CPU), or an explicit override.
      epilogue: optional :class:`EpilogueSpec` (DESIGN.md §7) with operands
        ``scale``/``shift``/``alpha``/``residual`` to match.
    Returns:
      (N, ceil(H/s), ceil(W/s), Cout).
    """
    interpret = resolve_interpret(interpret)
    d, s = dilation, stride
    spec = _NO_EP if epilogue is None else epilogue
    eps = pack_args(spec, scale=scale, shift=shift, alpha=alpha,
                    residual=residual)
    ep_kw = dict(zip(spec.slots, eps))
    if d == 1:
        return _dense_conv(x, w, stride=s, padding="SAME", th=th, tc=tc,
                           interpret=interpret, epilogue=epilogue, **ep_kw)
    if s != 1:
        y = _strided(x, w, d, s, th=th, tc=tc, interpret=interpret)
        return apply_reference(spec, y, eps)
    if not spec.empty or w.shape[0] % 2 == 0:
        # the fused-epilogue path composes through the dense kernel's
        # epilogue VJP; even kernels pad SAME asymmetrically — the symmetry
        # adjoint below assumes odd-k symmetric padding, so they too
        # differentiate compositionally through the dense kernel's VJP
        return _dilated_impl(x, w, d, th, tc, interpret, spec=spec, eps=eps)
    return _dilated_vjp(x, w, d, th, tc, interpret)


def _phase_to_batch(x: jax.Array, d: int) -> jax.Array:
    """Pad H, W to multiples of ``d`` and stack phases on the batch axis."""
    n, h, w_in, c = x.shape
    hp, wp = math.ceil(h / d) * d, math.ceil(w_in / d) * d
    xpad = jnp.pad(x, ((0, 0), (0, hp - h), (0, wp - w_in), (0, 0)))
    xb = xpad.reshape(n, hp // d, d, wp // d, d, c)
    return xb.transpose(2, 4, 0, 1, 3, 5).reshape(d * d * n, hp // d,
                                                  wp // d, c)


def _dilated_impl(x: jax.Array, w: jax.Array, d: int, th: int, tc: int,
                  interpret: bool, spec: EpilogueSpec = _NO_EP,
                  eps: tuple = ()) -> jax.Array:
    n, h, w_in, cin = x.shape
    cout = w.shape[-1]
    hp, wp = math.ceil(h / d) * d, math.ceil(w_in / d) * d
    # phases -> batch: (N, H/d, d, W/d, d, C) -> (d*d*N, H/d, W/d, C)
    xb = _phase_to_batch(x, d)

    # per-channel epilogue ops commute with the phase relabeling; the
    # residual rides the identical transform so the add fuses in-kernel
    # (its zero pad-up rows land in the cropped region below)
    ep_kw = dict(zip(spec.slots, eps))
    if "residual" in ep_kw:
        ep_kw["residual"] = _phase_to_batch(ep_kw["residual"], d)
    yb = _dense_conv(xb, w, padding="SAME", th=th, tc=tc, interpret=interpret,
                     epilogue=spec if not spec.empty else None, **ep_kw)

    # batch -> phases, then interleave and crop the pad-up rows/cols
    yb = yb.reshape(d, d, n, hp // d, wp // d, cout)
    y = yb.transpose(2, 3, 0, 4, 1, 5).reshape(n, hp, wp, cout)
    return y[:, :h, :w_in, :]


# ---------------------------------------------------------------------------
# Custom VJP (DESIGN.md §6): the input-gradient of a SAME dilated conv IS the
# same dilated conv with the flipped kernel — the adjoint re-enters this
# engine; the weight-gradient gathers taps at step ``d`` (one phase block
# per tap) and contracts on the MXU.
# ---------------------------------------------------------------------------

def _dilated_plain(x, w, d, th, tc, interpret):
    # custom_vjp binds default kwargs as operands — keep the vjp'd function's
    # signature free of the epilogue extras
    return _dilated_impl(x, w, d, th, tc, interpret)


_dilated_vjp = jax.custom_vjp(_dilated_plain, nondiff_argnums=(2, 3, 4, 5))


def _dilated_fwd(x, w, d, th, tc, interpret):
    return _dilated_impl(x, w, d, th, tc, interpret), (x, w)


def _dilated_bwd(d, th, tc, interpret, res, g):
    from repro.core import adjoints

    x, w = res

    def dilated_fn(gg, wf, dd):
        return _dilated_impl(gg, wf, dd, th, tc, interpret)

    dx = adjoints.dilated_conv_dx(g, w, d, dilated_fn)
    dw = adjoints.dilated_conv_dw(x, g, w.shape[0], d)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_dilated_vjp.defvjp(_dilated_fwd, _dilated_bwd)


def _strided(x: jax.Array, w: jax.Array, d: int, s: int, *, th: int, tc: int,
             interpret: bool) -> jax.Array:
    """Class-batched strided-dilated path: q*q class windows, ONE strided conv.

    Shares the schedule/window/stitch implementation with the XLA path —
    only the dense conv engine differs.
    """
    from repro.core.dilated import _dilated_strided_decomposed

    def conv_fn(xb, wt, sb):
        return _dense_conv(xb, wt, stride=sb, padding="VALID", th=th, tc=tc,
                           interpret=interpret)

    return _dilated_strided_decomposed(x, w, d, s, "batched", conv_fn)
