"""MXU-tiled matmul Pallas kernel (LM MLP/projection hot-spot).

Classic three-level tiling: grid ``(M/TM, N/TN, K/TK)`` with the K dimension
innermost (sequential on TPU) accumulating into a VMEM f32 scratch; the
output block is written on the last K step.  Tiles default to MXU-aligned
(128) and are clamped for small shapes.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import resolve_interpret


def _mm_kernel(a, b, out, acc):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        a[...], b[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        out[...] = acc[...].astype(out.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, tm: int = 128, tn: int = 128,
           tk: int = 128, interpret: bool | None = None) -> jax.Array:
    """(M, K) @ (K, N) -> (M, N) with f32 accumulation."""
    interpret = resolve_interpret(interpret)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    tm, tn, tk = min(tm, m), min(tn, n), min(tk, k)
    mp, np_, kp = (math.ceil(m / tm) * tm, math.ceil(n / tn) * tn,
                   math.ceil(k / tk) * tk)
    ap = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    bp = jnp.pad(b, ((0, kp - k), (0, np_ - n)))

    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // tm, np_ // tn, kp // tk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, l: (i, l)),
            pl.BlockSpec((tk, tn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        # f32 accumulator lives across the sequential K loop
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]
