"""Shared helpers for the Pallas kernel package."""

from __future__ import annotations

import functools

import jax


@functools.lru_cache(maxsize=1)
def default_backend() -> str:
    """The JAX default backend platform, probed once per process.

    ``jax.default_backend()`` walks the live backend registry; every kernel
    call funnels through :func:`resolve_interpret`, so the probe is memoized
    (the attached backend cannot change within a process).
    """
    return jax.default_backend()


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the ``interpret`` flag for a Pallas kernel.

    ``None`` (the default everywhere in this package) auto-detects: interpret
    mode on CPU hosts, compiled kernels whenever a real accelerator backend is
    attached.  Pass an explicit bool to override (e.g. ``interpret=True`` to
    debug a kernel on TPU, or ``False`` to assert compilation).
    """
    if interpret is None:
        return default_backend() == "cpu"
    return interpret
