"""Shared helpers for the Pallas kernel package."""

from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the ``interpret`` flag for a Pallas kernel.

    ``None`` (the default everywhere in this package) auto-detects: interpret
    mode on CPU hosts, compiled kernels whenever a real accelerator backend is
    attached.  Pass an explicit bool to override (e.g. ``interpret=True`` to
    debug a kernel on TPU, or ``False`` to assert compilation).
    """
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret
