"""Shared helpers for the Pallas kernel package."""

from __future__ import annotations

import functools
import time

import jax


@functools.lru_cache(maxsize=1)
def default_backend() -> str:
    """The JAX default backend platform, probed once per process.

    ``jax.default_backend()`` walks the live backend registry; every kernel
    call funnels through :func:`resolve_interpret`, so the probe is memoized
    (the attached backend cannot change within a process).
    """
    return jax.default_backend()


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the ``interpret`` flag for a Pallas kernel.

    ``None`` (the default everywhere in this package) auto-detects: interpret
    mode on CPU hosts, compiled kernels whenever a real accelerator backend is
    attached.  Pass an explicit bool to override (e.g. ``interpret=True`` to
    debug a kernel on TPU, or ``False`` to assert compilation).
    """
    if interpret is None:
        return default_backend() == "cpu"
    return interpret


#: accepted spellings of the mixed-precision compute dtypes (DESIGN.md §12)
_DTYPE_ALIASES = {
    "bf16": "bfloat16", "bfloat16": "bfloat16",
    "fp32": "float32", "f32": "float32", "float32": "float32",
    "fp16": "float16", "f16": "float16", "float16": "float16",
}


def canon_dtype(compute_dtype):
    """Canonicalise a ``compute_dtype`` argument to a jnp dtype (or None).

    Accepts ``None`` (keep the input dtype), a dtype object, or a string
    alias (``"bf16"``/``"bfloat16"``/``"fp32"``/...).  Strings are the form
    that rides jit ``static_argnames`` through the model forwards, so the
    aliases are resolved here, once, for every consumer.
    """
    import jax.numpy as jnp

    if compute_dtype is None:
        return None
    if isinstance(compute_dtype, str):
        alias = _DTYPE_ALIASES.get(compute_dtype.lower())
        if alias is None:
            raise ValueError(f"unknown compute_dtype {compute_dtype!r}; "
                             f"known: {sorted(set(_DTYPE_ALIASES))}")
        return jnp.dtype(alias)
    return jnp.dtype(compute_dtype)


def time_call(fn, *args, iters: int = 5, warmup: int = 1) -> float:
    """Best-of-``iters`` wall time (seconds) of ``fn(*args)``.

    The single timing harness shared by the autotune sweep, the kernel
    microbenchmarks and the calibration capture, so every timed region obeys
    the same two rules:

    * the result is materialised via ``jax.block_until_ready`` INSIDE the
      timed region — jax dispatch is asynchronous, so returning at launch
      would record launch latency as kernel runtime;
    * the estimator is the minimum, not the mean: on shared/loaded hosts the
      distribution has a long right tail of scheduler noise and the minimum
      is the stable estimator of the actual cost.

    ``warmup`` untimed calls run first (compile + cache effects).
    """
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        t1 = time.perf_counter()
        best = min(best, t1 - t0)
    return best
