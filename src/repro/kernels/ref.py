"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

_DIMS = ("NHWC", "HWIO", "NHWC")


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1,
               padding: str | int = "SAME") -> jax.Array:
    """Dense 2-D convolution oracle. NHWC x HWIO -> NHWC, f32 accumulation."""
    if isinstance(padding, int):
        pad = [(padding, padding), (padding, padding)]
    elif padding == "SAME":
        kh, kw = w.shape[0], w.shape[1]
        pad = [((kh - 1) // 2, kh // 2), ((kw - 1) // 2, kw // 2)]
    else:
        pad = padding
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=pad,
        dimension_numbers=_DIMS, preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def dilated_conv2d_ref(x: jax.Array, w: jax.Array, dilation: int) -> jax.Array:
    """SAME dilated convolution oracle (rhs_dilation)."""
    k = w.shape[0]
    pad = (dilation * (k - 1)) // 2
    return lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=[(pad, pad), (pad, pad)],
        rhs_dilation=(dilation, dilation), dimension_numbers=_DIMS,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def transposed_conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 2,
                          padding: int = 1, output_padding: int = 1) -> jax.Array:
    """Transposed convolution oracle (lhs_dilation)."""
    p_lo, p_hi = padding, padding + output_padding
    return lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=[(p_lo, p_hi), (p_lo, p_hi)],
        lhs_dilation=(stride, stride), dimension_numbers=_DIMS,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """(B, H, S, D) attention oracle with f32 softmax."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
