"""Fused elementwise epilogues for the decomposition engine (DESIGN.md §7).

Every ENet bottleneck / ESP module used to pay three extra elementwise HBM
passes after each fused convolution: BN scale/shift, PReLU, and a residual
add.  The decomposed kernels are compute-lean enough that those passes
dominate the memory roofline — so they are applied *inside* the Pallas
kernels, on the fp32 accumulator tile while it is still in VMEM.

An :class:`EpilogueSpec` is a small frozen (hashable — it rides the
``static_argnames`` of the jitted kernel wrappers) description of *which*
ops run and in what order::

    y = conv(x, w)                       # fp32 accumulator tile
    y = y * scale + shift                if spec.bn        (folded BN)
    y = y + residual                     if spec.residual == "pre_act"
    y = where(y >= 0, y, alpha * y)      if spec.prelu
    y = y + residual                     if spec.residual == "post_act"

The operand *arrays* (``scale``/``shift`` per ``Cout`` channel, ``alpha``
scalar or per-channel, ``residual`` with the output's NHWC shape) travel as
ordinary traced inputs packed by :func:`pack_args`; the spec decides which
slots exist, so each (spec, shape) pair compiles exactly the operands it
needs.

BN is *folded*: scale/shift are a single multiply-add, computed from the BN
parameters (and, at inference, running statistics) by
``repro.models.common.fold_bn`` — batch-statistics normalisation cannot be
fused into a single output pass because the statistics are a function of the
very output being produced.

:func:`apply_reference` is the unfused oracle — the XLA backend uses it
post-conv, the fused kernels' VJPs differentiate through it
(``adjoints.fused_epilogue_bwd``), and the parity tests pin
``fused kernel == unfused kernel + apply_reference``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

#: residual placement values
_RESIDUAL = ("none", "pre_act", "post_act")


@dataclasses.dataclass(frozen=True)
class EpilogueSpec:
    """Static description of a fused epilogue (hashable: jit-static)."""

    bn: bool = False            # folded BN: y * scale + shift
    prelu: bool = False         # PReLU with learnable slope alpha
    residual: str = "none"      # "none" | "pre_act" | "post_act"

    def __post_init__(self):
        if self.residual not in _RESIDUAL:
            raise ValueError(f"residual must be one of {_RESIDUAL}, "
                             f"got {self.residual!r}")

    @property
    def empty(self) -> bool:
        return not (self.bn or self.prelu or self.residual != "none")

    @property
    def slots(self) -> tuple[str, ...]:
        """Operand slot names, in packing order."""
        out = []
        if self.bn:
            out += ["scale", "shift"]
        if self.prelu:
            out.append("alpha")
        if self.residual != "none":
            out.append("residual")
        return tuple(out)


def fingerprint(spec: EpilogueSpec | None) -> str:
    """Compact cache-key tag of an epilogue configuration.

    The fused operands change the kernel's VMEM footprint (a residual
    streams a second output-shaped block), so autotune winners are only
    valid for the configuration they were timed with —
    ``autotune.make_key`` folds this tag into the cache key.  ``None`` and
    the empty spec share the tag ``"none"``; anything else is distinct per
    ``(bn, prelu, residual)``.
    """
    if spec is None or spec.empty:
        return "none"
    return f"bn{int(spec.bn)}.pr{int(spec.prelu)}.res-{spec.residual}"


def pack_args(spec: EpilogueSpec, *, scale=None, shift=None, alpha=None,
              residual=None) -> tuple[jax.Array, ...]:
    """Collect the operand arrays a spec needs into its canonical tuple.

    Raises if a required operand is missing or a superfluous one is given —
    the spec is the single source of truth for what the kernel receives.
    """
    given = {"scale": scale, "shift": shift, "alpha": alpha,
             "residual": residual}
    for name, v in given.items():
        if (name in spec.slots) != (v is not None):
            need = "requires" if name in spec.slots else "does not take"
            raise ValueError(f"epilogue {spec} {need} operand {name!r}")
    return tuple(given[name] for name in spec.slots)


def _chanvec(v: jax.Array, cout: int) -> jax.Array:
    """Broadcast a scalar/per-channel epilogue operand to a (cout,) vector."""
    v = jnp.asarray(v, jnp.float32).reshape(-1)
    if v.shape[0] not in (1, cout):
        raise ValueError(f"epilogue channel operand has {v.shape[0]} entries, "
                         f"expected 1 or {cout}")
    return jnp.broadcast_to(v, (cout,))


def apply_reference(spec: EpilogueSpec, z: jax.Array,
                    args: tuple[jax.Array, ...]) -> jax.Array:
    """Unfused oracle: the epilogue as plain jnp ops on the conv output.

    Computes in fp32 (matching the fused kernels, which apply the epilogue
    on the fp32 accumulator before the output cast) and casts back to
    ``z.dtype``.
    """
    if spec.empty:
        return z
    it = iter(args)
    cout = z.shape[-1]
    y = z.astype(jnp.float32)
    if spec.bn:
        y = y * _chanvec(next(it), cout) + _chanvec(next(it), cout)
    if spec.prelu:
        alpha = _chanvec(next(it), cout)
        y_res = next(it).astype(jnp.float32) if spec.residual == "pre_act" \
            else None
        if y_res is not None:
            y = y + y_res
        y = jnp.where(y >= 0, y, alpha * y)
        if spec.residual == "post_act":
            y = y + next(it).astype(jnp.float32)
    elif spec.residual != "none":
        y = y + next(it).astype(jnp.float32)
    return y.astype(z.dtype)


def apply_tile(spec: EpilogueSpec, acc: jax.Array,
               refs: tuple, *, flat: int) -> jax.Array:
    """Apply the epilogue inside a Pallas kernel body.

    ``acc`` is the fp32 accumulator reshaped to ``(flat, tc)``; ``refs`` are
    the epilogue operand *blocks* in slot order — channel vectors arrive as
    ``(1, tc)`` tiles, the residual as a block reshapable to ``(flat, tc)``.
    """
    it = iter(refs)
    if spec.bn:
        acc = acc * next(it).reshape(1, -1) + next(it).reshape(1, -1)
    if spec.prelu:
        alpha = next(it).reshape(1, -1)
        if spec.residual == "pre_act":
            acc = acc + next(it).reshape(flat, -1).astype(jnp.float32)
        acc = jnp.where(acc >= 0, acc, alpha * acc)
        if spec.residual == "post_act":
            acc = acc + next(it).reshape(flat, -1).astype(jnp.float32)
    elif spec.residual != "none":
        acc = acc + next(it).reshape(flat, -1).astype(jnp.float32)
    return acc


__all__ = ["EpilogueSpec", "pack_args", "apply_reference", "apply_tile",
           "fingerprint"]
