"""Per-shape ``(th, tc)`` tile autotuning for the Pallas engines (DESIGN.md §7).

The kernels' tile shape used to be hard-coded at ``(th, tc) = (8, 128)``
regardless of layer geometry.  This module ranks a small candidate grid per
*(engine kind, input shape, kernel, stride, dilation, dtype, epilogue)* key
with the analytic policy (:mod:`repro.kernels.tiling_policy` — VMEM
footprint + MXU occupancy, DESIGN.md §12), times only the top few plus
``DEFAULT_TILES``, and caches the winner — in memory for the process, and
on disk so the cost is paid once per machine.  ``$REPRO_AUTOTUNE_SWEEP=1``
forces the old exhaustive timing of the whole grid.

Cache layout and invalidation (DESIGN.md §7):

* one JSON file per ``(device kind, jax version)`` —
  ``<cache dir>/<device_kind>-jax<version>-v<SCHEMA>.json`` — so a different
  accelerator, an upgraded jax, or a schema bump each start from a clean
  table rather than serving stale timings;
* the cache dir is ``$REPRO_AUTOTUNE_CACHE`` or ``~/.cache/repro-autotune``;
* entries map :func:`make_key` strings to ``[th, tc]`` pairs.

``get_tiles`` is wired into the dispatcher (``repro.core.decompose.conv2d``)
so every call site benefits transparently: a cache hit returns the tuned
tiles, a miss returns the defaults *without* sweeping unless autotuning is
switched on (``REPRO_AUTOTUNE=1``) — keeping cold-start latency and CI
determinism intact.  Sweeps can also be run ahead of time via :func:`tune`
(``benchmarks/kernel_bench.py`` does, and reports the tuned-vs-default
delta).
"""

from __future__ import annotations

import json
import os
import pathlib

import jax
import jax.numpy as jnp

DEFAULT_TILES = (8, 128)
#: schema 2: the fused-epilogue configuration joined the cache key — v1
#: tables conflated epilogue variants of the same geometry (wrong winners
#: for whichever configuration tuned second), so they must invalidate.
_SCHEMA = 2
#: how many analytically ranked candidates the default tune() times
#: (plus DEFAULT_TILES) — the policy replaces the exhaustive sweep
POLICY_TOP = 3
#: candidate grids — th rides the sublane axis, tc the 128-wide lane axis
TH_CANDIDATES = (4, 8, 16, 32)
TC_CANDIDATES = (64, 128, 256)
KINDS = ("dense", "dilated", "tconv")

_MEM: dict[str, tuple[int, int]] = {}
_DISK: dict[str, tuple[int, int]] | None = None


def autotune_enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "").lower() in ("1", "true", "on")


def _device_kind() -> str:
    try:
        kind = jax.devices()[0].device_kind
    except Exception:  # no backend at all — still allow cache-key formation
        kind = "unknown"
    return "".join(c if c.isalnum() else "_" for c in kind)


def cache_path() -> pathlib.Path:
    base = os.environ.get("REPRO_AUTOTUNE_CACHE")
    root = pathlib.Path(base) if base else (
        pathlib.Path.home() / ".cache" / "repro-autotune")
    return root / f"{_device_kind()}-jax{jax.__version__}-v{_SCHEMA}.json"


def make_key(kind: str, x_shape: tuple, w_shape: tuple, *, stride: int = 1,
             dilation: int = 1, dtype=jnp.float32, padding=None,
             output_padding: int | None = None, epilogue=None) -> str:
    """Canonical cache key for one kernel geometry.

    ``padding``/``output_padding`` are part of the geometry — they change
    the output extent and therefore the tiling.  ``None`` is *canonicalised*
    to the engine default (dense/dilated ``SAME``, tconv ``(k-1)//2`` and
    ``output_padding=1``) so the dispatcher's resolved values and an
    ahead-of-time ``tune()`` call with defaults produce the same key.

    ``epilogue`` is part of the key too: a fused residual streams a second
    output-shaped block through VMEM, so a winner timed without it is not
    a winner with it (the schema-2 bugfix — v1 keys conflated them).
    """
    from repro.kernels.epilogue import fingerprint

    if kind not in KINDS:
        raise ValueError(f"unknown engine kind {kind!r}")
    n, h, w, cin = x_shape
    kh, kw = w_shape[0], w_shape[1]
    cout = w_shape[3]
    if kind == "tconv":
        pad = (kh - 1) // 2 if padding is None else padding
        op = 1 if output_padding is None else output_padding
    else:
        pad = "SAME" if padding is None else padding
        op = 0      # forward convs have no output padding
    return (f"{kind}/n{n}x{h}x{w}x{cin}/k{kh}x{kw}x{cout}"
            f"/s{stride}/d{dilation}/p{pad}/op{op}/{jnp.dtype(dtype).name}"
            f"/ep{fingerprint(epilogue)}")


def candidates(h_out: int, cout: int) -> list[tuple[int, int]]:
    """The (th, tc) sweep grid, clipped to the output geometry.

    Oversized candidates are dropped rather than clamped — the kernels clamp
    internally, so a clamped duplicate would just re-time the same tiling.
    """
    ths = [t for t in TH_CANDIDATES if t <= max(h_out, TH_CANDIDATES[0])]
    tcs = [t for t in TC_CANDIDATES if t <= max(cout, TC_CANDIDATES[0])]
    return [(th, tc) for th in ths for tc in tcs]


def _load_disk() -> dict[str, tuple[int, int]]:
    global _DISK
    if _DISK is None:
        _DISK = {}
        path = cache_path()
        if path.exists():
            try:
                raw = json.loads(path.read_text())
                _DISK = {k: tuple(v) for k, v in raw.get("entries", {}).items()}
            except (json.JSONDecodeError, OSError):
                _DISK = {}      # corrupt cache — retune rather than crash
    return _DISK


def _persist(key: str, tiles: tuple[int, int]) -> None:
    disk = _load_disk()
    disk[key] = tiles
    path = cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {"device_kind": _device_kind(), "jax_version": jax.__version__,
               "schema": _SCHEMA,
               "entries": {k: list(v) for k, v in sorted(disk.items())}}
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(payload, indent=1))
    tmp.replace(path)           # atomic: concurrent readers see old or new


def clear_memory_cache() -> None:
    """Drop the in-process caches (tests; after swapping the cache dir)."""
    global _DISK
    _MEM.clear()
    _DISK = None


def _out_hw(kind: str, x_shape: tuple, w_shape: tuple, stride: int,
            padding, output_padding) -> tuple[int, int]:
    """Output (H, W) of one geometry — sizes the synthetic residual operand."""
    n, h, w_in, _ = x_shape
    kh, kw = w_shape[0], w_shape[1]
    if kind == "tconv":
        from repro.core import transposed as tr

        p_lo = (kh - 1) // 2 if padding is None else padding
        op = 1 if output_padding is None else output_padding
        return (tr.out_size(h, stride, kh, p_lo, p_lo + op),
                tr.out_size(w_in, stride, kw, p_lo, p_lo + op))
    if kind == "dense" and isinstance(padding, int):
        return ((h + 2 * padding - kh) // stride + 1,
                (w_in + 2 * padding - kw) // stride + 1)
    return -(-h // stride), -(-w_in // stride)      # SAME


def _ep_operands(spec, kind: str, x_shape: tuple, w_shape: tuple,
                 stride: int, padding, output_padding, dtype) -> dict:
    """Synthetic epilogue operands so tuned calls time the real footprint."""
    if spec is None or spec.empty:
        return {}
    cout = w_shape[3]
    out = {}
    if spec.bn:
        out["scale"] = jnp.ones((cout,), jnp.float32)
        out["shift"] = jnp.zeros((cout,), jnp.float32)
    if spec.prelu:
        out["alpha"] = jnp.full((cout,), 0.25, jnp.float32)
    if spec.residual != "none":
        oh, ow = _out_hw(kind, x_shape, w_shape, stride, padding,
                         output_padding)
        out["residual"] = jnp.zeros((x_shape[0], oh, ow, cout), dtype)
    return out


def _build_call(kind: str, x: jax.Array, w: jax.Array, th: int, tc: int,
                stride: int, dilation: int, padding, output_padding,
                epilogue=None):
    ep_kw = _ep_operands(epilogue, kind, x.shape, w.shape, stride, padding,
                         output_padding, x.dtype)
    if kind == "dense":
        from repro.kernels.conv2d import conv2d
        return lambda: conv2d(x, w, stride=stride,
                              padding="SAME" if padding is None else padding,
                              th=th, tc=tc, epilogue=epilogue, **ep_kw)
    if kind == "dilated":
        from repro.kernels.dilated_conv import dilated_conv2d
        return lambda: dilated_conv2d(x, w, dilation, stride=stride,
                                      th=th, tc=tc, epilogue=epilogue,
                                      **ep_kw)
    from repro.kernels.transposed_conv import transposed_conv2d
    return lambda: transposed_conv2d(
        x, w, stride=stride, padding=padding,
        output_padding=1 if output_padding is None else output_padding,
        th=th, tc=tc, epilogue=epilogue, **ep_kw)


def _time_candidate(call, iters: int) -> float:
    """Best-of-``iters`` wall time (s) after a compile/warmup call.

    Delegates to the shared blocking timer (``repro.kernels.util.time_call``)
    so the timed region always includes ``jax.block_until_ready`` — async
    dispatch must not record launch latency as kernel runtime.
    """
    from repro.kernels.util import time_call

    return time_call(call, iters=iters)


def _prune_default() -> int | None:
    """Sweep-prune width from ``$REPRO_AUTOTUNE_PRUNE`` (unset/0 = off)."""
    raw = os.environ.get("REPRO_AUTOTUNE_PRUNE", "")
    try:
        k = int(raw)
    except ValueError:
        return None
    return k if k > 0 else None


def tune(kind: str, x_shape: tuple, w_shape: tuple, *, stride: int = 1,
         dilation: int = 1, dtype=jnp.float32, padding=None,
         output_padding: int | None = None, iters: int = 3,
         cands: list[tuple[int, int]] | None = None,
         prune: int | None = None, calibration=None,
         epilogue=None, policy_top: int | None = None) -> tuple[int, int]:
    """Time the promising candidates for one geometry; persist the winner.

    Deterministic given timings: candidates are visited in a fixed order and
    ties keep the earlier candidate.  Returns the winning ``(th, tc)``.

    By default the analytic policy (:mod:`repro.kernels.tiling_policy`,
    DESIGN.md §12) ranks the grid by VMEM footprint (dtype- and
    epilogue-aware) and MXU occupancy, and only the top ``policy_top``
    (default :data:`POLICY_TOP`) plus ``DEFAULT_TILES`` are timed.
    ``$REPRO_AUTOTUNE_SWEEP=1`` forces the exhaustive sweep of the whole
    grid instead.

    ``prune`` (or ``$REPRO_AUTOTUNE_PRUNE``) is the legacy calibrated
    pruner: the grid is ranked by ``repro.core.calibrate.tile_scores`` and
    only the top ``prune`` run.  In both modes the current default tiling
    is always kept in the timed set, so candidate selection can never
    regress below the no-autotune baseline.
    """
    key = make_key(kind, x_shape, w_shape, stride=stride, dilation=dilation,
                   dtype=dtype, padding=padding,
                   output_padding=output_padding, epilogue=epilogue)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, x_shape, jnp.float32).astype(dtype)
    w = jax.random.normal(k2, w_shape, jnp.float32).astype(dtype)
    if kind == "tconv":
        # th tiles the per-parity *block-row* axis: ~ceil(OH/s) ~ H rows
        h_out = x_shape[1]
    else:
        h_out = -(-x_shape[1] // stride)
    if cands is None:
        cands = candidates(h_out, w_shape[3])
    prune = _prune_default() if prune is None else prune
    if prune is not None and prune < len(cands):
        from repro.core.calibrate import CaptureCase, modeled_cycles, tile_scores

        case = CaptureCase(kind, tuple(x_shape), tuple(w_shape),
                           stride=stride, dilation=dilation)
        ranked = tile_scores(h_out, w_shape[3], cands, kind=kind,
                             base_cycles=modeled_cycles(case),
                             calibration=calibration,
                             dtype=jnp.dtype(dtype).name)
        keep = {c for _, c in ranked[:prune]}
        keep.add(DEFAULT_TILES)     # never time fewer than the baseline
        cands = [c for c in cands if c in keep]
    else:
        from repro.core.calibrate import CaptureCase, modeled_cycles
        from repro.kernels import tiling_policy

        try:
            base_cycles = modeled_cycles(CaptureCase(
                kind, tuple(x_shape), tuple(w_shape), stride=stride,
                dilation=dilation))
        except Exception:       # unmodeled geometry — rank without cell term
            base_cycles = None
        cands = tiling_policy.top_candidates(
            kind, x_shape, w_shape, cands,
            top=POLICY_TOP if policy_top is None else policy_top,
            default_tiles=DEFAULT_TILES, stride=stride, dilation=dilation,
            padding=padding, output_padding=output_padding, dtype=dtype,
            epilogue=epilogue, base_cycles=base_cycles,
            calibration=calibration)
    best, best_t = DEFAULT_TILES, float("inf")
    for th, tc in cands:
        t = _time_candidate(_build_call(kind, x, w, th, tc, stride, dilation,
                                        padding, output_padding,
                                        epilogue=epilogue),
                            iters)
        if t < best_t:
            best, best_t = (th, tc), t
    _MEM[key] = best
    _persist(key, best)
    return best


def get_tiles(kind: str, x_shape: tuple, w_shape: tuple, *, stride: int = 1,
              dilation: int = 1, dtype=jnp.float32, padding=None,
              output_padding: int | None = None,
              epilogue=None) -> tuple[int, int]:
    """Resolve the tile shape for one geometry: mem -> disk -> tune/defaults.

    Only tunes on a full miss when ``REPRO_AUTOTUNE=1`` — the default is a
    pure lookup so cold paths (tests, first-run UX) stay deterministic and
    cheap; the table is populated by CI / ``kernel_bench`` runs and shipped
    via the CI cache.
    """
    key = make_key(kind, x_shape, w_shape, stride=stride, dilation=dilation,
                   dtype=dtype, padding=padding,
                   output_padding=output_padding, epilogue=epilogue)
    hit = _MEM.get(key)
    if hit is not None:
        return hit
    hit = _load_disk().get(key)
    if hit is not None:
        _MEM[key] = hit
        return hit
    if autotune_enabled():
        return tune(kind, x_shape, w_shape, stride=stride, dilation=dilation,
                    dtype=dtype, padding=padding,
                    output_padding=output_padding, epilogue=epilogue)
    _MEM[key] = DEFAULT_TILES   # negative-cache the lookup, not the timing
    return DEFAULT_TILES


__all__ = ["DEFAULT_TILES", "POLICY_TOP", "get_tiles", "tune", "make_key",
           "candidates", "cache_path", "clear_memory_cache",
           "autotune_enabled"]
