"""Dense 2-D convolution Pallas kernel — the MXU workhorse after decomposition.

The paper's decomposition reduces dilated/transposed convolutions to *dense*
convolutions; this kernel is the TPU execution engine for those.  It computes
an NHWC convolution as a sum of ``kh*kw`` shifted implicit-GEMM taps, keeping
the MXU contraction on ``Cin`` and the lane dimension on a ``Cout`` tile.
Rectangular kernels (``kh != kw`` — ENet's 5x1/1x5 asymmetric pair) are
first-class: the tap loops, pads and halo are all per-dim.

Tiling (per grid step): one batch element, ``TH`` output rows x full output
width, one ``TC``-wide ``Cout`` tile.  The input row halo (``kh - stride``
rows) is assembled *without overlapping BlockSpecs* by passing the input
twice — the current row tile and the next row tile — and concatenating in
VMEM (standard Pallas halo idiom).

An optional fused epilogue (:mod:`repro.kernels.epilogue`, DESIGN.md §7) —
folded BN scale/shift, PReLU, residual add — is applied to the fp32
accumulator tile while it is still in VMEM, removing up to three elementwise
HBM passes per convolution.

VMEM per step ~ x_tile(2 * s*TH * Wp * Cin) + w(kh*kw*Cin*TC) + out(TH*W*TC),
sized well under a v5e core's VMEM for every shape used in this repo.  The
grid runs the row stream innermost with ``dimension_semantics`` declared, so
Mosaic's pipeliner double-buffers the input halo pair (next tile's DMA
overlaps the current tile's MXU work) while the weight tile stays resident
for a whole ``Cout``-tile pass; ``tiling_policy.footprint_bytes`` mirrors
exactly these blocks when the autotuner scores candidates (DESIGN.md §12).

Mixed precision (DESIGN.md §12): bf16 inputs accumulate in fp32 — every tap
GEMM issues with ``preferred_element_type=jnp.float32``, the fused epilogue
applies to the fp32 accumulator, and only the final output cast returns to
the input dtype.  The VJPs keep fp32 tap-correlation accumulation and cast
``dx``/``dw`` back to the primal dtypes.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.epilogue import EpilogueSpec, apply_tile, pack_args
from repro.kernels.util import resolve_interpret

_NO_EP = EpilogueSpec()


def _conv_kernel(x_cur, x_nxt, w, *rest, spec: EpilogueSpec, th: int,
                 kh: int, kw: int, stride: int, w_out: int):
    """One (batch, row-tile, cout-tile) grid step."""
    out = rest[-1]
    ep_refs = rest[:-1]
    s = stride
    halo = kh - s
    # assemble the input window: s*TH rows + halo rows from the next tile
    xw = x_cur[0]
    if halo > 0:
        xw = jnp.concatenate([xw, x_nxt[0][:halo]], axis=0)
    cin = xw.shape[-1]
    acc = jnp.zeros((th * w_out, out.shape[-1]), jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            # output row t reads input row s*t + dy; col c reads s*c + dx
            rows = xw[dy : dy + s * (th - 1) + 1 : s,
                      dx : dx + s * (w_out - 1) + 1 : s, :]
            acc += jax.lax.dot_general(
                rows.reshape(th * w_out, cin), w[dy, dx],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
    if not spec.empty:
        args = tuple(r[0] if name == "residual" else r[...]
                     for name, r in zip(spec.slots, ep_refs))
        acc = apply_tile(spec, acc, args, flat=th * w_out)
    out[0] = acc.reshape(th, w_out, out.shape[-1]).astype(out.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "th", "tc", "interpret", "epilogue"),
)
def conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
           padding: str | int = "SAME", th: int = 8, tc: int = 128,
           interpret: bool | None = None,
           epilogue: EpilogueSpec | None = None,
           scale: jax.Array | None = None, shift: jax.Array | None = None,
           alpha: jax.Array | None = None,
           residual: jax.Array | None = None) -> jax.Array:
    """Pallas dense convolution. NHWC x HWIO -> NHWC.  Differentiable: a
    ``jax.custom_vjp`` routes the input-gradient through the transposed-conv
    engine and the weight-gradient through tap-gather correlations
    (:mod:`repro.core.adjoints`, DESIGN.md §6); the fused-epilogue path
    differentiates by adjoint re-entry (``adjoints.fused_epilogue_bwd``).

    Args:
      x: (N, H, W, Cin).
      w: (kh, kw, Cin, Cout) — rectangular kernels supported.
      stride: spatial stride (1 or 2 used in this repo).
      padding: "SAME", "VALID" or an explicit symmetric int.
      th: output rows per tile.  tc: Cout tile width (lane dim, 128 on MXU).
      interpret: None -> auto (interpret on CPU), or an explicit override.
      epilogue: optional :class:`EpilogueSpec` fused into the kernel; the
        spec's operands (``scale``/``shift``/``alpha``/``residual``) must be
        passed to match (DESIGN.md §7).
    """
    interpret = resolve_interpret(interpret)
    kh, kw = w.shape[0], w.shape[1]
    if isinstance(padding, int):
        pads = ((padding, padding), (padding, padding))
    elif padding == "SAME":
        pads = (((kh - 1) // 2, kh // 2), ((kw - 1) // 2, kw // 2))
    else:  # VALID
        pads = ((0, 0), (0, 0))
    spec = _NO_EP if epilogue is None else epilogue
    if spec.empty:
        return _conv2d_vjp(x, w, stride, pads, th, tc, interpret)
    eps = pack_args(spec, scale=scale, shift=shift, alpha=alpha,
                    residual=residual)
    return _conv2d_ep_vjp(x, w, eps, spec, stride, pads, th, tc, interpret)


def _chan_operand(v: jax.Array, cout: int, cout_p: int) -> jax.Array:
    """Broadcast a scalar/per-channel operand to a padded (1, cout_p) row."""
    from repro.kernels.epilogue import _chanvec

    return jnp.pad(_chanvec(v, cout), (0, cout_p - cout)).reshape(1, cout_p)


def _conv2d_raw(x: jax.Array, w: jax.Array, eps: tuple, spec: EpilogueSpec,
                stride: int, pads: tuple[tuple[int, int], tuple[int, int]],
                th: int, tc: int, interpret: bool) -> jax.Array:
    n, h, w_in, cin = x.shape
    kh, kw, _, cout = w.shape
    s = stride
    ph, pw = pads
    h_out = (h + ph[0] + ph[1] - kh) // s + 1
    w_out = (w_in + pw[0] + pw[1] - kw) // s + 1

    th = min(th, h_out)
    # the halo (kh - s rows) is served from the *next* row tile, which holds
    # s*th rows — keep th large enough that one tile covers it (tiny inputs)
    th = max(th, math.ceil(max(kh - s, 0) / s))
    n_row_tiles = math.ceil(h_out / th)
    h_out_p = n_row_tiles * th
    tc = min(tc, cout)
    n_cout_tiles = math.ceil(cout / tc)
    cout_p = n_cout_tiles * tc

    # pad input so every tile (incl. the +1 halo tile) reads in-bounds:
    # rows needed: s*h_out_p + (kh - s) for tiles, plus one extra halo tile.
    # (when VALID windows don't consume the whole input, the "needed" extent
    # is smaller than what's there — clamp at 0; excess rows/cols are simply
    # never read by any block)
    rows_needed = s * h_out_p + max(kh - s, 0) + s * th
    cols_needed = s * (w_out - 1) + kw
    xp = jnp.pad(
        x,
        ((0, 0), (ph[0], max(rows_needed - h - ph[0], 0)),
         (pw[0], max(cols_needed - w_in - pw[0], 0)), (0, 0)),
    )
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, cout_p - cout)))

    # grid order (batch, cout tile, row tile): the row stream is innermost,
    # so the pipeline double-buffers consecutive input row tiles (the halo
    # pair advances by one block per step) while the weight tile's block
    # index is unchanged across the whole inner stream and stays resident
    grid = (n, n_cout_tiles, n_row_tiles)
    x_spec_cur = pl.BlockSpec((1, s * th, cols_needed, cin),
                              lambda b, c, i: (b, i, 0, 0))
    x_spec_nxt = pl.BlockSpec((1, s * th, cols_needed, cin),
                              lambda b, c, i: (b, i + 1, 0, 0))
    w_spec = pl.BlockSpec((kh, kw, cin, tc), lambda b, c, i: (0, 0, 0, c))
    out_spec = pl.BlockSpec((1, th, w_out, tc), lambda b, c, i: (b, i, 0, c))

    # epilogue operands: channel vectors as padded (1, cout_p) rows tiled on
    # the cout grid axis; the residual blocked exactly like the output
    ep_in, ep_specs = [], []
    for name, v in zip(spec.slots, eps):
        if name == "residual":
            if v.shape != (n, h_out, w_out, cout):
                raise ValueError(f"residual shape {v.shape} != output "
                                 f"{(n, h_out, w_out, cout)}")
            ep_in.append(jnp.pad(v, ((0, 0), (0, h_out_p - h_out), (0, 0),
                                     (0, cout_p - cout))))
            ep_specs.append(pl.BlockSpec((1, th, w_out, tc),
                                         lambda b, c, i: (b, i, 0, c)))
        else:
            ep_in.append(_chan_operand(v, cout, cout_p))
            ep_specs.append(pl.BlockSpec((1, tc), lambda b, c, i: (0, c)))

    out = pl.pallas_call(
        functools.partial(_conv_kernel, spec=spec, th=th, kh=kh, kw=kw,
                          stride=s, w_out=w_out),
        grid=grid,
        in_specs=[x_spec_cur, x_spec_nxt, w_spec, *ep_specs],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, h_out_p, w_out, cout_p), x.dtype),
        # batch/cout steps are independent; the row stream is sequential so
        # Mosaic's pipeliner overlaps each tile's DMA with the previous
        # tile's MXU work (double-buffered VMEM streams)
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, xp, wp, *ep_in)
    return out[:, :h_out, :, :cout]


def _conv2d_impl(x: jax.Array, w: jax.Array, stride: int,
                 pads: tuple[tuple[int, int], tuple[int, int]],
                 th: int, tc: int, interpret: bool) -> jax.Array:
    return _conv2d_raw(x, w, (), _NO_EP, stride, pads, th, tc, interpret)


# ---------------------------------------------------------------------------
# Custom VJP (DESIGN.md §6): the input-gradient of a strided dense conv IS a
# transposed convolution — it routes through the weight-decomposition engine
# (the fused Pallas transposed-conv kernel); the weight-gradient is a batched
# tap-gather correlation on the MXU.
# ---------------------------------------------------------------------------

_conv2d_vjp = jax.custom_vjp(_conv2d_impl, nondiff_argnums=(2, 3, 4, 5, 6))


def _conv2d_fwd(x, w, stride, pads, th, tc, interpret):
    return _conv2d_impl(x, w, stride, pads, th, tc, interpret), (x, w)


def _dx_lax(g, w, stride, pads, h, w_in):
    """Fallback input-gradient (rectangular kernels / exotic pads): the same
    adjoint expressed as one lhs-dilated lax convolution."""
    from repro.core.adjoints import flip_io

    kh, kw = w.shape[0], w.shape[1]
    (pl_h, _), (pl_w, _) = pads
    hg, wg = g.shape[1], g.shape[2]
    ph_h = h - (hg - 1) * stride - 1 + pl_h - (kh - 1)
    ph_w = w_in - (wg - 1) * stride - 1 + pl_w - (kw - 1)
    return jax.lax.conv_general_dilated(
        g, flip_io(w), window_strides=(1, 1),
        padding=[(kh - 1 - pl_h, kh - 1 + ph_h), (kw - 1 - pl_w, kw - 1 + ph_w)],
        lhs_dilation=(stride, stride),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _conv2d_bwd(stride, pads, th, tc, interpret, res, g):
    from repro.core import adjoints

    x, w = res
    kh, kw, _, _ = w.shape
    (pl_h, _), (pl_w, _) = pads
    n, h, w_in, _ = x.shape
    if kh == kw and pl_h == pl_w and kh - 1 - pl_h >= 0:
        from repro.kernels.transposed_conv import transposed_conv2d as _tconv

        def tconv_fn(gg, wf, s, p_lo, op):
            return _tconv(gg, wf, stride=s, padding=p_lo, output_padding=op,
                          th=th, tc=tc, interpret=interpret)

        dx = adjoints.dense_conv_dx(g, w, stride, pl_h, h, w_in, tconv_fn)
    else:
        dx = _dx_lax(g, w, stride, pads, h, w_in)
    dw = adjoints.dense_conv_dw(x, g, kh, kw, stride, pl_h, pl_w)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_conv2d_vjp.defvjp(_conv2d_fwd, _conv2d_bwd)


# ---------------------------------------------------------------------------
# Fused-epilogue VJP (DESIGN.md §7): the backward differentiates the
# composition conv∘epilogue by re-entry — the conv cotangent flows through
# the §6 adjoints above, the epilogue gradients are elementwise fp32 ops.
# ---------------------------------------------------------------------------

def _conv2d_ep_impl(x, w, eps, spec, stride, pads, th, tc, interpret):
    return _conv2d_raw(x, w, eps, spec, stride, pads, th, tc, interpret)


_conv2d_ep_vjp = jax.custom_vjp(_conv2d_ep_impl,
                                nondiff_argnums=(3, 4, 5, 6, 7, 8))


def _conv2d_ep_fwd(x, w, eps, spec, stride, pads, th, tc, interpret):
    y = _conv2d_ep_impl(x, w, eps, spec, stride, pads, th, tc, interpret)
    return y, (x, w, eps)


def _conv2d_ep_bwd(spec, stride, pads, th, tc, interpret, res, g):
    from repro.core import adjoints

    x, w, eps = res

    def conv_apply(xx, ww):
        return _conv2d_vjp(xx, ww, stride, pads, th, tc, interpret)

    return adjoints.fused_epilogue_bwd(conv_apply, spec, x, w, eps, g)


_conv2d_ep_vjp.defvjp(_conv2d_ep_fwd, _conv2d_ep_bwd)
