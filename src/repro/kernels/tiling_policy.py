"""Analytic ``(th, tc)`` tiling policy for the Pallas engines (DESIGN.md §12).

The autotuner used to *time* the whole candidate grid per geometry.  This
module scores every candidate from first principles instead, so only the
top few (plus ``DEFAULT_TILES``) are ever run:

* **VMEM footprint** — each candidate's per-grid-step working set, assembled
  from the same block shapes the kernels declare (`conv2d.py`,
  `transposed_conv.py`), doubled for the pipeline's double-buffered
  input/weight/output streams, plus the fp32 accumulator.  The footprint is
  dtype-aware (bf16 halves the streamed bytes) and epilogue-aware (a fused
  residual streams a second output-shaped block; channel vectors ride along
  as fp32 rows).  Candidates that overflow the budget score ``inf`` — they
  would spill or fail to fit, so they are never worth timing.
* **MXU occupancy** — each grid step issues GEMMs of shape
  ``(th * w_out, cin) x (cin, tc)``.  Lanes pad to 128, sublanes pack by
  dtype (8 fp32 / 16 bf16 rows per tile), so narrow ``tc`` or a flattened
  row count that straddles a packing boundary wastes issue slots.
* **tile quantization + grid overhead** — the classic terms shared with
  ``calibrate.tile_scores``: padded-output work multiplier and a per-cell
  dispatch weight (calibrated from the fitted ``b_us / (a * cycles)`` when
  a :class:`~repro.core.calibrate.Calibration` is supplied).

The combined score is ``quantization_waste / occupancy + cell_w * cells``
(lower is better), with ``inf`` for budget violations.  ``top_candidates``
returns the top-``k`` plus ``DEFAULT_TILES``; when the geometry cannot be
modeled (unknown kind) or ``$REPRO_AUTOTUNE_SWEEP`` is set, it falls back
to the full exhaustive sweep so the policy can never hide a winner the old
path would have found.
"""

from __future__ import annotations

import math
import os

import jax.numpy as jnp

#: ~16 MiB of VMEM per TPU core; leave headroom for compiler scratch and
#: semaphores so a "fits" verdict survives lowering.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

#: MXU lane width — the last-dim tiling quantum on TPU.
LANES = 128

_KINDS = ("dense", "dilated", "tconv")


def itemsize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def sublanes(dtype) -> int:
    """Rows per (sublane, lane) register tile: 8 fp32, 16 bf16, 32 int8."""
    return max(8 * (4 // max(itemsize(dtype), 1)), 8)


def _ep_extra(spec, out_elems: int, isz: int) -> int:
    """Streamed bytes a fused epilogue adds per grid step.

    Channel vectors (scale/shift/alpha) travel as fp32 ``(1, tc)`` rows —
    negligible but counted; a residual streams a full output-shaped block in
    the output dtype.
    """
    if spec is None or spec.empty:
        return 0
    extra = 0
    for name in spec.slots:
        extra += out_elems * isz if name == "residual" else 0
    return extra


def _dense_geometry(x_shape, w_shape, stride, padding):
    n, h, w_in, cin = x_shape
    kh, kw = w_shape[0], w_shape[1]
    cout = w_shape[3]
    if padding is None or padding == "SAME":
        ph = ((kh - 1) // 2, kh // 2)
        pw = ((kw - 1) // 2, kw // 2)
    elif padding == "VALID":
        ph = pw = (0, 0)
    else:
        ph = pw = (padding, padding)
    h_out = (h + ph[0] + ph[1] - kh) // stride + 1
    w_out = (w_in + pw[0] + pw[1] - kw) // stride + 1
    return n, h_out, w_out, cin, cout, kh, kw


def _phase_batched(x_shape, dilation):
    """Dilated convs run the dense kernel on the phase-batched layout."""
    n, h, w_in, cin = x_shape
    d = dilation
    return (n * d * d, -(-h // d), -(-w_in // d), cin)


def footprint_bytes(kind: str, x_shape, w_shape, th: int, tc: int, *,
                    stride: int = 1, dilation: int = 1, padding=None,
                    output_padding: int | None = None, dtype=jnp.float32,
                    epilogue=None) -> int:
    """Per-grid-step VMEM working set of one ``(th, tc)`` candidate (bytes).

    Mirrors the kernels' BlockSpecs: double-buffered input halo pair +
    weight tile + output tile (x2 for the pipeline), epilogue operands, and
    the fp32 accumulator.  Dilated geometries are scored as the dense kernel
    on the phase-batched layout they actually run.
    """
    isz = itemsize(dtype)
    if kind == "dilated":
        x_shape = _phase_batched(x_shape, dilation)
        stride, padding = 1, None   # classes fold the stride out
    if kind in ("dense", "dilated"):
        _, h_out, w_out, cin, cout, kh, kw = _dense_geometry(
            x_shape, w_shape, stride, padding)
        th_e = max(min(th, h_out), math.ceil(max(kh - stride, 0) / stride))
        tc_e = min(tc, cout)
        cols = stride * (w_out - 1) + kw
        x_block = stride * th_e * cols * cin          # x_cur; x_nxt doubles it
        w_block = kh * kw * cin * tc_e
        out_block = th_e * w_out * tc_e
        acc = th_e * w_out * tc_e * 4
    else:       # tconv: parity-plane kernel (transposed_conv.py)
        from repro.core import transposed as tr
        from repro.kernels.transposed_conv import parity_schedule

        n, h, w_in, cin = x_shape
        k = w_shape[0]
        cout = w_shape[3]
        s = stride
        p_lo = (k - 1) // 2 if padding is None else padding
        op = 1 if output_padding is None else output_padding
        oh = tr.out_size(h, s, k, p_lo, p_lo + op)
        ow = tr.out_size(w_in, s, k, p_lo, p_lo + op)
        hb, wb = math.ceil(oh / s), math.ceil(ow / s)
        offs = [o for taps in parity_schedule(k, s, p_lo) for _, o in taps]
        shift = max(0, -min(offs, default=0))
        halo = max(offs, default=0) + shift
        th_e = max(min(th, hb), halo)
        tc_e = min(tc, cout)
        cols = max(wb + halo, w_in + shift)
        x_block = th_e * cols * cin
        w_block = k * k * cin * tc_e
        out_block = s * s * th_e * wb * tc_e
        acc = s * s * th_e * wb * tc_e * 4
    streamed = (2 * x_block + w_block + out_block) * isz
    streamed += _ep_extra(epilogue, out_block, isz)
    return 2 * streamed + acc       # x2: the pipeline double-buffers streams


def mxu_occupancy(kind: str, x_shape, w_shape, th: int, tc: int, *,
                  stride: int = 1, dilation: int = 1, padding=None,
                  output_padding: int | None = None,
                  dtype=jnp.float32) -> float:
    """Fraction of MXU issue slots doing real work for one candidate's GEMM.

    The kernels flatten each tile to ``(th * w_out, cin) x (cin, tc)``;
    lanes quantize to 128 and sublane rows pack by dtype, so the occupancy
    is the product of the two padding fractions.
    """
    if kind == "dilated":
        x_shape = _phase_batched(x_shape, dilation)
        stride, padding = 1, None
    if kind in ("dense", "dilated"):
        _, h_out, w_out, _, cout, kh, _ = _dense_geometry(
            x_shape, w_shape, stride, padding)
        th_e = max(min(th, h_out), math.ceil(max(kh - stride, 0) / stride))
        rows = th_e * w_out
    else:
        from repro.core import transposed as tr

        n, h, w_in, _ = x_shape
        k = w_shape[0]
        cout = w_shape[3]
        p_lo = (k - 1) // 2 if padding is None else padding
        op = 1 if output_padding is None else output_padding
        oh = tr.out_size(h, stride, k, p_lo, p_lo + op)
        ow = tr.out_size(w_in, stride, k, p_lo, p_lo + op)
        hb, wb = math.ceil(oh / stride), math.ceil(ow / stride)
        rows = max(min(th, hb), 1) * wb
    tc_e = min(tc, cout)
    sub = sublanes(dtype)
    lane_occ = tc_e / (math.ceil(tc_e / LANES) * LANES)
    row_occ = rows / (math.ceil(rows / sub) * sub)
    return lane_occ * row_occ


def _cell_weight(kind: str, backend: str, base_cycles, calibration,
                 dtype) -> float:
    """Per-grid-cell overhead weight; calibrated when a fit is available."""
    cell_w = 1e-3
    if calibration is not None and base_cycles:
        from repro.core.calibrate import key_of

        co = calibration.coeffs.get(
            key_of(kind, backend, dtype=jnp.dtype(dtype).name))
        if co is None:      # fall back to the fp32 fit of the same engine
            co = calibration.coeffs.get(key_of(kind, backend))
        if co is not None and co.a_us_per_cycle > 0:
            compute_us = co.a_us_per_cycle * base_cycles
            if compute_us > 0:
                cell_w = co.b_us / compute_us
    return cell_w


def rank(kind: str, x_shape, w_shape, cands, *, stride: int = 1,
         dilation: int = 1, padding=None, output_padding: int | None = None,
         dtype=jnp.float32, epilogue=None, backend: str = "xla",
         base_cycles: float | None = None, calibration=None,
         vmem_budget: int = VMEM_BUDGET_BYTES
         ) -> list[tuple[float, tuple[int, int]]]:
    """Score every candidate analytically; ``(score, (th, tc))`` ascending.

    ``score = quantization_waste / mxu_occupancy + cell_w * n_cells``, with
    ``inf`` when the candidate's VMEM footprint exceeds ``vmem_budget``.
    Ties keep candidate order (the sweep's determinism rule).
    """
    if kind not in _KINDS:
        raise ValueError(f"unknown engine kind {kind!r}")
    if kind == "tconv":
        h_out, cout = x_shape[1], w_shape[3]    # th tiles the block-row axis
    else:
        h_out, cout = -(-x_shape[1] // stride), w_shape[3]
    cell_w = _cell_weight(kind, backend, base_cycles, calibration, dtype)
    geom = dict(stride=stride, dilation=dilation, padding=padding,
                output_padding=output_padding, dtype=dtype)
    scored = []
    for i, (th, tc) in enumerate(cands):
        vmem = footprint_bytes(kind, x_shape, w_shape, th, tc,
                               epilogue=epilogue, **geom)
        if vmem > vmem_budget:
            scored.append((float("inf"), i, (th, tc)))
            continue
        occ = mxu_occupancy(kind, x_shape, w_shape, th, tc, **geom)
        waste = (math.ceil(h_out / th) * th / h_out) * \
                (math.ceil(cout / tc) * tc / cout)
        cells = math.ceil(h_out / th) * math.ceil(cout / tc)
        scored.append((waste / max(occ, 1e-9) + cell_w * cells, i, (th, tc)))
    scored.sort(key=lambda t: (t[0], t[1]))
    return [(s, c) for s, _, c in scored]


def sweep_forced() -> bool:
    """``$REPRO_AUTOTUNE_SWEEP=1`` disables the policy (exhaustive timing)."""
    return os.environ.get("REPRO_AUTOTUNE_SWEEP", "").lower() in (
        "1", "true", "on")


def top_candidates(kind: str, x_shape, w_shape, cands, *, top: int = 3,
                   default_tiles: tuple[int, int] | None = None,
                   **rank_kw) -> list[tuple[int, int]]:
    """The candidates worth timing: analytic top-``top`` + ``default_tiles``.

    Returns the input list unchanged (exhaustive sweep) when the sweep is
    forced via the environment or the geometry cannot be scored — the
    policy degrades to the old behaviour, never to a smaller search space
    than the baseline tiling.
    """
    if sweep_forced():
        return list(cands)
    try:
        ranked = rank(kind, x_shape, w_shape, cands, **rank_kw)
    except (ValueError, ZeroDivisionError):
        return list(cands)      # unmodelable geometry: fall back to the sweep
    keep = [c for s, c in ranked[:top] if math.isfinite(s)]
    if not keep:                # every candidate over budget — time them all
        return list(cands)      # rather than guess blind
    if default_tiles is not None and default_tiles in cands \
            and default_tiles not in keep:
        keep.append(default_tiles)
    return [c for c in cands if c in keep]   # candidate order == sweep order


__all__ = ["VMEM_BUDGET_BYTES", "LANES", "itemsize", "sublanes",
           "footprint_bytes", "mxu_occupancy", "rank", "top_candidates",
           "sweep_forced"]
