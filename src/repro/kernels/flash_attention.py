"""Blocked (flash) attention Pallas kernel — LM prefill/training hot-spot.

Online-softmax attention tiled over (batch*heads, q-tiles, kv-tiles) with the
kv dimension innermost (sequential on TPU).  Running max/denominator and the
f32 output accumulator live in VMEM scratch across the kv loop; causal
masking is applied per-tile with broadcasted iotas.

Used by the LM stack when ``config.use_pallas_attention`` is set; the XLA
einsum path (``ref.attention_ref``) is the default for dry-runs.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q, k, v, out, m_scr, l_scr, acc, *, scale: float,
                  causal: bool, tq: int, tk: int, seq_k: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc[...] = jnp.zeros_like(acc)

    s = jax.lax.dot_general(
        q[0].astype(jnp.float32), k[0].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    ) * scale                                           # (tq, tk)
    if causal:
        q_pos = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
    # mask kv padding beyond the true sequence
    k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    s = jnp.where(k_pos < seq_k, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p, v[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _done():
        out[0] = (acc[...] / jnp.maximum(l_scr[...], 1e-30)).astype(out.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "tq", "tk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, tq: int = 128, tk: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """(B, H, Sq, D) x (B, H, Sk, D) -> (B, H, Sq, D)."""
    interpret = resolve_interpret(interpret)
    b, h, sq, dh = q.shape
    _, _, sk, _ = k.shape
    scale = dh ** -0.5
    tq, tk = min(tq, sq), min(tk, sk)
    sq_p, sk_p = math.ceil(sq / tq) * tq, math.ceil(sk / tk) * tk

    qf = jnp.pad(q.reshape(b * h, sq, dh), ((0, 0), (0, sq_p - sq), (0, 0)))
    kf = jnp.pad(k.reshape(b * h, sk, dh), ((0, 0), (0, sk_p - sk), (0, 0)))
    vf = jnp.pad(v.reshape(b * h, sk, dh), ((0, 0), (0, sk_p - sk), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          tq=tq, tk=tk, seq_k=sk),
        grid=(b * h, sq_p // tq, sk_p // tk),
        in_specs=[
            pl.BlockSpec((1, tq, dh), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, tk, dh), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, tk, dh), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, dh), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),   # running max
            pltpu.VMEM((tq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((tq, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :sq, :].reshape(b, h, sq, dh)
