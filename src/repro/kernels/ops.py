"""Public jit'd wrappers for the Pallas kernels, with shape checks.

These are the entry points the model zoo uses when ``use_pallas`` execution
is selected; each has a pure-jnp oracle in :mod:`repro.kernels.ref`.  The
three convolution kernels carry custom VJPs (DESIGN.md §6) and are safe
under ``jax.grad``.
"""

from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.conv2d import conv2d as _conv2d
from repro.kernels.dilated_conv import dilated_conv2d as _dilated
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.matmul import matmul as _matmul
from repro.kernels.transposed_conv import transposed_conv2d as _tconv


def conv2d(x, w, *, stride=1, padding="SAME", interpret=None, **epilogue_kw):
    """Dense conv — rectangular kernels and fused epilogues supported."""
    if x.ndim != 4 or w.ndim != 4 or x.shape[-1] != w.shape[2]:
        raise ValueError(f"bad conv shapes {x.shape} x {w.shape}")
    return _conv2d(x, w, stride=stride, padding=padding, interpret=interpret,
                   **epilogue_kw)


def dilated_conv2d(x, w, dilation, *, stride=1, interpret=None, **epilogue_kw):
    if w.shape[0] != w.shape[1]:
        raise ValueError("square kernels only")
    return _dilated(x, w, dilation, stride=stride, interpret=interpret,
                    **epilogue_kw)


def transposed_conv2d(x, w, *, stride=2, padding=None, output_padding=1,
                      interpret=None, **epilogue_kw):
    """Fused decomposed transposed conv — any square (k, stride)."""
    if x.ndim != 4 or w.ndim != 4 or x.shape[-1] != w.shape[2]:
        raise ValueError(f"bad conv shapes {x.shape} x {w.shape}")
    if w.shape[0] != w.shape[1]:
        raise ValueError("square kernels only")
    return _tconv(x, w, stride=stride, padding=padding,
                  output_padding=output_padding, interpret=interpret,
                  **epilogue_kw)


def matmul(a, b, *, interpret=None):
    if a.shape[-1] != b.shape[0]:
        raise ValueError(f"bad matmul shapes {a.shape} x {b.shape}")
    return _matmul(a, b, interpret=interpret)


def attention(q, k, v, *, causal=True, interpret=None):
    if q.shape[-1] != k.shape[-1] or k.shape[:2] != v.shape[:2]:
        raise ValueError("bad attention shapes")
    return _flash(q, k, v, causal=causal, interpret=interpret)


# oracle aliases so callers can switch implementations uniformly
conv2d_ref = ref.conv2d_ref
dilated_conv2d_ref = ref.dilated_conv2d_ref
transposed_conv2d_ref = ref.transposed_conv2d_ref
matmul_ref = ref.matmul_ref
attention_ref = ref.attention_ref
