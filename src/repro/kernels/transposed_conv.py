"""Decomposed transposed-convolution Pallas kernel (paper §II-C, Fig. 6/9).

Implements the paper's weight decomposition for *arbitrary* ``(kernel,
stride, output_padding)``: a transposed convolution with stride ``s``
decomposes into ``s*s`` parity sub-convolutions, and the per-parity tap
schedule — which kernel taps land on real (non-zero-inserted) input for each
output parity, and at which input offset — is generated programmatically from
``(k, s, padding)`` (the ``ceil(k/s) x ceil(k/s)`` sub-kernel assignment of
paper Fig. 6).  The kernel computes all parity planes in a single pass over
each input tile — the TPU analogue of Fig. 9's schedule where all ``k*k``
weights share one input broadcast.  No zero-inserted input is ever
materialised; MACs issued == nonzero MACs.

Output is produced as ``s*s`` parity planes ``(N, s*s, Hb, Wb, Cout)`` and
interleaved into ``(N, OH, OW, Cout)`` by a reshape/transpose in the wrapper
(a layout op on TPU).

The row halo (input rows past the tile edge needed by positive tap offsets)
is assembled without overlapping BlockSpecs by passing the input twice — the
current row tile and the next — and concatenating in VMEM; negative offsets
(taps reading rows *before* the block index, which appear whenever
``padding >= s``) are absorbed by shifting the whole input down with a pad.

An optional fused epilogue (:mod:`repro.kernels.epilogue`, DESIGN.md §7) is
applied per parity plane on the fp32 accumulator — including the identically
zero planes of ``k < s`` parities, whose *epilogue* output (BN shift,
residual) is not zero.  The residual operand is de-interleaved into the same
parity-plane layout by the wrapper (a layout op).

See DESIGN.md §3 for the schedule derivation.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.epilogue import (EpilogueSpec, apply_reference, apply_tile,
                                    pack_args)
from repro.kernels.util import resolve_interpret

_NO_EP = EpilogueSpec()


def parity_schedule(k: int, s: int, p_lo: int) -> list[list[tuple[int, int]]]:
    """Per-parity tap schedule for one spatial dim (paper §II-C, Fig. 6).

    Output pixel ``y = s*b + r`` (block ``b``, parity ``r``) reads kernel tap
    ``t`` iff ``(t - p_lo + r) % s == 0``, from input index ``b + off`` with
    ``off = (r + t - p_lo) // s``.  Returns ``[(t, off), ...]`` per parity
    ``r``; a parity's list is empty when no tap hits it (possible for
    ``k < s`` — that output plane is identically zero).
    """
    return [
        [(t, (r + t - p_lo) // s) for t in range(k) if (t - p_lo + r) % s == 0]
        for r in range(s)
    ]


def _tconv_kernel(x_cur, x_nxt, w, *rest, spec: EpilogueSpec, th: int,
                  wb: int, sched, shift: int, halo: int):
    """Fused all-parity step: every live tap shares one input window."""
    out = rest[-1]
    ep_refs = rest[:-1]
    xw = x_cur[0]
    if halo > 0:
        xw = jnp.concatenate([xw, x_nxt[0][:halo]], axis=0)
    cin = xw.shape[-1]
    tc = out.shape[-1]

    def tap(oy, ox, wt):
        rows = xw[oy : oy + th, ox : ox + wb, :]
        return jax.lax.dot_general(
            rows.reshape(th * wb, cin), wt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    planes = []
    idx = 0
    for rtaps in sched:
        for ctaps in sched:
            acc = None
            for ty, oy in rtaps:
                for tx, ox in ctaps:
                    v = tap(oy + shift, ox + shift, w[ty, tx])
                    acc = v if acc is None else acc + v
            if acc is None:         # empty tap set (k < s): zero conv plane
                acc = jnp.zeros((th * wb, tc), jnp.float32)
            if not spec.empty:
                args = tuple(r[0][idx] if name == "residual" else r[...]
                             for name, r in zip(spec.slots, ep_refs))
                acc = apply_tile(spec, acc, args, flat=th * wb)
            planes.append(acc)
            idx += 1
    s2 = len(planes)
    out[0] = jnp.stack(planes, axis=0).reshape(s2, th, wb, tc).astype(out.dtype)


@functools.partial(jax.jit, static_argnames=(
    "stride", "padding", "output_padding", "th", "tc", "interpret",
    "epilogue"))
def transposed_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 2,
                      padding: int | None = None, output_padding: int = 1,
                      th: int = 8, tc: int = 128,
                      interpret: bool | None = None,
                      epilogue: EpilogueSpec | None = None,
                      scale: jax.Array | None = None,
                      shift: jax.Array | None = None,
                      alpha: jax.Array | None = None,
                      residual: jax.Array | None = None) -> jax.Array:
    """Fused decomposed transposed conv for arbitrary ``(k, stride)``.

    Differentiable: a ``jax.custom_vjp`` routes the input-gradient through
    the strided dense engine (the adjoint of upsampling is downsampling) and
    the weight-gradient through tap-gather correlations
    (:mod:`repro.core.adjoints`, DESIGN.md §6); the fused-epilogue path
    differentiates by adjoint re-entry (``adjoints.fused_epilogue_bwd``).

    Args:
      x: (N, H, W, Cin).   w: (k, k, Cin, Cout), square.
      stride: upsampling factor ``s >= 1``.
      padding: low-side pad of the zero-inserted input; ``None`` -> (k-1)//2.
      output_padding: extra high-side output size (``p_hi = padding + it``).
      th: output *block* rows per tile.  tc: Cout tile width.
      interpret: None -> auto (interpret on CPU), or an explicit override.
      epilogue: optional :class:`EpilogueSpec` fused per parity plane
        (DESIGN.md §7), with operands ``scale``/``shift``/``alpha``/
        ``residual`` to match.
    Returns:
      (N, OH, OW, Cout) with ``OH = (H-1)*s + p_lo + p_hi - k + 2``.
    """
    interpret = resolve_interpret(interpret)
    kh, kw = w.shape[0], w.shape[1]
    if kh != kw:
        raise ValueError(f"square kernels only, got {kh}x{kw}")
    p_lo = (kh - 1) // 2 if padding is None else padding
    spec = _NO_EP if epilogue is None else epilogue
    eps = pack_args(spec, scale=scale, shift=shift, alpha=alpha,
                    residual=residual)
    if stride == 1:
        # no zero-insertion -> plain dense correlation with (p_lo, p_hi) pads
        p_hi = p_lo + output_padding
        y = jax.lax.conv_general_dilated(
            x, w, window_strides=(1, 1),
            padding=[(p_lo, p_hi), (p_lo, p_hi)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        return apply_reference(spec, y, eps)
    if spec.empty:
        return _tconv_vjp(x, w, stride, p_lo, output_padding, th, tc,
                          interpret)
    return _tconv_ep_vjp(x, w, eps, spec, stride, p_lo, output_padding, th,
                         tc, interpret)


def _residual_to_planes(res: jax.Array, s: int, hb: int, wb: int, rows_p: int,
                        cout_p: int) -> jax.Array:
    """De-interleave an (N, OH, OW, C) residual into padded parity planes.

    Inverse of the wrapper's output interleave: plane ``s*ry + rx`` at block
    ``(b, c)`` holds ``res[:, s*b + ry, s*c + rx, :]`` — a reshape/transpose
    layout op, then pad to the kernel's blocked extents.
    """
    n, oh, ow, cout = res.shape
    rp = jnp.pad(res, ((0, 0), (0, hb * s - oh), (0, wb * s - ow), (0, 0)))
    rp = rp.reshape(n, hb, s, wb, s, cout).transpose(0, 2, 4, 1, 3, 5)
    rp = rp.reshape(n, s * s, hb, wb, cout)
    return jnp.pad(rp, ((0, 0), (0, 0), (0, rows_p - hb), (0, 0),
                        (0, cout_p - cout)))


def _tconv_raw(x: jax.Array, w: jax.Array, eps: tuple, spec: EpilogueSpec,
               s: int, p_lo: int, output_padding: int, th: int, tc: int,
               interpret: bool) -> jax.Array:
    n, h, w_in, cin = x.shape
    k, _, _, cout = w.shape
    p_hi = p_lo + output_padding
    oh = (h - 1) * s + p_lo + p_hi - k + 2
    ow = (w_in - 1) * s + p_lo + p_hi - k + 2
    if oh <= 0 or ow <= 0:
        raise ValueError(f"degenerate output {oh}x{ow} for input {h}x{w_in}")
    hb, wb = math.ceil(oh / s), math.ceil(ow / s)  # block rows/cols per parity

    sched = parity_schedule(k, s, p_lo)
    offs = [o for taps in sched for _, o in taps]
    shift = max(0, -min(offs))      # absorb negative offsets by shifting input
    halo = max(offs) + shift        # rows needed past the current tile

    th = max(min(th, hb), halo)     # next-tile concat must cover the halo
    n_row_tiles = math.ceil(hb / th)
    tc = min(tc, cout)
    n_cout_tiles = math.ceil(cout / tc)
    cout_p = n_cout_tiles * tc

    # rows: one extra tile keeps the next-tile BlockSpec in bounds
    rows_p = max((n_row_tiles + 1) * th, h + shift)
    rows_p = math.ceil(rows_p / th) * th
    cols_p = max(wb + halo, w_in + shift)
    xp = jnp.pad(x, ((0, 0), (shift, rows_p - h - shift),
                     (shift, cols_p - w_in - shift), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, cout_p - cout)))

    # grid order (batch, cout tile, row tile): the row stream is innermost —
    # the pipeline double-buffers consecutive input tiles (halo pair advances
    # one block per step) while the weight tile stays resident per cout tile
    grid = (n, n_cout_tiles, n_row_tiles)
    x_cur = pl.BlockSpec((1, th, cols_p, cin), lambda b, c, i: (b, i, 0, 0))
    x_nxt = pl.BlockSpec((1, th, cols_p, cin), lambda b, c, i: (b, i + 1, 0, 0))
    w_spec = pl.BlockSpec((k, k, cin, tc), lambda b, c, i: (0, 0, 0, c))
    out_spec = pl.BlockSpec((1, s * s, th, wb, tc), lambda b, c, i: (b, 0, i, 0, c))

    # epilogue operands: channel vectors tiled on the cout axis, the residual
    # de-interleaved to parity-plane layout and blocked like the output
    from repro.kernels.conv2d import _chan_operand

    ep_in, ep_specs = [], []
    for name, v in zip(spec.slots, eps):
        if name == "residual":
            if v.shape != (n, oh, ow, cout):
                raise ValueError(f"residual shape {v.shape} != output "
                                 f"{(n, oh, ow, cout)}")
            ep_in.append(_residual_to_planes(v, s, hb, wb,
                                             n_row_tiles * th, cout_p))
            ep_specs.append(pl.BlockSpec((1, s * s, th, wb, tc),
                                         lambda b, c, i: (b, 0, i, 0, c)))
        else:
            ep_in.append(_chan_operand(v, cout, cout_p))
            ep_specs.append(pl.BlockSpec((1, tc), lambda b, c, i: (0, c)))

    planes = pl.pallas_call(
        functools.partial(_tconv_kernel, spec=spec, th=th, wb=wb, sched=sched,
                          shift=shift, halo=halo),
        grid=grid,
        in_specs=[x_cur, x_nxt, w_spec, *ep_specs],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n, s * s, n_row_tiles * th, wb, cout_p), x.dtype),
        # batch/cout steps independent; sequential row stream -> Mosaic
        # overlaps each tile's DMA with the previous tile's MXU work
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, xp, wp, *ep_in)

    planes = planes[:, :, :hb, :, :cout]                   # (N, s*s, Hb, Wb, C)
    # interleave parities: out[n, s*b+ry, s*c+rx] = planes[n, s*ry+rx, b, c]
    planes = planes.reshape(n, s, s, hb, wb, cout)
    out = planes.transpose(0, 3, 1, 4, 2, 5).reshape(n, hb * s, wb * s, cout)
    return out[:, :oh, :ow, :]


def _tconv_impl(x: jax.Array, w: jax.Array, s: int, p_lo: int,
                output_padding: int, th: int, tc: int,
                interpret: bool) -> jax.Array:
    return _tconv_raw(x, w, (), _NO_EP, s, p_lo, output_padding, th, tc,
                      interpret)


# ---------------------------------------------------------------------------
# Custom VJP (DESIGN.md §6): the input-gradient of a transposed conv IS a
# strided dense convolution — it routes through the dense Pallas engine; the
# weight-gradient is a batched tap-gather correlation on the MXU.
# ---------------------------------------------------------------------------

_tconv_vjp = jax.custom_vjp(_tconv_impl, nondiff_argnums=(2, 3, 4, 5, 6, 7))


def _tconv_fwd(x, w, s, p_lo, output_padding, th, tc, interpret):
    return _tconv_impl(x, w, s, p_lo, output_padding, th, tc, interpret), (x, w)


def _tconv_bwd(s, p_lo, output_padding, th, tc, interpret, res, g):
    from repro.core import adjoints
    from repro.kernels.conv2d import conv2d as _dense_conv

    x, w = res
    k = w.shape[0]
    p_hi = p_lo + output_padding

    def conv_fn(gp, wf, stride):
        return _dense_conv(gp, wf, stride=stride, padding="VALID",
                           th=th, tc=tc, interpret=interpret)

    dx = adjoints.tconv_dx(g, w, s, p_lo, p_hi, conv_fn)
    dw = adjoints.tconv_dw(x, g, k, s, p_lo, p_hi)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_tconv_vjp.defvjp(_tconv_fwd, _tconv_bwd)


# ---------------------------------------------------------------------------
# Fused-epilogue VJP (DESIGN.md §7): adjoint re-entry through the §6 rules.
# ---------------------------------------------------------------------------

def _tconv_ep_impl(x, w, eps, spec, s, p_lo, output_padding, th, tc,
                   interpret):
    return _tconv_raw(x, w, eps, spec, s, p_lo, output_padding, th, tc,
                      interpret)


_tconv_ep_vjp = jax.custom_vjp(_tconv_ep_impl,
                               nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))


def _tconv_ep_fwd(x, w, eps, spec, s, p_lo, output_padding, th, tc, interpret):
    y = _tconv_ep_impl(x, w, eps, spec, s, p_lo, output_padding, th, tc,
                       interpret)
    return y, (x, w, eps)


def _tconv_ep_bwd(spec, s, p_lo, output_padding, th, tc, interpret, res, g):
    from repro.core import adjoints

    x, w, eps = res

    def conv_apply(xx, ww):
        return _tconv_vjp(xx, ww, s, p_lo, output_padding, th, tc, interpret)

    return adjoints.fused_epilogue_bwd(conv_apply, spec, x, w, eps, g)


_tconv_ep_vjp.defvjp(_tconv_ep_fwd, _tconv_ep_bwd)
