"""Decomposed transposed-convolution Pallas kernel (paper §II-C, Fig. 6/9).

Implements the paper's weight decomposition for the stride-2, 3x3 case used
throughout ENet's decoder: the kernel computes all four parity sub-
convolutions (center 1x1, horizontal 1x2, vertical 2x1, corners 2x2) in a
single pass over each input tile — the TPU analogue of Fig. 9's schedule
where all nine weights share one input broadcast.  No zero-inserted input is
ever materialised; MACs issued == nonzero MACs.

Output is produced as four parity planes ``(N, 4, H, W, Cout)`` and
interleaved into ``(N, 2H, 2W, Cout)`` by a reshape/transpose in the wrapper
(a layout op on TPU).

General (stride, kernel) combinations fall back to the composable jnp path in
``repro.core.transposed``; ENet only uses this fused case.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tconv_kernel(x_cur, x_nxt, w, out, *, th: int, w_in: int):
    """Fused 4-parity step: s=2, k=3, p=1, output_padding=1.

    Parity equations (b, c index the input tile; halo row/col +1):
      out[2b,   2c  ] = w[1,1] x[b, c]
      out[2b,   2c+1] = w[1,0] x[b, c] + w[1,2] x[b, c+1]
      out[2b+1, 2c  ] = w[0,1] x[b, c] + w[2,1] x[b+1, c]
      out[2b+1, 2c+1] = w[0,0] x[b,c] + w[0,2] x[b,c+1]
                      + w[2,0] x[b+1,c] + w[2,2] x[b+1,c+1]
    """
    xw = jnp.concatenate([x_cur[0], x_nxt[0][:1]], axis=0)  # (th+1, w_in+1, cin)
    cin = xw.shape[-1]
    tc = out.shape[-1]

    def tap(dy, dx, wt):
        rows = xw[dy : dy + th, dx : dx + w_in, :]
        return jax.lax.dot_general(
            rows.reshape(th * w_in, cin), wt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    ee = tap(0, 0, w[1, 1])
    eo = tap(0, 0, w[1, 0]) + tap(0, 1, w[1, 2])
    oe = tap(0, 0, w[0, 1]) + tap(1, 0, w[2, 1])
    oo = (tap(0, 0, w[0, 0]) + tap(0, 1, w[0, 2])
          + tap(1, 0, w[2, 0]) + tap(1, 1, w[2, 2]))
    planes = jnp.stack([ee, eo, oe, oo], axis=0)  # (4, th*w_in, tc)
    out[0] = planes.reshape(4, th, w_in, tc).astype(out.dtype)


@functools.partial(jax.jit, static_argnames=("th", "tc", "interpret"))
def transposed_conv2d(x: jax.Array, w: jax.Array, *, th: int = 8,
                      tc: int = 128, interpret: bool = True) -> jax.Array:
    """Fused decomposed transposed conv: s=2, k=3, padding=1, out_pad=1.

    Args:
      x: (N, H, W, Cin).   w: (3, 3, Cin, Cout).
    Returns:
      (N, 2H, 2W, Cout).
    """
    n, h, w_in, cin = x.shape
    kh, kw, _, cout = w.shape
    if (kh, kw) != (3, 3):
        raise ValueError("fused kernel covers the paper's 3x3/s2 case")

    th = min(th, h)
    n_row_tiles = math.ceil(h / th)
    h_p = n_row_tiles * th
    tc = min(tc, cout)
    n_cout_tiles = math.ceil(cout / tc)
    cout_p = n_cout_tiles * tc

    # halo: +1 row (via next-tile concat) and +1 col (padded); plus one extra
    # row tile so the next-tile BlockSpec stays in bounds.
    xp = jnp.pad(x, ((0, 0), (0, h_p - h + th), (0, 1), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, cout_p - cout)))

    grid = (n, n_row_tiles, n_cout_tiles)
    x_cur = pl.BlockSpec((1, th, w_in + 1, cin), lambda b, i, c: (b, i, 0, 0))
    x_nxt = pl.BlockSpec((1, th, w_in + 1, cin), lambda b, i, c: (b, i + 1, 0, 0))
    w_spec = pl.BlockSpec((3, 3, cin, tc), lambda b, i, c: (0, 0, 0, c))
    out_spec = pl.BlockSpec((1, 4, th, w_in, tc), lambda b, i, c: (b, 0, i, 0, c))

    planes = pl.pallas_call(
        functools.partial(_tconv_kernel, th=th, w_in=w_in),
        grid=grid,
        in_specs=[x_cur, x_nxt, w_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, 4, h_p, w_in, cout_p), x.dtype),
        interpret=interpret,
    )(xp, xp, wp)

    planes = planes[:, :, :h, :, :cout]                    # (N, 4, H, W, C)
    # interleave parities: out[n, 2b+ry, 2c+rx] = planes[n, 2*ry+rx, b, c]
    planes = planes.reshape(n, 2, 2, h, w_in, cout)
    out = planes.transpose(0, 3, 1, 4, 2, 5).reshape(n, 2 * h, 2 * w_in, cout)
    return out
