"""Decomposed transposed-convolution Pallas kernel (paper §II-C, Fig. 6/9).

Implements the paper's weight decomposition for *arbitrary* ``(kernel,
stride, output_padding)``: a transposed convolution with stride ``s``
decomposes into ``s*s`` parity sub-convolutions, and the per-parity tap
schedule — which kernel taps land on real (non-zero-inserted) input for each
output parity, and at which input offset — is generated programmatically from
``(k, s, padding)`` (the ``ceil(k/s) x ceil(k/s)`` sub-kernel assignment of
paper Fig. 6).  The kernel computes all parity planes in a single pass over
each input tile — the TPU analogue of Fig. 9's schedule where all ``k*k``
weights share one input broadcast.  No zero-inserted input is ever
materialised; MACs issued == nonzero MACs.

Output is produced as ``s*s`` parity planes ``(N, s*s, Hb, Wb, Cout)`` and
interleaved into ``(N, OH, OW, Cout)`` by a reshape/transpose in the wrapper
(a layout op on TPU).

The row halo (input rows past the tile edge needed by positive tap offsets)
is assembled without overlapping BlockSpecs by passing the input twice — the
current row tile and the next — and concatenating in VMEM; negative offsets
(taps reading rows *before* the block index, which appear whenever
``padding >= s``) are absorbed by shifting the whole input down with a pad.

See DESIGN.md §3 for the schedule derivation.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import resolve_interpret


def parity_schedule(k: int, s: int, p_lo: int) -> list[list[tuple[int, int]]]:
    """Per-parity tap schedule for one spatial dim (paper §II-C, Fig. 6).

    Output pixel ``y = s*b + r`` (block ``b``, parity ``r``) reads kernel tap
    ``t`` iff ``(t - p_lo + r) % s == 0``, from input index ``b + off`` with
    ``off = (r + t - p_lo) // s``.  Returns ``[(t, off), ...]`` per parity
    ``r``; a parity's list is empty when no tap hits it (possible for
    ``k < s`` — that output plane is identically zero).
    """
    return [
        [(t, (r + t - p_lo) // s) for t in range(k) if (t - p_lo + r) % s == 0]
        for r in range(s)
    ]


def _tconv_kernel(x_cur, x_nxt, w, out, *, th: int, wb: int,
                  sched, shift: int, halo: int):
    """Fused all-parity step: every live tap shares one input window."""
    xw = x_cur[0]
    if halo > 0:
        xw = jnp.concatenate([xw, x_nxt[0][:halo]], axis=0)
    cin = xw.shape[-1]
    tc = out.shape[-1]

    def tap(oy, ox, wt):
        rows = xw[oy : oy + th, ox : ox + wb, :]
        return jax.lax.dot_general(
            rows.reshape(th * wb, cin), wt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    planes = []
    for rtaps in sched:
        for ctaps in sched:
            if not rtaps or not ctaps:
                planes.append(jnp.zeros((th * wb, tc), jnp.float32))
                continue
            acc = None
            for ty, oy in rtaps:
                for tx, ox in ctaps:
                    v = tap(oy + shift, ox + shift, w[ty, tx])
                    acc = v if acc is None else acc + v
            planes.append(acc)
    s2 = len(planes)
    out[0] = jnp.stack(planes, axis=0).reshape(s2, th, wb, tc).astype(out.dtype)


@functools.partial(jax.jit, static_argnames=(
    "stride", "padding", "output_padding", "th", "tc", "interpret"))
def transposed_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 2,
                      padding: int | None = None, output_padding: int = 1,
                      th: int = 8, tc: int = 128,
                      interpret: bool | None = None) -> jax.Array:
    """Fused decomposed transposed conv for arbitrary ``(k, stride)``.

    Differentiable: a ``jax.custom_vjp`` routes the input-gradient through
    the strided dense engine (the adjoint of upsampling is downsampling) and
    the weight-gradient through tap-gather correlations
    (:mod:`repro.core.adjoints`, DESIGN.md §6).

    Args:
      x: (N, H, W, Cin).   w: (k, k, Cin, Cout), square.
      stride: upsampling factor ``s >= 1``.
      padding: low-side pad of the zero-inserted input; ``None`` -> (k-1)//2.
      output_padding: extra high-side output size (``p_hi = padding + it``).
      th: output *block* rows per tile.  tc: Cout tile width.
      interpret: None -> auto (interpret on CPU), or an explicit override.
    Returns:
      (N, OH, OW, Cout) with ``OH = (H-1)*s + p_lo + p_hi - k + 2``.
    """
    interpret = resolve_interpret(interpret)
    kh, kw = w.shape[0], w.shape[1]
    if kh != kw:
        raise ValueError(f"square kernels only, got {kh}x{kw}")
    p_lo = (kh - 1) // 2 if padding is None else padding
    if stride == 1:
        # no zero-insertion -> plain dense correlation with (p_lo, p_hi) pads
        p_hi = p_lo + output_padding
        return jax.lax.conv_general_dilated(
            x, w, window_strides=(1, 1),
            padding=[(p_lo, p_hi), (p_lo, p_hi)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
    return _tconv_vjp(x, w, stride, p_lo, output_padding, th, tc, interpret)


def _tconv_impl(x: jax.Array, w: jax.Array, s: int, p_lo: int,
                output_padding: int, th: int, tc: int,
                interpret: bool) -> jax.Array:
    n, h, w_in, cin = x.shape
    k, _, _, cout = w.shape
    p_hi = p_lo + output_padding
    oh = (h - 1) * s + p_lo + p_hi - k + 2
    ow = (w_in - 1) * s + p_lo + p_hi - k + 2
    if oh <= 0 or ow <= 0:
        raise ValueError(f"degenerate output {oh}x{ow} for input {h}x{w_in}")
    hb, wb = math.ceil(oh / s), math.ceil(ow / s)  # block rows/cols per parity

    sched = parity_schedule(k, s, p_lo)
    offs = [o for taps in sched for _, o in taps]
    shift = max(0, -min(offs))      # absorb negative offsets by shifting input
    halo = max(offs) + shift        # rows needed past the current tile

    th = max(min(th, hb), halo)     # next-tile concat must cover the halo
    n_row_tiles = math.ceil(hb / th)
    tc = min(tc, cout)
    n_cout_tiles = math.ceil(cout / tc)
    cout_p = n_cout_tiles * tc

    # rows: one extra tile keeps the next-tile BlockSpec in bounds
    rows_p = max((n_row_tiles + 1) * th, h + shift)
    rows_p = math.ceil(rows_p / th) * th
    cols_p = max(wb + halo, w_in + shift)
    xp = jnp.pad(x, ((0, 0), (shift, rows_p - h - shift),
                     (shift, cols_p - w_in - shift), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, cout_p - cout)))

    grid = (n, n_row_tiles, n_cout_tiles)
    x_cur = pl.BlockSpec((1, th, cols_p, cin), lambda b, i, c: (b, i, 0, 0))
    x_nxt = pl.BlockSpec((1, th, cols_p, cin), lambda b, i, c: (b, i + 1, 0, 0))
    w_spec = pl.BlockSpec((k, k, cin, tc), lambda b, i, c: (0, 0, 0, c))
    out_spec = pl.BlockSpec((1, s * s, th, wb, tc), lambda b, i, c: (b, 0, i, 0, c))

    planes = pl.pallas_call(
        functools.partial(_tconv_kernel, th=th, wb=wb, sched=sched,
                          shift=shift, halo=halo),
        grid=grid,
        in_specs=[x_cur, x_nxt, w_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n, s * s, n_row_tiles * th, wb, cout_p), x.dtype),
        interpret=interpret,
    )(xp, xp, wp)

    planes = planes[:, :, :hb, :, :cout]                   # (N, s*s, Hb, Wb, C)
    # interleave parities: out[n, s*b+ry, s*c+rx] = planes[n, s*ry+rx, b, c]
    planes = planes.reshape(n, s, s, hb, wb, cout)
    out = planes.transpose(0, 3, 1, 4, 2, 5).reshape(n, hb * s, wb * s, cout)
    return out[:, :oh, :ow, :]


# ---------------------------------------------------------------------------
# Custom VJP (DESIGN.md §6): the input-gradient of a transposed conv IS a
# strided dense convolution — it routes through the dense Pallas engine; the
# weight-gradient is a batched tap-gather correlation on the MXU.
# ---------------------------------------------------------------------------

_tconv_vjp = jax.custom_vjp(_tconv_impl, nondiff_argnums=(2, 3, 4, 5, 6, 7))


def _tconv_fwd(x, w, s, p_lo, output_padding, th, tc, interpret):
    return _tconv_impl(x, w, s, p_lo, output_padding, th, tc, interpret), (x, w)


def _tconv_bwd(s, p_lo, output_padding, th, tc, interpret, res, g):
    from repro.core import adjoints
    from repro.kernels.conv2d import conv2d as _dense_conv

    x, w = res
    k = w.shape[0]
    p_hi = p_lo + output_padding

    def conv_fn(gp, wf, stride):
        return _dense_conv(gp, wf, stride=stride, padding="VALID",
                           th=th, tc=tc, interpret=interpret)

    dx = adjoints.tconv_dx(g, w, s, p_lo, p_hi, conv_fn)
    dw = adjoints.tconv_dw(x, g, k, s, p_lo, p_hi)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_tconv_vjp.defvjp(_tconv_fwd, _tconv_bwd)
