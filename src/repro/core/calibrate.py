"""Calibration layer: modeled cycles -> measured wall time (DESIGN.md §10).

The cycle model (:mod:`repro.core.cycle_model`) is purely analytical — it
counts cycles on the paper's 168-MAC array.  This module grounds it: it
captures per-op wall times from the executable engines (blocking timer,
best-of-N), pairs each measurement with the modeled cycle count of the same
geometry, and fits a least-squares affine map

    ``us_measured ~= a * cycles_modeled + b``

per ``(engine kind, backend, device kind, dtype)`` key.  ``a`` is the
effective microseconds-per-modeled-cycle of this host (its inverse is the
host's "array rate"), ``b`` the fixed per-call dispatch overhead.  The
dtype is part of the key because bf16 halves the bytes moved per modeled
cycle — a single fit shared across precisions mispredicts both (the
schema-2 bugfix; schema-1 payloads load with their keys mapped to
``/float32``).  Prediction-error
reports (per-sample relative error + MAPE per key) are emitted into
``BENCH_<rev>.json`` by ``benchmarks/run.py`` and gated over revisions by
``benchmarks/perf_gate.py``.

Consumers:

* ``benchmarks/run.py`` — ``capture_and_fit()`` builds the ``calibration``
  section of the bench JSON (samples, coefficients, error report);
* ``repro.kernels.autotune`` — ``tile_scores()`` ranks sweep candidates so
  only the model-promising few are timed;
* ``repro.launch.serve_gen.GenServer`` — ``predict_layers()`` turns a
  workload's layer table into a calibrated admission estimate;
* ``cycle_model.serve_report(..., calibration=...)`` — calibrated latency
  keys next to the 500 MHz array numbers.

Everything here is dependency-free beyond jax/numpy; the fit is closed-form
(no scipy).
"""

from __future__ import annotations

import json
import math
import os
import pathlib
from dataclasses import asdict, dataclass

from repro.core import cycle_model as cm
from repro.core.enet_spec import ConvLayer

#: engine kinds, matching ``repro.kernels.autotune.KINDS``
KINDS = ("dense", "dilated", "tconv")

#: ``ConvLayer.kind`` -> engine kind, for costing layer tables
KIND_OF_LAYER = {"conv": "dense", "dilated": "dilated", "transposed": "tconv"}


def _device_kind() -> str:
    import jax

    try:
        kind = jax.devices()[0].device_kind
    except Exception:
        kind = "unknown"
    return "".join(c if c.isalnum() else "_" for c in kind)


def key_of(kind: str, backend: str, device_kind: str | None = None,
           dtype: str = "float32") -> str:
    """Canonical calibration key ``kind/backend/device_kind/dtype``."""
    if kind not in KINDS:
        raise ValueError(f"unknown engine kind {kind!r}; known: {KINDS}")
    return f"{kind}/{backend}/{device_kind or _device_kind()}/{dtype}"


@dataclass(frozen=True)
class Sample:
    """One (modeled cycles, measured wall time) observation."""
    kind: str           # dense | dilated | tconv
    backend: str        # xla | pallas
    device_kind: str
    name: str           # geometry tag, e.g. "dense/32x32x16->32/k3s1"
    cycles: float       # modeled cycles (cycle_model costing of the geometry)
    us: float           # measured microseconds (blocking, best-of-N)
    dtype: str = "float32"      # compute dtype the measurement ran in

    @property
    def key(self) -> str:
        return key_of(self.kind, self.backend, self.device_kind, self.dtype)


@dataclass
class Coeffs:
    """Affine fit ``us = a * cycles + b`` for one key."""
    a_us_per_cycle: float
    b_us: float
    n: int              # samples the fit saw

    def predict(self, cycles: float) -> float:
        return self.a_us_per_cycle * cycles + self.b_us


def _fit_one(pairs: list[tuple[float, float]]) -> Coeffs:
    """Closed-form least squares on (cycles, us) pairs.

    Degenerate cases are resolved toward physical sanity: a single sample
    (or a single distinct abscissa) fits a pure slope through the origin;
    negative intercepts (tiny-op noise) are clamped to 0 and the slope
    refit; the slope itself is clamped >= 0.
    """
    n = len(pairs)
    if n == 0:
        raise ValueError("cannot fit a calibration on zero samples")
    sx = sum(c for c, _ in pairs)
    sy = sum(u for _, u in pairs)
    sxx = sum(c * c for c, _ in pairs)
    sxy = sum(c * u for c, u in pairs)
    denom = n * sxx - sx * sx
    if n == 1 or abs(denom) < 1e-12 * max(sxx, 1.0):
        a = (sy / sx) if sx else 0.0
        return Coeffs(max(a, 0.0), 0.0, n)
    a = (n * sxy - sx * sy) / denom
    b = (sy - a * sx) / n
    if b < 0.0 or a < 0.0:
        # refit through the origin — a negative dispatch overhead (or a
        # negative rate) is measurement noise, not physics
        a = (sxy / sxx) if sxx else 0.0
        return Coeffs(max(a, 0.0), 0.0, n)
    return Coeffs(a, b, n)


class Calibration:
    """Fitted cycles->us maps, one :class:`Coeffs` per key."""

    def __init__(self, coeffs: dict[str, Coeffs] | None = None):
        self.coeffs: dict[str, Coeffs] = dict(coeffs or {})

    # ------------------------------------------------------------- fitting --
    @classmethod
    def fit(cls, samples: list[Sample]) -> "Calibration":
        by_key: dict[str, list[tuple[float, float]]] = {}
        for s in samples:
            by_key.setdefault(s.key, []).append((s.cycles, s.us))
        return cls({k: _fit_one(v) for k, v in sorted(by_key.items())})

    # ---------------------------------------------------------- prediction --
    def _coeffs_for(self, kind: str, backend: str,
                    device_kind: str | None, dtype: str):
        """Fit for a key, falling back to the fp32 fit when a non-fp32
        dtype is unfitted — fp32 wall is an upper bound for bf16, so the
        fallback is a conservative estimate rather than "no estimate"."""
        co = self.coeffs.get(key_of(kind, backend, device_kind, dtype))
        if co is None and dtype != "float32":
            co = self.coeffs.get(key_of(kind, backend, device_kind))
        return co

    def predict(self, kind: str, cycles: float, *, backend: str = "xla",
                device_kind: str | None = None,
                dtype: str = "float32") -> float | None:
        """Predicted wall microseconds, or ``None`` if the key is unfitted."""
        co = self._coeffs_for(kind, backend, device_kind, dtype)
        return None if co is None else co.predict(cycles)

    def predict_layers(self, layers: list[ConvLayer], *, backend: str = "xla",
                       device_kind: str | None = None,
                       dtype: str = "float32") -> float | None:
        """Calibrated microseconds for one pass over a layer table.

        Sums per-layer predictions (each layer is one engine dispatch, so
        each pays its key's ``b_us`` overhead).  Returns ``None`` if any
        layer's kind has no fitted coefficients — a partial estimate would
        silently undercount.
        """
        split = self.predict_layers_split(layers, backend=backend,
                                          device_kind=device_kind,
                                          dtype=dtype)
        return None if split is None else split[0] + split[1]

    def predict_layers_split(self, layers: list[ConvLayer], *,
                             backend: str = "xla",
                             device_kind: str | None = None,
                             dtype: str = "float32"
                             ) -> tuple[float, float] | None:
        """``(compute_us, dispatch_us)`` for one pass over a layer table.

        ``compute_us`` is the fitted-slope part (``a * cycles`` per layer) —
        it scales with every pass; ``dispatch_us`` is the summed per-layer
        fixed overhead (``b_us`` per engine dispatch) — a ``K``-step fused
        scan pays it once per *dispatch*, not once per step, which is what
        ``cycle_model.serve_report(scan_steps=...)`` amortises.  Same
        coverage gate as :meth:`predict_layers`: ``None`` when any layer's
        kind has no fitted coefficients.
        """
        compute = dispatch = 0.0
        for l in layers:
            co = self._coeffs_for(KIND_OF_LAYER[l.kind], backend,
                                  device_kind, dtype)
            if co is None:
                return None
            compute += co.a_us_per_cycle * cm.cycles_our_decomposed(l)
            dispatch += co.b_us
        return compute, dispatch

    # ------------------------------------------------------ error reports --
    def error_report(self, samples: list[Sample]) -> dict[str, dict]:
        """Prediction-error table per key: the calibrated-model residuals.

        ``err_pct = 100 * (predicted - measured) / measured`` per sample;
        ``mape_pct`` is the mean absolute of those — the headline number the
        perf gate tracks over revisions.
        """
        out: dict[str, dict] = {}
        for s in samples:
            co = self.coeffs.get(s.key)
            if co is None:
                continue
            pred = co.predict(s.cycles)
            err_pct = 100.0 * (pred - s.us) / s.us if s.us else 0.0
            e = out.setdefault(s.key, {
                "a_us_per_cycle": co.a_us_per_cycle, "b_us": co.b_us,
                "n": co.n, "samples": [],
            })
            e["samples"].append({
                "name": s.name, "cycles": s.cycles,
                "us": round(s.us, 3), "pred_us": round(pred, 3),
                "err_pct": round(err_pct, 2),
            })
        for e in out.values():
            errs = [abs(r["err_pct"]) for r in e["samples"]]
            e["mape_pct"] = round(sum(errs) / len(errs), 2) if errs else 0.0
            e["max_abs_err_pct"] = round(max(errs), 2) if errs else 0.0
        return out

    # --------------------------------------------------------- persistence --
    def to_payload(self) -> dict:
        return {"schema": 2,
                "coeffs": {k: asdict(v) for k, v in sorted(self.coeffs.items())}}

    @classmethod
    def from_payload(cls, payload: dict) -> "Calibration":
        """Load a payload; schema-1 keys (no dtype segment) map to fp32.

        Pre-dtype caches were fitted exclusively on fp32 captures, so
        ``kind/backend/device`` upgrades losslessly to
        ``kind/backend/device/float32``.
        """
        coeffs = {}
        for k, v in payload.get("coeffs", {}).items():
            if k.count("/") == 2:       # schema 1: dtype segment missing
                k = f"{k}/float32"
            coeffs[k] = Coeffs(**v)
        return cls(coeffs)

    def save(self, path: str | pathlib.Path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(json.dumps(self.to_payload(), indent=1))
        tmp.replace(p)

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "Calibration":
        return cls.from_payload(json.loads(pathlib.Path(path).read_text()))


def default_cache_path() -> pathlib.Path:
    """On-disk home of the host's calibration table (mirrors autotune's)."""
    base = os.environ.get("REPRO_CALIBRATION_CACHE")
    root = pathlib.Path(base) if base else (
        pathlib.Path.home() / ".cache" / "repro-calibration")
    return root / f"{_device_kind()}-v1.json"


# ---------------------------------------------------------------------------
# Capture: run geometries through the real engines, timed + modeled
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CaptureCase:
    """One geometry to measure: enough to build both the executable call and
    the :class:`ConvLayer` the cycle model costs."""
    kind: str
    x_shape: tuple      # (N, H, W, Cin)
    w_shape: tuple      # (kh, kw, Cin, Cout)
    stride: int = 1
    dilation: int = 1
    output_padding: int = 1     # tconv only
    dtype: str = "float32"      # compute dtype the engines run in

    @property
    def name(self) -> str:
        n, h, w, cin = self.x_shape
        kh, kw, _, cout = self.w_shape
        tag = "" if self.dtype == "float32" else f"/{self.dtype}"
        return (f"{self.kind}/{n}x{h}x{w}x{cin}->{cout}"
                f"/k{kh}s{self.stride}d{self.dilation}{tag}")


def layer_of(case: CaptureCase) -> ConvLayer:
    """The :class:`ConvLayer` whose modeled cycles match one capture case."""
    n, h, w, cin = case.x_shape
    kh, kw, _, cout = case.w_shape
    if case.kind == "dense":
        ho, wo = -(-h // case.stride), -(-w // case.stride)
        return ConvLayer(case.name, "conv", ho, wo, cin, cout, kh, kw,
                         stride=case.stride)
    if case.kind == "dilated":
        ho, wo = -(-h // case.stride), -(-w // case.stride)
        return ConvLayer(case.name, "dilated", ho, wo, cin, cout, kh, kw,
                         D=case.dilation - 1, stride=case.stride,
                         group="dilated")
    from repro.core import transposed as tr

    p_lo = (kh - 1) // 2
    ho = tr.out_size(h, case.stride, kh, p_lo, p_lo + case.output_padding)
    wo = tr.out_size(w, case.stride, kw, p_lo, p_lo + case.output_padding)
    return ConvLayer(case.name, "transposed", ho, wo, cin, cout, kh, kw,
                     stride=case.stride, group="transposed",
                     output_padding=case.output_padding, padding=p_lo)


def modeled_cycles(case: CaptureCase) -> float:
    """Modeled decomposed cycles of one case (batch scales linearly)."""
    return case.x_shape[0] * cm.cycles_our_decomposed(layer_of(case))


def default_cases(smoke: bool = True) -> list[CaptureCase]:
    """The capture sweep: a few sizes per engine kind so each key's fit sees
    a spread of cycle counts (slope + intercept need >= 2 abscissae)."""
    if smoke:
        hws = (16, 32, 48)      # 3 abscissae: the affine fit has residuals
    else:
        hws = (16, 32, 64, 96, 128)
    cases = []
    for hw in hws:
        c = 16
        cases.append(CaptureCase("dense", (1, hw, hw, c), (3, 3, c, c)))
        cases.append(CaptureCase("dilated", (1, hw, hw, c), (3, 3, c, c),
                                 dilation=4))
        cases.append(CaptureCase("tconv", (1, hw, hw, c), (3, 3, c, c),
                                 stride=2))
    return cases


def measure_case(case: CaptureCase, *, backend: str = "xla",
                 iters: int = 3) -> float:
    """Blocking best-of-``iters`` wall microseconds of one engine dispatch."""
    import jax
    import jax.numpy as jnp

    from repro.core.decompose import conv2d
    from repro.kernels.util import time_call

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, case.x_shape, jnp.float32).astype(case.dtype)
    w = jax.random.normal(k2, case.w_shape, jnp.float32).astype(case.dtype)
    call = jax.jit(lambda a, b: conv2d(
        a, b, stride=case.stride, dilation=case.dilation,
        transposed=case.kind == "tconv",
        output_padding=case.output_padding if case.kind == "tconv" else 0,
        backend=backend))
    return time_call(call, x, w, iters=iters) * 1e6


def capture_samples(*, smoke: bool = True, backends: tuple[str, ...] = ("xla",),
                    iters: int = 3, cases: list[CaptureCase] | None = None,
                    dtypes: tuple[str, ...] = ("float32",)) -> list[Sample]:
    """Measure the capture sweep on this host; returns fit-ready samples.

    ``backends`` defaults to xla only — the pallas kernels run in interpret
    mode on CPU hosts, where wall time measures the interpreter, not the
    kernel; pass ``("xla", "pallas")`` on a real accelerator (or to track
    the interpret-mode trajectory explicitly).  Each dtype in ``dtypes``
    re-times the sweep in that precision and lands under its own fit key.
    """
    from dataclasses import replace

    dev = _device_kind()
    cases = default_cases(smoke) if cases is None else cases
    out = []
    for backend in backends:
        for dtype in dtypes:
            for case in cases:
                case = replace(case, dtype=dtype)
                us = measure_case(case, backend=backend, iters=iters)
                out.append(Sample(case.kind, backend, dev, case.name,
                                  modeled_cycles(case), us, dtype=dtype))
    return out


def capture_and_fit(*, smoke: bool = True,
                    backends: tuple[str, ...] = ("xla",),
                    iters: int = 3,
                    dtypes: tuple[str, ...] = ("float32", "bfloat16")) -> dict:
    """The ``calibration`` section of ``BENCH_<rev>.json``: capture, fit,
    and report prediction errors in one payload.  Captures fp32 *and* bf16
    by default so every precision the engines serve has its own fit."""
    samples = capture_samples(smoke=smoke, backends=backends, iters=iters,
                              dtypes=dtypes)
    calib = Calibration.fit(samples)
    return {
        "device_kind": _device_kind(),
        "smoke": smoke,
        "fit": calib.to_payload(),
        "errors": calib.error_report(samples),
    }


# ---------------------------------------------------------------------------
# Tile-candidate scoring (consumed by repro.kernels.autotune)
# ---------------------------------------------------------------------------

def tile_scores(h_out: int, cout: int, cands: list[tuple[int, int]],
                *, kind: str = "dense", backend: str = "xla",
                base_cycles: float | None = None,
                calibration: "Calibration | None" = None,
                dtype: str = "float32"
                ) -> list[tuple[float, tuple[int, int]]]:
    """Model-driven score per ``(th, tc)`` candidate (lower is better).

    The analytic part is tile-quantization waste: a ``(th, tc)`` grid pads
    the output to ``ceil(h_out/th)*th x ceil(cout/tc)*tc``, so the padded
    fraction is the work multiplier.  When a :class:`Calibration` knows this
    ``(kind, backend)`` key, its fitted per-call overhead ``b_us`` (relative
    to the modeled compute time ``a * cycles``) weights a per-grid-cell
    launch term — small tiles mean more cells, and on hosts where dispatch
    overhead dominates the calibrated score prunes them; without a fit the
    cell term uses a conservative constant weight.

    Returns ``(score, cand)`` sorted ascending, ties keeping candidate
    order (same determinism rule as the sweep itself).
    """
    cell_w = 1e-3
    if calibration is not None and base_cycles:
        co = calibration.coeffs.get(key_of(kind, backend, dtype=dtype))
        if co is None:      # fall back to the fp32 fit of the same engine
            co = calibration.coeffs.get(key_of(kind, backend))
        if co is not None and co.a_us_per_cycle > 0:
            compute_us = co.a_us_per_cycle * base_cycles
            if compute_us > 0:
                cell_w = co.b_us / compute_us
    scored = []
    for i, (th, tc) in enumerate(cands):
        pad = (math.ceil(h_out / th) * th / h_out) * \
              (math.ceil(cout / tc) * tc / cout)
        cells = math.ceil(h_out / th) * math.ceil(cout / tc)
        scored.append((pad + cell_w * cells, i, (th, tc)))
    scored.sort(key=lambda t: (t[0], t[1]))
    return [(s, c) for s, _, c in scored]


__all__ = [
    "KINDS", "KIND_OF_LAYER", "Sample", "Coeffs", "Calibration",
    "CaptureCase", "key_of", "layer_of", "modeled_cycles", "default_cases",
    "measure_case", "capture_samples", "capture_and_fit", "tile_scores",
    "default_cache_path",
]
