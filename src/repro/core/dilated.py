"""Input decomposition for dilated convolutions (paper §II-B).

A dilated convolution with dilation rate ``d = D + 1`` (``D`` zeros inserted
between adjacent weight taps, effective kernel ``(d*(k-1)+1)``) touches, for the
output pixel at ``(y, x)``, only input pixels whose coordinates are congruent to
``(y, x) mod d``.  The input therefore splits exactly into ``d**2`` *phase
blocks* — block ``(i, j)`` holds input pixels at ``x[i::d, j::d]`` — and the
dilated convolution is equivalent to ``d**2`` independent *dense* SAME
convolutions of each phase block with the compact ``k x k`` kernel, stitched
back by interleaving.

This file provides three executable forms, all NHWC / HWIO:

* :func:`dilated_conv2d_reference` — XLA oracle (``rhs_dilation``).
* :func:`dilated_conv2d_naive` — what a dense accelerator does naively: the
  kernel is explicitly zero-inserted to its enlarged ``(d*(k-1)+1)`` footprint
  and convolved densely.  Numerically identical to the oracle but performs the
  full zero-laden MAC count; used as the cycle-model "ideal dense" workload.
* :func:`dilated_conv2d_decomposed` — the paper's method: phase split ->
  dense conv -> stitch.  Two execution strategies:

  - ``ragged``: faithful to the paper — each of the ``d**2`` ragged blocks is
    convolved separately (matches Fig. 4 block shapes).
  - ``batched``: TPU-native beyond-paper variant — the input is padded up to a
    multiple of ``d``, the phases are stacked on the batch axis and executed as
    ONE dense convolution (full MXU occupancy even for small phase extents).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

_DIMS = ("NHWC", "HWIO", "NHWC")


def same_pad(k: int) -> int:
    """Padding for SAME output with an odd kernel of size ``k``."""
    if k % 2 != 1:
        raise ValueError(f"SAME padding defined for odd kernels only, got k={k}")
    return (k - 1) // 2


def effective_kernel_size(k: int, dilation: int) -> int:
    """Zero-inserted footprint: ``(2*D + k)`` for step ``d = D+1`` == d*(k-1)+1."""
    return dilation * (k - 1) + 1


def dilated_conv2d_reference(x: jax.Array, w: jax.Array, dilation: int) -> jax.Array:
    """XLA oracle: SAME dilated convolution via ``rhs_dilation``.

    Args:
      x: (N, H, W, Cin).
      w: (k, k, Cin, Cout) compact (non-dilated) kernel.
      dilation: step ``d = D + 1`` (``d = 1`` is a plain dense convolution).
    Returns:
      (N, H, W, Cout) — output spatially equal to input (SAME).
    """
    k = w.shape[0]
    pad = same_pad(effective_kernel_size(k, dilation))
    return lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=[(pad, pad), (pad, pad)],
        rhs_dilation=(dilation, dilation), dimension_numbers=_DIMS,
    )


def zero_insert_weight(w: jax.Array, dilation: int) -> jax.Array:
    """Explicitly materialise the enlarged zero-inserted kernel (Fig. 2)."""
    k, _, cin, cout = w.shape
    ke = effective_kernel_size(k, dilation)
    we = jnp.zeros((ke, ke, cin, cout), w.dtype)
    return we.at[::dilation, ::dilation].set(w)


def dilated_conv2d_naive(x: jax.Array, w: jax.Array, dilation: int) -> jax.Array:
    """Dense execution of the zero-inserted kernel — the paper's baseline."""
    we = zero_insert_weight(w, dilation)
    pad = same_pad(we.shape[0])
    return lax.conv_general_dilated(
        x, we, window_strides=(1, 1), padding=[(pad, pad), (pad, pad)],
        dimension_numbers=_DIMS,
    )


def phase_split(x: jax.Array, d: int) -> list[list[jax.Array]]:
    """Split NHWC input into ``d x d`` ragged phase blocks (paper Fig. 4).

    Block ``(i, j)`` has shape ``(N, ceil((H-i)/d), ceil((W-j)/d), C)``.
    """
    return [[x[:, i::d, j::d, :] for j in range(d)] for i in range(d)]


def phase_stitch(blocks: list[list[jax.Array]], out_shape: tuple[int, ...]) -> jax.Array:
    """Interleave ``d x d`` phase outputs back into a dense NHWC tensor."""
    d = len(blocks)
    out = jnp.zeros(out_shape, blocks[0][0].dtype)
    for i in range(d):
        for j in range(d):
            out = out.at[:, i::d, j::d, :].set(blocks[i][j])
    return out


def _phase_to_batch(x: jax.Array, d: int) -> tuple[jax.Array, int, int]:
    """Pad H, W up to multiples of ``d`` and stack phases on the batch axis.

    Returns (stacked ``(d*d*N, H//d, W//d, C)``, padded H, padded W).  Padding
    with zeros is exact: the oracle's SAME conv also pads with zeros, and the
    excess rows are dropped at stitch time.
    """
    n, h, w_, c = x.shape
    hp, wp = math.ceil(h / d) * d, math.ceil(w_ / d) * d
    x = jnp.pad(x, ((0, 0), (0, hp - h), (0, wp - w_), (0, 0)))
    # (N, H/d, d, W/d, d, C) -> (d, d, N, H/d, W/d, C) -> merge phases into batch
    x = x.reshape(n, hp // d, d, wp // d, d, c).transpose(2, 4, 0, 1, 3, 5)
    return x.reshape(d * d * n, hp // d, wp // d, c), hp, wp


def _batch_to_phase(y: jax.Array, d: int, n: int, h: int, w_: int) -> jax.Array:
    """Inverse of :func:`_phase_to_batch` (crops the pad-up rows/cols)."""
    _, hb, wb, c = y.shape
    y = y.reshape(d, d, n, hb, wb, c).transpose(2, 3, 0, 4, 1, 5)
    y = y.reshape(n, hb * d, wb * d, c)
    return y[:, :h, :w_, :]


@partial(jax.jit, static_argnames=("dilation", "strategy"))
def dilated_conv2d_decomposed(
    x: jax.Array, w: jax.Array, dilation: int, strategy: str = "batched"
) -> jax.Array:
    """The paper's method: phase decomposition -> dense conv -> stitch.

    ``strategy='ragged'`` runs the d**2 ragged blocks separately (faithful to
    the paper's schedule); ``strategy='batched'`` phase-batches them into one
    dense convolution (TPU-native, beyond-paper).  Both are exact.
    """
    d = dilation
    if d == 1:
        return dilated_conv2d_reference(x, w, 1)
    k = w.shape[0]
    pad = same_pad(k)
    if strategy == "ragged":
        blocks = phase_split(x, d)
        outs = [
            [
                lax.conv_general_dilated(
                    b, w, window_strides=(1, 1), padding=[(pad, pad), (pad, pad)],
                    dimension_numbers=_DIMS,
                )
                for b in row
            ]
            for row in blocks
        ]
        n, h, w_, _ = x.shape
        return phase_stitch(outs, (n, h, w_, w.shape[-1]))
    if strategy == "batched":
        n, h, w_, _ = x.shape
        xb, _, _ = _phase_to_batch(x, d)
        yb = lax.conv_general_dilated(
            xb, w, window_strides=(1, 1), padding=[(pad, pad), (pad, pad)],
            dimension_numbers=_DIMS,
        )
        return _batch_to_phase(yb, d, n, h, w_)
    raise ValueError(f"unknown strategy {strategy!r}")


# ---------------------------------------------------------------------------
# MAC counting (drives the cycle model and the paper-claim benchmarks)
# ---------------------------------------------------------------------------

def macs_dense(h: int, w: int, cin: int, cout: int, k: int, dilation: int = 1) -> int:
    """MACs of the *naive dense* execution: enlarged kernel incl. zeros."""
    ke = effective_kernel_size(k, dilation)
    return h * w * cin * cout * ke * ke


def macs_nonzero(h: int, w: int, cin: int, cout: int, k: int) -> int:
    """Ideal sparse MACs: only the k*k nonzero taps (interior approximation)."""
    return h * w * cin * cout * k * k


def macs_decomposed(h: int, w: int, cin: int, cout: int, k: int, dilation: int) -> int:
    """MACs actually issued by the decomposition == nonzero MACs (exact)."""
    del dilation  # decomposition issues exactly the nonzero MACs
    return macs_nonzero(h, w, cin, cout, k)
