"""Input decomposition for dilated convolutions (paper §II-B).

A dilated convolution with dilation rate ``d = D + 1`` (``D`` zeros inserted
between adjacent weight taps, effective kernel ``(d*(k-1)+1)``) touches, for the
output pixel at ``(y, x)``, only input pixels whose coordinates are congruent to
``(y, x) mod d``.  The input therefore splits exactly into ``d**2`` *phase
blocks* — block ``(i, j)`` holds input pixels at ``x[i::d, j::d]`` — and the
dilated convolution is equivalent to ``d**2`` independent *dense* SAME
convolutions of each phase block with the compact ``k x k`` kernel, stitched
back by interleaving.

This file provides three executable forms, all NHWC / HWIO:

* :func:`dilated_conv2d_reference` — XLA oracle (``rhs_dilation``).
* :func:`dilated_conv2d_naive` — what a dense accelerator does naively: the
  kernel is explicitly zero-inserted to its enlarged ``(d*(k-1)+1)`` footprint
  and convolved densely.  Numerically identical to the oracle but performs the
  full zero-laden MAC count; used as the cycle-model "ideal dense" workload.
* :func:`dilated_conv2d_decomposed` — the paper's method: phase split ->
  dense conv -> stitch.  Two execution strategies:

  - ``ragged``: faithful to the paper — each of the ``d**2`` ragged blocks is
    convolved separately (matches Fig. 4 block shapes).
  - ``batched``: TPU-native beyond-paper variant — the input is padded up to a
    multiple of ``d``, the phases are stacked on the batch axis and executed as
    ONE dense convolution (full MXU occupancy even for small phase extents).

All three forms accept an output ``stride``: the decomposition generalizes to
strided dilated convolutions via the output-class schedule
(:func:`stride_class_schedule`, DESIGN.md §2c) — ``(d/gcd(s,d))**2`` classes,
each a strided VALID dense conv of one phase block, still issuing exactly the
nonzero MACs.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

_DIMS = ("NHWC", "HWIO", "NHWC")


def same_pad(k: int) -> int:
    """Padding for SAME output with an odd kernel of size ``k``."""
    if k % 2 != 1:
        raise ValueError(f"SAME padding defined for odd kernels only, got k={k}")
    return (k - 1) // 2


def effective_kernel_size(k: int, dilation: int) -> int:
    """Zero-inserted footprint: ``(2*D + k)`` for step ``d = D+1`` == d*(k-1)+1."""
    return dilation * (k - 1) + 1


def strided_out_size(h: int, k: int, dilation: int, stride: int) -> int:
    """Output extent of a SAME-padded strided dilated conv: ``ceil(h/s)``."""
    ke = effective_kernel_size(k, dilation)
    return (h + 2 * same_pad(ke) - ke) // stride + 1


def dilated_conv2d_reference(x: jax.Array, w: jax.Array, dilation: int,
                             stride: int = 1) -> jax.Array:
    """XLA oracle: SAME dilated convolution via ``rhs_dilation``.

    Args:
      x: (N, H, W, Cin).
      w: (k, k, Cin, Cout) compact (non-dilated) kernel.
      dilation: step ``d = D + 1`` (``d = 1`` is a plain dense convolution).
      stride: output stride ``s`` (output extent ``ceil(H/s)``).
    Returns:
      (N, ceil(H/s), ceil(W/s), Cout).
    """
    k = w.shape[0]
    pad = same_pad(effective_kernel_size(k, dilation))
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=[(pad, pad), (pad, pad)],
        rhs_dilation=(dilation, dilation), dimension_numbers=_DIMS,
    )


def zero_insert_weight(w: jax.Array, dilation: int) -> jax.Array:
    """Explicitly materialise the enlarged zero-inserted kernel (Fig. 2)."""
    k, _, cin, cout = w.shape
    ke = effective_kernel_size(k, dilation)
    we = jnp.zeros((ke, ke, cin, cout), w.dtype)
    return we.at[::dilation, ::dilation].set(w)


def dilated_conv2d_naive(x: jax.Array, w: jax.Array, dilation: int,
                         stride: int = 1) -> jax.Array:
    """Dense execution of the zero-inserted kernel — the paper's baseline."""
    we = zero_insert_weight(w, dilation)
    pad = same_pad(we.shape[0])
    return lax.conv_general_dilated(
        x, we, window_strides=(stride, stride), padding=[(pad, pad), (pad, pad)],
        dimension_numbers=_DIMS,
    )


def phase_split(x: jax.Array, d: int) -> list[list[jax.Array]]:
    """Split NHWC input into ``d x d`` ragged phase blocks (paper Fig. 4).

    Block ``(i, j)`` has shape ``(N, ceil((H-i)/d), ceil((W-j)/d), C)``.
    """
    return [[x[:, i::d, j::d, :] for j in range(d)] for i in range(d)]


def phase_stitch(blocks: list[list[jax.Array]], out_shape: tuple[int, ...]) -> jax.Array:
    """Interleave ``d x d`` phase outputs back into a dense NHWC tensor."""
    d = len(blocks)
    out = jnp.zeros(out_shape, blocks[0][0].dtype)
    for i in range(d):
        for j in range(d):
            out = out.at[:, i::d, j::d, :].set(blocks[i][j])
    return out


def _phase_to_batch(x: jax.Array, d: int) -> tuple[jax.Array, int, int]:
    """Pad H, W up to multiples of ``d`` and stack phases on the batch axis.

    Returns (stacked ``(d*d*N, H//d, W//d, C)``, padded H, padded W).  Padding
    with zeros is exact: the oracle's SAME conv also pads with zeros, and the
    excess rows are dropped at stitch time.
    """
    n, h, w_, c = x.shape
    hp, wp = math.ceil(h / d) * d, math.ceil(w_ / d) * d
    x = jnp.pad(x, ((0, 0), (0, hp - h), (0, wp - w_), (0, 0)))
    # (N, H/d, d, W/d, d, C) -> (d, d, N, H/d, W/d, C) -> merge phases into batch
    x = x.reshape(n, hp // d, d, wp // d, d, c).transpose(2, 4, 0, 1, 3, 5)
    return x.reshape(d * d * n, hp // d, wp // d, c), hp, wp


def _batch_to_phase(y: jax.Array, d: int, n: int, h: int, w_: int) -> jax.Array:
    """Inverse of :func:`_phase_to_batch` (crops the pad-up rows/cols)."""
    _, hb, wb, c = y.shape
    y = y.reshape(d, d, n, hb, wb, c).transpose(2, 3, 0, 4, 1, 5)
    y = y.reshape(n, hb * d, wb * d, c)
    return y[:, :h, :w_, :]


def stride_class_schedule(d: int, s: int, p: int, out_len: int
                          ) -> tuple[int, int, list[tuple[int, int, int]]]:
    """Output-class schedule for one spatial dim of a *strided* dilated conv.

    Output pixel ``y`` reads input ``s*y - p + d*t`` for taps ``t`` — all
    congruent to ``r(y) = (s*y - p) mod d``, so each output lives in exactly
    one input phase block.  ``r(y)`` is periodic in ``y`` with period
    ``q = d // gcd(s, d)``: outputs ``y = j + q*u`` (class ``j``) all read
    phase block ``r_j = (s*j - p) mod d`` at block positions
    ``m0_j + s_blk*u + t`` with ``s_blk = s // gcd(s, d)`` and
    ``m0_j = (s*j - p - r_j) // d``.

    Returns ``(q, s_blk, [(r_j, m0_j, n_out_j)])`` — each class is a dense
    VALID correlation of its phase block with the compact kernel at block
    stride ``s_blk``; MACs issued == nonzero MACs.  ``s = 1`` degenerates to
    the paper's schedule (``q = d``, ``s_blk = 1``, ``r_j = j`` up to the
    padding shift).
    """
    g = math.gcd(s, d)
    q, s_blk = d // g, s // g
    sched = []
    for j in range(q):
        r = (s * j - p) % d
        m0 = (s * j - p - r) // d
        n_out = len(range(j, out_len, q))
        sched.append((r, m0, n_out))
    return q, s_blk, sched


def _class_window(x: jax.Array, d: int, row, col,
                  rows_span: int, cols_span: int) -> jax.Array:
    """Extract one (row-class, col-class) phase window, padded to a common span.

    ``row``/``col`` are ``(r, m0, n_out)`` schedule entries.  The returned
    block is aligned so the class's first output reads rows/cols ``[0, k)``
    — a VALID correlation at stride ``s_blk`` then yields the class plane.
    Zero padding is exact: it mirrors the oracle's SAME-conv zero pads.
    """
    (ri, m0i, _), (rj, m0j, _) = row, col
    blk = x[:, ri::d, rj::d, :]
    pt, pl_ = max(0, -m0i), max(0, -m0j)
    st, sl = m0i + pt, m0j + pl_
    pb = max(0, st + rows_span - (blk.shape[1] + pt))
    pr = max(0, sl + cols_span - (blk.shape[2] + pl_))
    blk = jnp.pad(blk, ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    return blk[:, st : st + rows_span, sl : sl + cols_span, :]


def _dilated_strided_decomposed(x: jax.Array, w: jax.Array, d: int, s: int,
                                strategy: str, conv_fn=None,
                                phase_sharding=None) -> jax.Array:
    """Strided-dilated decomposition: class split -> strided dense conv -> stitch.

    ``conv_fn(xb, w, sb)`` runs a VALID dense conv at stride ``sb`` (defaults
    to ``lax``; the Pallas pipeline passes its own engine here so both paths
    share one schedule/stitch implementation).
    """
    if conv_fn is None:
        def conv_fn(xb, wt, sb):
            return lax.conv_general_dilated(
                xb, wt, window_strides=(sb, sb), padding="VALID",
                dimension_numbers=_DIMS,
            )

    k = w.shape[0]
    p = same_pad(effective_kernel_size(k, d))
    n, h, w_, _ = x.shape
    cout = w.shape[-1]
    oh = strided_out_size(h, k, d, s)
    ow = strided_out_size(w_, k, d, s)
    q, sb, rsched = stride_class_schedule(d, s, p, oh)
    _, _, csched = stride_class_schedule(d, s, p, ow)
    ny_max = max(e[2] for e in rsched)
    nx_max = max(e[2] for e in csched)
    rows_span = sb * (ny_max - 1) + k
    cols_span = sb * (nx_max - 1) + k
    windows = [
        _class_window(x, d, row, col, rows_span, cols_span)
        for row in rsched for col in csched
    ]
    if strategy == "batched":
        # all q*q class windows share one strided dense conv (phase-batched)
        xb = jnp.concatenate(windows, axis=0)
        if phase_sharding is not None:
            xb = lax.with_sharding_constraint(xb, phase_sharding)
        yb = conv_fn(xb, w, sb)
        planes = [yb[i * n : (i + 1) * n] for i in range(q * q)]
    else:  # ragged: one conv per class (paper-faithful schedule)
        planes = [conv_fn(win, w, sb) for win in windows]
    out = jnp.zeros((n, oh, ow, cout), x.dtype)
    i = 0
    for ji, (_, _, nyi) in enumerate(rsched):
        for jj, (_, _, nxj) in enumerate(csched):
            out = out.at[:, ji::q, jj::q, :].set(planes[i][:, :nyi, :nxj, :])
            i += 1
    return out


@partial(jax.jit,
         static_argnames=("dilation", "strategy", "stride", "phase_sharding"))
def dilated_conv2d_decomposed(
    x: jax.Array, w: jax.Array, dilation: int, strategy: str = "batched",
    stride: int = 1, phase_sharding=None,
) -> jax.Array:
    """The paper's method: phase decomposition -> dense conv -> stitch.

    ``strategy='ragged'`` runs the d**2 ragged blocks separately (faithful to
    the paper's schedule); ``strategy='batched'`` phase-batches them into one
    dense convolution (TPU-native, beyond-paper).  Both are exact.
    ``stride > 1`` uses the output-class schedule (:func:`stride_class_schedule`)
    — ``(d/gcd(s,d))**2`` classes, each a strided VALID dense conv.

    ``phase_sharding`` (a hashable ``NamedSharding``, DESIGN.md §13) constrains
    the folded phase-batch axis of the batched strategy — the d**2 phase blocks
    are independent, so GSPMD distributes them like data.  Static, so meshed
    and un-meshed callers never share a trace-cache entry.
    """
    d = dilation
    if strategy not in ("ragged", "batched"):
        raise ValueError(f"unknown strategy {strategy!r}")
    if d == 1:
        return dilated_conv2d_reference(x, w, 1, stride)
    if stride != 1:
        return _dilated_strided_decomposed(x, w, d, stride, strategy,
                                           phase_sharding=phase_sharding)
    k = w.shape[0]
    pad = same_pad(k)
    if strategy == "ragged":
        blocks = phase_split(x, d)
        outs = [
            [
                lax.conv_general_dilated(
                    b, w, window_strides=(1, 1), padding=[(pad, pad), (pad, pad)],
                    dimension_numbers=_DIMS,
                )
                for b in row
            ]
            for row in blocks
        ]
        n, h, w_, _ = x.shape
        return phase_stitch(outs, (n, h, w_, w.shape[-1]))
    if strategy == "batched":
        n, h, w_, _ = x.shape
        xb, _, _ = _phase_to_batch(x, d)
        if phase_sharding is not None:
            xb = lax.with_sharding_constraint(xb, phase_sharding)
        yb = lax.conv_general_dilated(
            xb, w, window_strides=(1, 1), padding=[(pad, pad), (pad, pad)],
            dimension_numbers=_DIMS,
        )
        return _batch_to_phase(yb, d, n, h, w_)
    raise ValueError(f"unknown strategy {strategy!r}")


# ---------------------------------------------------------------------------
# MAC counting (drives the cycle model and the paper-claim benchmarks)
# ---------------------------------------------------------------------------

def macs_dense(h: int, w: int, cin: int, cout: int, k: int, dilation: int = 1,
               stride: int = 1) -> int:
    """MACs of the *naive dense* execution: enlarged kernel incl. zeros."""
    ke = effective_kernel_size(k, dilation)
    oh = strided_out_size(h, k, dilation, stride)
    ow = strided_out_size(w, k, dilation, stride)
    return oh * ow * cin * cout * ke * ke


def macs_nonzero(h: int, w: int, cin: int, cout: int, k: int,
                 stride: int = 1) -> int:
    """Ideal sparse MACs: only the k*k nonzero taps (interior approximation)."""
    oh, ow = -(-h // stride), -(-w // stride)
    return oh * ow * cin * cout * k * k


def macs_decomposed(h: int, w: int, cin: int, cout: int, k: int, dilation: int,
                    stride: int = 1) -> int:
    """MACs actually issued by the decomposition == nonzero MACs (exact)."""
    del dilation  # decomposition issues exactly the nonzero MACs
    return macs_nonzero(h, w, cin, cout, k, stride)
