"""Cycle-accurate model of the paper's accelerator (VWA [16] + decomposition).

Array: ``B`` PE blocks, each an ``n x 3`` MAC array — 168 MACs total at
500 MHz (Table I: 168 GOPS peak).  We use ``(n, B) = (7, 8)``: ``B`` must
divide ENet's power-of-two channel counts for the near-ideal dilated
efficiencies the paper reports, and ``n = 7`` reproduces the ~9 %-vs-8 %
general-convolution overhead of Fig. 10.

Modeled execution (assumptions documented inline; see DESIGN.md §2):

* ideal dense   = all MACs incl. zeros, no array constraints (paper's Fig. 10
                  baseline) -> cycles = MACs / 168.
* ideal sparse  = in-bounds nonzero MACs only -> cycles = MACs / 168.
* our work:
  - general convolutions: output columns scheduled per weight column; the
    column vector packs ``kh`` taps x ``cin`` channels in groups of 3; output
    rows tiled by ``n`` (ceil) — the utilization gap the paper reports
    ("utilization of our work is not full in the general convolutions").
  - decomposed dilated: phase blocks of a column class stream back-to-back
    (Fig. 8), so no row-tiling loss; left/right boundary columns use 2 of 3
    weight columns (the paper's boundary trick); top/bottom pad rows issue a
    full 3-tap column with one wasted tap — the only loss, growing with D
    exactly as the paper's 83–98 % efficiency band.
  - decomposed transposed: all ``k**2`` sub-kernel taps are assigned across
    the ``3*B`` weight ports and share the input broadcast (Fig. 9), packing
    ``k*k x cin`` tap-channel pairs in groups of ``3*B``; rows tiled by ``n``
    on the *input* ("marginal loss due to the tiled input", Fig. 12).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.enet_spec import ConvLayer

MACS_PER_CYCLE = 168
FREQ_HZ = 500e6
N_ROWS = 7     # n: MAC rows per PE block
N_BLOCKS = 8   # B: PE blocks (7 * 3 * 8 = 168 MACs)


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def _dilated_eff_k(l: ConvLayer) -> int:
    """Zero-inserted kernel footprint ``d*(k-1)+1`` (``2D+3`` for k=3)."""
    return (l.D + 1) * (l.kh - 1) + 1


def tconv_pads(l: ConvLayer) -> tuple[int, int]:
    """Resolve a transposed layer's ``(p_lo, p_hi)`` zero-insert pads.

    ``padding=None`` means the framework default ``(k-1)//2`` (every
    ENet/ESPNet layer); generative decoders record explicit pads — DCGAN's
    k=4/s=2 chains use ``p_lo=2`` with ``output_padding=0`` (the PyTorch
    ``ConvTranspose2d(k=4, s=2, p=1)`` geometry), U-Net's k=2/s=2 upsample
    ``p_lo=1`` — so the costing must not assume ``(k-1)//2``.

    Square kernels only, like the executable engine (``decompose.conv2d``
    rejects ``kh != kw`` transposed convs): a single ``p_lo`` cannot
    describe a rectangular kernel's per-dimension pads.
    """
    if l.kh != l.kw:
        raise ValueError(
            f"transposed layers are square-kernel only, got {l.kh}x{l.kw}")
    p_lo = (l.kh - 1) // 2 if l.padding is None else l.padding
    return p_lo, p_lo + l.output_padding


def tconv_input_size(l: ConvLayer) -> tuple[int, int]:
    """Invert the transposed output-size relation to the input extent.

    ``oh = (h_in - 1)*s + p_lo + p_hi - k + 2`` with ``(p_lo, p_hi)`` from
    :func:`tconv_pads` — the general (k, s, padding) form; reduces to
    ``h_out // s`` for the ENet case (k=3, s=2, output_padding=1) and for
    DCGAN's (k=4, s=2, p_lo=2, output_padding=0).
    """
    s = l.stride
    p_lo, p_hi = tconv_pads(l)

    def inv(out: int, k: int) -> int:
        return (out - p_lo - p_hi + k - 2) // s + 1

    return inv(l.h_out, l.kh), inv(l.w_out, l.kw)


# ---------------------------------------------------------------------------
# MAC counts (architecture-independent)
# ---------------------------------------------------------------------------

def ideal_dense_macs(l: ConvLayer) -> int:
    """All MACs including zero operands (paper's Fig. 10 baseline)."""
    if l.kind == "dilated":
        ke = _dilated_eff_k(l)
        return l.h_out * l.w_out * l.cin * l.cout * ke * ke
    # dense conv and transposed-over-zero-inserted-input both issue kh*kw
    # taps per output pixel.
    return l.h_out * l.w_out * l.cin * l.cout * l.kh * l.kw


def _dilated_live_taps_dim(in_len: int, out_len: int, d: int, s: int,
                           p: int, k: int) -> int:
    """Exact in-bounds tap count along one dim via the output-class schedule
    (the same one the engine executes — see repro.core.dilated)."""
    from repro.core.dilated import stride_class_schedule

    _, sb, sched = stride_class_schedule(d, s, p, out_len)
    total = 0
    for r, m0, n_out in sched:
        blk = _ceil(max(in_len - r, 0), d)
        for u in range(n_out):
            total += sum(1 for t in range(k) if 0 <= m0 + sb * u + t < blk)
    return total


def ideal_sparse_macs(l: ConvLayer) -> int:
    """Nonzero AND in-bounds MACs only (paper's ideal sparse)."""
    if l.kind == "dilated":
        d, k = l.D + 1, l.kh
        if l.stride == 1:
            # sum over phase blocks of SAME-conv in-bounds taps:
            # sum_i (k*Hb_i - (k-1)) = k*H - (k-1)*d  (separable in H and W)
            return ((k * l.h_out - (k - 1) * d) * (k * l.w_out - (k - 1) * d)
                    * l.cin * l.cout)
        # strided: exact count over the output-class schedule; input extent
        # is s*h_out (SAME output = ceil(H/s); we model the divisible case).
        s = l.stride
        p = (d * (k - 1)) // 2
        live_r = _dilated_live_taps_dim(s * l.h_out, l.h_out, d, s, p, k)
        live_c = _dilated_live_taps_dim(s * l.w_out, l.w_out, d, s, p, l.kw)
        return live_r * live_c * l.cin * l.cout
    if l.kind == "transposed":
        s = l.stride
        h_in, w_in = tconv_input_size(l)
        p_lo, _ = tconv_pads(l)
        total = 0
        for ry in range(s):
            # parities with no live tap (possible when k < s) are identically
            # zero conv planes: they contribute no MACs at all
            taps_r = [t for t in range(l.kh) if (t - p_lo + ry) % s == 0]
            n_y = len(range(ry, l.h_out, s))
            live_r = sum(
                1
                for b in range(n_y)
                for t in taps_r
                if 0 <= b + (ry + t - p_lo) // s < h_in
            )
            for rx in range(s):
                taps_c = [t for t in range(l.kw) if (t - p_lo + rx) % s == 0]
                n_x = len(range(rx, l.w_out, s))
                live_c = sum(
                    1
                    for b in range(n_x)
                    for t in taps_c
                    if 0 <= b + (rx + t - p_lo) // s < w_in
                )
                total += live_r * live_c
        return total * l.cin * l.cout
    # dense conv: in-bounds taps of a SAME/strided conv — the paper counts
    # "all MACs needed in the convolution"; boundary deficit is negligible
    # and general convs are never compared against ideal sparse.
    return l.h_out * l.w_out * l.cin * l.cout * l.kh * l.kw


# ---------------------------------------------------------------------------
# Cycle counts on the modeled array
# ---------------------------------------------------------------------------

def cycles_ideal_dense(l: ConvLayer) -> float:
    return ideal_dense_macs(l) / MACS_PER_CYCLE


def cycles_ideal_sparse(l: ConvLayer) -> float:
    return ideal_sparse_macs(l) / MACS_PER_CYCLE


def cycles_our_general(l: ConvLayer, n: int = N_ROWS, b: int = N_BLOCKS) -> int:
    """Dense convolution on the array (naive path for any layer kind)."""
    if l.kind == "dilated":
        kh = kw = _dilated_eff_k(l)
        h_out, w_out = l.h_out, l.w_out
    elif l.kind == "transposed":
        kh, kw = l.kh, l.kw
        h_out, w_out = l.h_out, l.w_out  # dense over the zero-inserted input
    else:
        kh, kw = l.kh, l.kw
        h_out, w_out = l.h_out, l.w_out
    col_cycles = kw * _ceil(kh * l.cin, 3)
    return _ceil(h_out, n) * w_out * _ceil(l.cout, b) * col_cycles


def cycles_our_decomposed(l: ConvLayer, n: int = N_ROWS, b: int = N_BLOCKS) -> int:
    """Decomposed execution (the paper's method) of a layer on the array."""
    if l.kind == "dilated":
        d, s, k = l.D + 1, l.stride, l.kw
        # Column classes j (q = d/gcd(s,d) of them, q = d when s = 1): each
        # has ceil((W-j)/q) output columns; boundary columns drop (k-1) of
        # the k weight columns across the class -> sum_j (k*Wb_j - (k-1))
        # column-ops (= 3W - 2d for the paper's k=3, s=1 case).  Phase
        # blocks stream, so rows cost H/n tiles amortized (ceil once per
        # layer); each weight-column op packs kh taps x cin channels in
        # groups of 3.
        q = d // math.gcd(s, d)
        col_ops = sum(k * len(range(j, l.w_out, q)) - (k - 1) for j in range(q))
        row_tiles = l.h_out / n  # streamed: quantization amortized per layer
        return math.ceil(
            row_tiles * col_ops * _ceil(l.kh * l.cin, 3) * _ceil(l.cout, b))
    if l.kind == "transposed":
        h_in, w_in = tconv_input_size(l)
        taps = l.kh * l.kw
        # all sub-kernel taps x cin x cout packed across the 3*B weight
        # ports, sharing the input column broadcast (Fig. 9); input rows tile
        # by n ("marginal loss due to the tiled input").
        port_cycles = _ceil(taps * l.cin * l.cout, 3 * b)
        return _ceil(h_in, n) * w_in * port_cycles
    return cycles_our_general(l, n, b)


# ---------------------------------------------------------------------------
# Aggregation (drives Figs. 10/11/12 + Table I benchmarks)
# ---------------------------------------------------------------------------

@dataclass
class GroupStats:
    macs_dense: int = 0
    macs_sparse: int = 0
    cycles_dense: float = 0.0
    cycles_sparse: float = 0.0
    cycles_ours: float = 0.0


def summarize(layers: list[ConvLayer]) -> dict[str, GroupStats]:
    groups: dict[str, GroupStats] = {
        "general": GroupStats(), "dilated": GroupStats(),
        "transposed": GroupStats(), "total": GroupStats(),
    }
    for l in layers:
        g = groups[l.group]
        md, ms = ideal_dense_macs(l), ideal_sparse_macs(l)
        ours = cycles_our_decomposed(l)
        for tgt in (g, groups["total"]):
            tgt.macs_dense += md
            tgt.macs_sparse += ms
            tgt.cycles_dense += md / MACS_PER_CYCLE
            tgt.cycles_sparse += ms / MACS_PER_CYCLE
            tgt.cycles_ours += ours
    return groups


def _group_speedup(gs: GroupStats) -> float:
    """Dense/ours cycle ratio of one layer group; 1.0 for an absent group.

    Generative workloads are not full-mix: DCGAN has no dilated layers at
    all, so the per-group ratios must not divide by an empty group's zero
    cycle count.
    """
    return gs.cycles_dense / gs.cycles_ours if gs.cycles_ours else 1.0


#: neutral report for an empty (or zero-cycle) layer list: no work means no
#: speedup claim — ratios are 1.0, shares/cycles/throughput are 0.  Guarded
#: here rather than at call sites so ``serve_report``/``training_report`` and
#: ad-hoc callers (e.g. admission control on a not-yet-populated lane) never
#: trip a ``ZeroDivisionError``.
_EMPTY_REPORT = {
    "total_macs_dense": 0, "ideal_dense_cycles": 0.0, "our_cycles": 0.0,
    "overall_speedup": 1.0, "cycle_reduction_pct": 0.0, "naive_cycles": 0.0,
    "speedup_vs_naive": 1.0, "cycle_reduction_vs_naive_pct": 0.0,
    "share_dilated_pct": 0.0, "share_transposed_pct": 0.0,
    "share_general_pct": 0.0, "ours_dilated_pct": 0.0,
    "ours_transposed_pct": 0.0, "ours_general_pct": 0.0,
    "dilated_speedup": 1.0, "transposed_speedup": 1.0,
    "peak_gops": MACS_PER_CYCLE * 2 * FREQ_HZ / 1e9, "effective_gops": 0.0,
}


def report(layers: list[ConvLayer]) -> dict[str, float]:
    """The paper's headline numbers, computed from the model."""
    g = summarize(layers)
    tot = g["total"]
    if not tot.cycles_dense or not tot.cycles_ours:
        return dict(_EMPTY_REPORT)
    naive = float(sum(cycles_our_general(l) for l in layers))
    out = {
        "total_macs_dense": tot.macs_dense,
        "ideal_dense_cycles": tot.cycles_dense,
        "our_cycles": tot.cycles_ours,
        "overall_speedup": tot.cycles_dense / tot.cycles_ours,
        "cycle_reduction_pct": 100.0 * (1 - tot.cycles_ours / tot.cycles_dense),
        # the same array running the zero-laden dense schedule (utilization
        # losses included) — "a naive execution" in the abstract's sense
        "naive_cycles": naive,
        "speedup_vs_naive": naive / tot.cycles_ours,
        "cycle_reduction_vs_naive_pct": 100.0 * (1 - tot.cycles_ours / naive),
        # shares of the ideal-dense baseline (paper: 85 / 7 / 8)
        "share_dilated_pct": 100.0 * g["dilated"].cycles_dense / tot.cycles_dense,
        "share_transposed_pct": 100.0 * g["transposed"].cycles_dense / tot.cycles_dense,
        "share_general_pct": 100.0 * g["general"].cycles_dense / tot.cycles_dense,
        # our-work shares of the same baseline (paper: 2 / 2 / 9)
        "ours_dilated_pct": 100.0 * g["dilated"].cycles_ours / tot.cycles_dense,
        "ours_transposed_pct": 100.0 * g["transposed"].cycles_ours / tot.cycles_dense,
        "ours_general_pct": 100.0 * g["general"].cycles_ours / tot.cycles_dense,
        "dilated_speedup": _group_speedup(g["dilated"]),
        "transposed_speedup": _group_speedup(g["transposed"]),
        # throughput (Table I): peak = 168 MACs * 2 ops * 500 MHz
        "peak_gops": MACS_PER_CYCLE * 2 * FREQ_HZ / 1e9,
        "effective_gops": (tot.macs_dense * 2) / (tot.cycles_ours / FREQ_HZ) / 1e9,
    }
    return out


def serve_report(layers: list[ConvLayer], *, steps: int = 1,
                 batch: int = 1, scan_steps: int = 1,
                 steps_list: list[int] | None = None, calibration=None,
                 backend: str = "xla", devices: int = 1,
                 snapshot_every: int = 0) -> dict[str, float]:
    """Steady-state serving cost of an iterative sampler on the array.

    One served image costs ``steps`` full passes over the workload's layer
    table (a DDIM trajectory re-runs the same geometry at every timestep;
    ``steps=1`` is single-shot GAN generation).  Assumptions (DESIGN.md §9):
    the array executes one MAC stream, so a device batch of ``B`` requests
    multiplies *latency* by ``B`` while steady-state throughput is
    batch-invariant — batching exists to amortise host scheduling and weight
    fetches, not MACs — and scheduling overhead between steps is not
    modeled.  The decomposed-vs-naive throughput ratio therefore equals the
    per-pass ``report()['speedup_vs_naive']`` exactly; ``benchmarks/
    serve_bench.py`` and ``tests/test_serve_gen.py`` pin that consistency.

    ``scan_steps`` is the fused-dispatch depth ``K`` of the serving loop
    (``launch.steps.make_gen_scan_step``): the array cycles are unchanged
    (the same MACs stream either way), but the *host* pays one dispatch per
    ``ceil(steps / K)`` instead of one per step — reported as
    ``dispatches_per_image`` and amortised into the calibrated keys.

    ``calibration`` (a :class:`repro.core.calibrate.Calibration`) adds
    host-grounded keys next to the 500 MHz array numbers:
    ``calibrated_us_per_image`` / ``calibrated_images_per_s`` predict THIS
    host's wall time on ``backend`` as ``steps x compute + dispatches x
    per-pass dispatch overhead`` (``Calibration.predict_layers_split``);
    omitted when the calibration lacks a fitted key for some layer kind.

    ``steps_list`` (a mixed per-request step-budget set) adds the
    latency-percentile keys ``latency_p50_ms`` / ``latency_p99_ms`` from
    :func:`serve_percentiles` — the deterministic continuous-batching drain
    model of DESIGN.md §9.

    ``snapshot_every`` (the serving loop's snapshot cadence, DESIGN.md §11)
    adds the worst-case recovery cost: a crash lands just before the next
    snapshot, so recovery replays ``snapshot_every`` full ticks — each one
    fused dispatch of ``batch x scan_steps`` passes.  Reported as
    ``recovery_ticks_worst`` / ``recovery_ms_worst`` (array cycles) and,
    with a calibration, ``calibrated_recovery_us_worst`` (this host's wall
    time, dispatch overhead included).

    ``devices`` models mesh data parallelism over the request batch / the
    decomposition's phase-parity axis (DESIGN.md §13): the sub-problems are
    independent, so ``devices`` arrays stream MACs concurrently with no
    collective on the serve path — per-device compute divides by
    ``devices`` (throughput and batch-drain latency scale linearly), while
    host dispatch overhead is paid once per fused dispatch regardless.
    """
    if steps < 1 or batch < 1 or scan_steps < 1:
        raise ValueError(
            f"steps/batch/scan_steps must be >= 1, got "
            f"{steps}/{batch}/{scan_steps}")
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    dispatches = float(_ceil(steps, scan_steps))
    base = report(layers)
    ours = base["our_cycles"] * steps
    naive = base["naive_cycles"] * steps
    if not ours or not naive:
        # empty layer table (e.g. admission estimate for an unknown/empty
        # workload): zero cost, neutral ratio — not a ZeroDivisionError
        return {
            "steps": float(steps), "batch": float(batch),
            "scan_steps": float(scan_steps), "devices": float(devices),
            "dispatches_per_image": dispatches,
            "cycles_per_image_ours": 0.0, "cycles_per_image_naive": 0.0,
            "latency_ms_ours": 0.0, "latency_ms_naive": 0.0,
            "images_per_s_ours": 0.0, "images_per_s_naive": 0.0,
            "serve_speedup_vs_naive": 1.0,
        }
    out = {
        "steps": float(steps),
        "batch": float(batch),
        "scan_steps": float(scan_steps),
        "devices": float(devices),
        "dispatches_per_image": dispatches,
        "cycles_per_image_ours": ours,
        "cycles_per_image_naive": naive,
        "latency_ms_ours": 1e3 * batch * ours / FREQ_HZ / devices,
        "latency_ms_naive": 1e3 * batch * naive / FREQ_HZ / devices,
        "images_per_s_ours": devices * FREQ_HZ / ours,
        "images_per_s_naive": devices * FREQ_HZ / naive,
        "serve_speedup_vs_naive": naive / ours,
    }
    if snapshot_every > 0:
        # worst case: the crash lands one tick short of the next snapshot,
        # so snapshot_every ticks of batch x scan_steps passes replay
        tick_cycles = batch * scan_steps * base["our_cycles"] / devices
        out["recovery_ticks_worst"] = float(snapshot_every)
        out["recovery_ms_worst"] = 1e3 * snapshot_every * tick_cycles / FREQ_HZ
    if calibration is not None:
        split = calibration.predict_layers_split(layers, backend=backend)
        if split is not None:
            compute_us, dispatch_us = split
            us = steps * compute_us / devices + dispatches * dispatch_us
            out["calibrated_us_per_image"] = us
            out["calibrated_images_per_s"] = 1e6 / us if us else 0.0
            if snapshot_every > 0:
                tick_us = (batch * scan_steps * compute_us / devices
                           + dispatch_us)
                out["calibrated_recovery_us_worst"] = snapshot_every * tick_us
    if steps_list:
        pct = serve_percentiles(layers, steps_list, batch=batch,
                                scan_steps=scan_steps, devices=devices,
                                calibration=calibration, backend=backend)
        out["latency_p50_ms"] = pct["latency_p50_ms"]
        out["latency_p99_ms"] = pct["latency_p99_ms"]
    return out


def serve_percentiles(layers: list[ConvLayer], steps_list: list[int], *,
                      batch: int = 1, scan_steps: int = 1, calibration=None,
                      backend: str = "xla", devices: int = 1,
                      pcts: tuple[float, ...] = (50.0, 99.0)
                      ) -> dict[str, float]:
    """Latency percentiles of a mixed-step request drain (DESIGN.md §9).

    The serving loop is deterministic given the request set, so the
    percentile model *is* the schedule: ``len(steps_list)`` requests are all
    present at t=0, admitted FIFO into ``batch`` slots, and every scheduler
    tick advances each occupied slot by up to ``scan_steps`` trajectory
    steps in one fused dispatch.  A dispatch streams ``batch x scan_steps``
    full passes over the layer table through the array (padded substeps and
    idle slots stream too — the compiled step's shape does not shrink), so
    every tick costs the same ``batch * scan_steps * pass_cycles``.  A
    request's latency is its completion tick's end time; percentiles are
    taken over the request set (numpy linear interpolation).

    With a ``calibration``, tick wall time is modeled as ``batch x
    scan_steps x compute_us + dispatch_us`` (one fused dispatch pays the
    per-pass dispatch overhead once) and calibrated-us percentile keys ride
    along.
    """
    if batch < 1 or scan_steps < 1:
        raise ValueError(
            f"batch/scan_steps must be >= 1, got {batch}/{scan_steps}")
    if not steps_list or min(steps_list) < 1:
        raise ValueError(f"steps_list must be non-empty positive budgets, "
                         f"got {steps_list}")
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    pass_cycles = float(sum(cycles_our_decomposed(l) for l in layers))
    tick_cycles = batch * scan_steps * pass_cycles / devices
    split = (calibration.predict_layers_split(layers, backend=backend)
             if calibration is not None else None)
    tick_us = (batch * scan_steps * split[0] / devices + split[1]
               if split is not None else None)

    pending = list(steps_list)          # FIFO: remaining-step budgets
    slots: list[int] = []               # remaining steps of occupied slots
    done_ticks: list[int] = []          # completion tick per request, FIFO
    tick = 0
    while pending or slots:
        while pending and len(slots) < batch:
            slots.append(pending.pop(0))
        tick += 1
        nxt = []
        for rem in slots:
            rem -= scan_steps
            if rem > 0:
                nxt.append(rem)
            else:
                done_ticks.append(tick)
        slots = nxt
    lat_ms = [1e3 * t * tick_cycles / FREQ_HZ for t in done_ticks]
    out: dict[str, float] = {
        "requests": float(len(steps_list)),
        "ticks": float(tick),
        "dispatches": float(tick),
    }
    for p in pcts:
        key = f"p{p:g}"
        out[f"latency_{key}_ms"] = float(np_percentile(lat_ms, p))
        if tick_us is not None:
            out[f"calibrated_latency_{key}_us"] = float(
                np_percentile([t * tick_us for t in done_ticks], p))
    return out


def np_percentile(values: list[float], p: float) -> float:
    """Linear-interpolated percentile without importing numpy at module
    scope (the cycle model stays dependency-light; numpy is already a repo
    dependency everywhere this is called)."""
    xs = sorted(values)
    if not xs:
        return 0.0
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * p / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


def efficiency_vs_sparse(l: ConvLayer) -> float:
    """Per-layer efficiency of our work vs the ideal sparse case."""
    return cycles_ideal_sparse(l) / cycles_our_decomposed(l)


# paper Fig. 10: ENet's ideal-dense cycle shares per layer group
PAPER_FIG10_MIX = {"dilated": 85.0, "transposed": 7.0, "general": 8.0}


def headline(layers: list[ConvLayer],
             mix: dict[str, float] = PAPER_FIG10_MIX) -> dict[str, float]:
    """The abstract's headline numbers: ~8.2x speedup, ~87.8% cycle cut.

    The overall aggregate depends on layer-inventory bookkeeping the paper
    does not fully specify (skip projections, decoder widths), so the pinned
    reproduction normalizes the *measured per-group cycle ratios* by the
    paper's own reported workload mix (Fig. 10: dilated 85 / transposed 7 /
    general 8).  This isolates what the model actually claims — how well
    each convolution class executes — from how many MACs each class
    contributes, and recovers the abstract's numbers within tolerance
    (pinned in ``tests/test_paper_figures.py``).
    """
    g = summarize(layers)
    ratios = {k: g[k].cycles_ours / g[k].cycles_dense
              for k in ("dilated", "transposed", "general") if g[k].cycles_dense}
    ours = sum(mix[k] * ratios[k] for k in ratios)
    baseline = sum(mix[k] for k in ratios)
    return {
        "speedup": baseline / ours,
        "cycle_reduction_pct": 100.0 * (1 - ours / baseline),
        "group_ratios": ratios,
    }


# ---------------------------------------------------------------------------
# Training-cost extension (beyond-paper; EcoFlow's observation): the backward
# pass is itself made of dilated/transposed convolutions, so the same
# decomposition accelerates it.  See DESIGN.md §6.
# ---------------------------------------------------------------------------

def adjoint_layer(l: ConvLayer) -> ConvLayer:
    """The layer class of ``dL/dx`` — the adjoint symmetry as a spec map.

    * strided **transposed** layer -> strided dense conv at the input extent
      (downsampling is the adjoint of upsampling);
    * **dilated** layer -> dilated layer, same step, channels swapped (kept
      at the forward geometry: the adjoint issues exactly one MAC per
      forward MAC, so the class-streamed schedule costs the same);
    * strided general **conv** (``stride`` recorded, e.g. ESPNet's d=1
      pyramid branches) -> transposed layer at the input extent — the other
      side of the first rule;
    * stride-1 general **conv** -> general conv, channels swapped.
    """
    if l.kind == "transposed":
        h_in, w_in = tconv_input_size(l)
        return ConvLayer(f"{l.name}.dx", "conv", h_in, w_in, l.cout, l.cin,
                         l.kh, l.kw)
    if l.kind == "dilated":
        return ConvLayer(f"{l.name}.dx", "dilated", l.h_out, l.w_out,
                         l.cout, l.cin, l.kh, l.kw, D=l.D, stride=l.stride,
                         group="dilated")
    if l.stride > 1:
        return ConvLayer(f"{l.name}.dx", "transposed", l.stride * l.h_out,
                         l.stride * l.w_out, l.cout, l.cin, l.kh, l.kw,
                         stride=l.stride, group="transposed")
    return ConvLayer(f"{l.name}.dx", "conv", l.h_out, l.w_out, l.cout, l.cin,
                     l.kh, l.kw)


def wgrad_contention(l: ConvLayer, n: int = N_ROWS, b: int = N_BLOCKS) -> float:
    """Port-contention multiplier of the tap-gather weight-gradient pass.

    ``dL/dw`` *accumulates into* the weight ports instead of holding static
    weights in them, which costs three array constraints the old full-rate
    model ignored (each factor is >= 1; 1.0 means no loss):

    * **tap packing** — a PE block's 3 weight ports hold 3 tap-accumulators
      for the duration of a reduction, so the gather streams the shared
      input broadcast in ``ceil(taps/3)`` port groups rather than packing
      ``taps x cin x cout`` across all ``3*B`` ports at once (the forward
      transposed trick of Fig. 9 is unavailable: an accumulator cannot move
      ports mid-reduction).  Dense/dilated layers pack their column vector
      ``kh x cin`` in groups of 3 exactly like the forward schedule.
    * **cout tiling** — output-channel gradient blocks tile across the ``B``
      PE blocks (ceil loss when ``cout % B != 0``).

    No row-tiling term: in ``dL/dw`` the spatial positions are the
    *contraction* dimension (the output is the ``k x k x cin x cout`` weight
    block, not a row-tiled image), so the gather streams rows contiguously —
    the forward schedules' ``ceil(H/n)`` output-tiling loss has no analogue.
    """
    cout_tile = _ceil(l.cout, b) * b / l.cout
    if l.kind == "transposed":
        taps = l.kh * l.kw
        tap_pack = _ceil(taps, 3) * 3 / taps
    else:
        col = l.kh * l.cin
        tap_pack = _ceil(col, 3) * 3 / col
    return tap_pack * cout_tile


def cycles_wgrad(l: ConvLayer) -> float:
    """Cycles of ``dL/dw``: tap-gather correlations on the array.

    Each nonzero forward MAC contributes exactly one weight-gradient MAC,
    gathered phase-contiguously (no inserted zeros) — but the gather does
    NOT sustain the full 168-MAC rate: the explicit
    :func:`wgrad_contention` term models the port/tiling losses of
    accumulating into the weight ports (the old model assumed full array
    rate, which overstated the training-side win).
    """
    return ideal_sparse_macs(l) / MACS_PER_CYCLE * wgrad_contention(l)


def training_report(layers: list[ConvLayer]) -> dict[str, float]:
    """Forward + backward cycle model (the EcoFlow setting).

    Backward = input-gradient pass (each layer costed as its adjoint layer,
    executed decomposed) + weight-gradient pass (tap-gather correlations with
    the explicit :func:`wgrad_contention` port term).  The naive baseline
    executes the same adjoints with zero-laden dense schedules
    (``cycles_our_general``) and the weight gradients over the zero-inserted
    geometry (``ideal_dense_macs``).

    An empty (or zero-cycle) layer list returns zero cycles and neutral 1.0
    speedups rather than raising ``ZeroDivisionError`` — same policy as
    ``report()``'s absent-group guard.
    """
    fwd_ours = sum(cycles_our_decomposed(l) for l in layers)
    fwd_naive = sum(cycles_our_general(l) for l in layers)
    if not fwd_ours or not fwd_naive:
        return {
            "fwd_cycles": 0.0, "bwd_cycles": 0.0, "train_cycles": 0.0,
            "fwd_speedup_vs_naive": 1.0, "bwd_speedup_vs_naive": 1.0,
            "train_speedup_vs_naive": 1.0,
        }
    adj = [adjoint_layer(l) for l in layers]
    bwd_ours = (sum(cycles_our_decomposed(a) for a in adj)
                + sum(cycles_wgrad(l) for l in layers))
    bwd_naive = (sum(cycles_our_general(a) for a in adj)
                 + sum(ideal_dense_macs(l) / MACS_PER_CYCLE for l in layers))
    return {
        "fwd_cycles": fwd_ours,
        "bwd_cycles": bwd_ours,
        "train_cycles": fwd_ours + bwd_ours,
        "fwd_speedup_vs_naive": fwd_naive / fwd_ours,
        "bwd_speedup_vs_naive": bwd_naive / bwd_ours,
        "train_speedup_vs_naive": (fwd_naive + bwd_naive) / (fwd_ours + bwd_ours),
    }
