"""Weight decomposition for transposed convolutions (paper §II-C).

A transposed convolution with stride ``s`` zero-inserts ``s - 1`` zeros between
adjacent input elements and then runs a dense ``k x k`` correlation.  For the
output pixel at ``(y, x)`` only kernel taps with
``ky ≡ (p - y) (mod s)`` and ``kx ≡ (p - x) (mod s)`` land on real (non-inserted)
input — so the ``k x k`` weight decomposes exactly into ``s**2`` parity
sub-kernels that correlate *directly with the un-upsampled input*.

For the paper's case (``s=2, k=3, p=1``) the four sub-kernels are the four
corners (2x2), the horizontal endpoints (1x2), the vertical endpoints (2x1) and
the center (1x1) — Fig. 6.

Conventions (NHWC / HWIO, cross-correlation, no kernel flip):

    U = zero_insert(x, s)                  # (H-1)*s + 1 per spatial dim
    O[y, x] = sum_{ky,kx} W[ky,kx] * U_pad[y + ky, x + kx]
    with U_pad = pad(U, (p_lo, p_hi))      # output size (H-1)*s + p_lo + p_hi - k + 2

``p_hi = p_lo + output_padding`` recovers the usual framework semantics
(e.g. ENet's 2x upsampling uses s=2, k=3, p_lo=1, output_padding=1 -> O = 2H).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

_DIMS = ("NHWC", "HWIO", "NHWC")


def out_size(h: int, s: int, k: int, p_lo: int, p_hi: int) -> int:
    return (h - 1) * s + p_lo + p_hi - k + 2


def zero_insert_input(x: jax.Array, s: int) -> jax.Array:
    """Explicitly materialise the zero-inserted input (Fig. 5, naive path)."""
    if s == 1:
        return x
    n, h, w_, c = x.shape
    u = jnp.zeros((n, (h - 1) * s + 1, (w_ - 1) * s + 1, c), x.dtype)
    return u.at[:, ::s, ::s, :].set(x)


def transposed_conv2d_reference(
    x: jax.Array, w: jax.Array, stride: int, padding: int, output_padding: int = 0
) -> jax.Array:
    """XLA oracle via ``lhs_dilation`` (zero-insertion fused into the conv)."""
    k = w.shape[0]
    p_lo, p_hi = padding, padding + output_padding
    return lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=[(p_lo, p_hi), (p_lo, p_hi)],
        lhs_dilation=(stride, stride), dimension_numbers=_DIMS,
    )


def transposed_conv2d_naive(
    x: jax.Array, w: jax.Array, stride: int, padding: int, output_padding: int = 0
) -> jax.Array:
    """Dense execution over the explicitly zero-inserted input (naive path)."""
    u = zero_insert_input(x, stride)
    p_lo, p_hi = padding, padding + output_padding
    return lax.conv_general_dilated(
        u, w, window_strides=(1, 1), padding=[(p_lo, p_hi), (p_lo, p_hi)],
        dimension_numbers=_DIMS,
    )


def parity_taps(k: int, s: int, p_lo: int, r: int) -> list[int]:
    """Kernel taps (one spatial dim) that hit real input for output parity r."""
    return [t for t in range(k) if (t - p_lo + r) % s == 0]


def decompose_weight(w, s: int, p_lo: int):
    """Split an HWIO kernel into the ``s**2`` parity sub-kernels (Fig. 6).

    Returns ``{(ry, rx): (sub_kernel, row_offsets, col_offsets)}`` where the
    offsets are the *input* indices (relative to the output block index) each
    tap reads: ``offset = (r + t - p_lo) // s`` for tap ``t``.
    Parities whose tap set is empty (possible when ``k < s``) map to ``None``.
    """
    k = w.shape[0]
    out = {}
    for ry in range(s):
        for rx in range(s):
            tr = parity_taps(k, s, p_lo, ry)
            tc = parity_taps(k, s, p_lo, rx)
            if not tr or not tc:
                out[(ry, rx)] = None
                continue
            sub = w[jnp.array(tr)][:, jnp.array(tc)]
            ro = [(ry + t - p_lo) // s for t in tr]
            co = [(rx + t - p_lo) // s for t in tc]
            out[(ry, rx)] = (sub, ro, co)
    return out


@partial(jax.jit, static_argnames=("stride", "padding", "output_padding",
                                   "phase_sharding"))
def transposed_conv2d_decomposed(
    x: jax.Array, w: jax.Array, stride: int, padding: int,
    output_padding: int = 0, phase_sharding=None,
) -> jax.Array:
    """The paper's method: per-parity sub-kernel correlation, no zero-insert.

    Each parity output plane is a small dense VALID correlation of the (padded)
    input with its sub-kernel; the ``s**2`` planes interleave into the output.
    MACs issued == nonzero MACs of the naive execution (exact skip).

    ``phase_sharding`` (hashable ``NamedSharding``, DESIGN.md §13) constrains
    each parity plane's correlation input on the batch axis — the s**2 parity
    sub-problems are independent and batch-parallel.  Static, so meshed and
    un-meshed callers never share a trace-cache entry.
    """
    s, k = stride, w.shape[0]
    if s == 1:
        return transposed_conv2d_reference(x, w, 1, padding, output_padding)
    n, h, w_in, _ = x.shape
    cout = w.shape[-1]
    p_lo = padding
    oh = out_size(h, s, k, p_lo, p_lo + output_padding)
    ow = out_size(w_in, s, k, p_lo, p_lo + output_padding)
    out = jnp.zeros((n, oh, ow, cout), x.dtype)

    subs = decompose_weight(w, s, p_lo)
    for (ry, rx), entry in subs.items():
        # number of outputs in this parity plane
        nyr = len(range(ry, oh, s))
        nxr = len(range(rx, ow, s))
        if nyr == 0 or nxr == 0:
            continue
        if entry is None:  # parity never touched by any tap -> zeros
            continue
        sub, ro, co = entry
        # output plane index b reads input rows b + ro[0] .. b + ro[-1]
        # -> VALID correlate input padded by (-ro[0]) on top/left and whatever
        #    the last plane index needs on bottom/right.
        pad_top, pad_left = -ro[0], -co[0]
        need_bot = (nyr - 1) + ro[-1] - (h - 1)   # last input row needed minus available
        need_rgt = (nxr - 1) + co[-1] - (w_in - 1)
        xp = jnp.pad(
            x,
            (
                (0, 0),
                (max(pad_top, 0), max(need_bot, 0)),
                (max(pad_left, 0), max(need_rgt, 0)),
                (0, 0),
            ),
        )
        # crop if offsets start inside the input (pad_top < 0)
        xp = xp[:, max(-pad_top, 0):, max(-pad_left, 0):, :]
        if phase_sharding is not None:
            xp = lax.with_sharding_constraint(xp, phase_sharding)
        plane = lax.conv_general_dilated(
            xp, sub, window_strides=(1, 1), padding="VALID", dimension_numbers=_DIMS,
        )
        out = out.at[:, ry::s, rx::s, :].set(plane[:, :nyr, :nxr, :])
    return out


# ---------------------------------------------------------------------------
# MAC counting
# ---------------------------------------------------------------------------

def macs_naive(h: int, w: int, cin: int, cout: int, k: int, s: int,
               p_lo: int, p_hi: int) -> int:
    """MACs of dense execution over the zero-inserted input (incl. zeros)."""
    oh, ow = out_size(h, s, k, p_lo, p_hi), out_size(w, s, k, p_lo, p_hi)
    return oh * ow * cin * cout * k * k


def macs_decomposed_transposed(h: int, w: int, cin: int, cout: int, k: int,
                               s: int, p_lo: int, p_hi: int) -> int:
    """Exact MACs issued by the decomposition (sum over parity planes)."""
    oh, ow = out_size(h, s, k, p_lo, p_hi), out_size(w, s, k, p_lo, p_hi)
    total = 0
    for ry in range(s):
        for rx in range(s):
            tr = len(parity_taps(k, s, p_lo, ry))
            tc = len(parity_taps(k, s, p_lo, rx))
            nyr = len(range(ry, oh, s))
            nxr = len(range(rx, ow, s))
            total += nyr * nxr * tr * tc * cin * cout
    return total
