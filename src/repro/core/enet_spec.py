"""ENet @ 512x512 per-layer workload table (paper §III test case).

ENet (Paszke et al. 2016) trained on Cityscapes, resized to 512x512 as in the
paper.  Each entry records the convolution workload only (the accelerator's
job); pooling/unpooling/PReLU run on the side units and do not consume MAC
cycles.  Bottleneck internal channels are ``C/4`` per the ENet paper.

Layer kinds:
  - ``conv``        dense convolution (1x1 projections, 3x3 regular, 2x2/s2
                    downsample, 5x1+1x5 asymmetric — each asymmetric half is
                    its own entry)
  - ``dilated``     3x3 dilated convolution, ``D`` zeros between taps
                    (dilation step d = D+1; ENet uses d = 2,4,8,16)
  - ``transposed``  3x3 stride-2 upsampling convolution
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ConvLayer:
    name: str
    kind: str            # conv | dilated | transposed
    h_out: int           # output spatial height
    w_out: int           # output spatial width
    cin: int
    cout: int
    kh: int = 3
    kw: int = 3
    D: int = 0           # zeros between taps (dilated only);  d = D + 1
    stride: int = 1      # upsampling factor (transposed) or output stride (dilated)
    group: str = "general"  # general | dilated | transposed (paper Fig. 10 split)
    output_padding: int = 1  # transposed only: extra high-side output size
    # transposed only: low-side pad of the zero-inserted input (p_lo).  None
    # means the framework default (k-1)//2 that every ENet/ESPNet layer uses;
    # generative decoders record explicit pads (DCGAN k=4/s=2 upsampling is
    # p_lo=2, U-Net k=2/s=2 is p_lo=1 — repro.core.gen_spec).
    padding: int | None = None


def _bottleneck_regular(prefix: str, hw: int, c: int, D: int = 0, asym: bool = False):
    """Regular / dilated / asymmetric non-downsampling bottleneck (ENet §3)."""
    ci = c // 4
    layers = [ConvLayer(f"{prefix}.reduce", "conv", hw, hw, c, ci, 1, 1)]
    if asym:
        layers += [
            ConvLayer(f"{prefix}.conv5x1", "conv", hw, hw, ci, ci, 5, 1),
            ConvLayer(f"{prefix}.conv1x5", "conv", hw, hw, ci, ci, 1, 5),
        ]
    elif D > 0:
        layers.append(
            ConvLayer(f"{prefix}.dil(D={D})", "dilated", hw, hw, ci, ci, 3, 3, D=D,
                      group="dilated")
        )
    else:
        layers.append(ConvLayer(f"{prefix}.conv3x3", "conv", hw, hw, ci, ci, 3, 3))
    layers.append(ConvLayer(f"{prefix}.expand", "conv", hw, hw, ci, c, 1, 1))
    return layers


def _bottleneck_down(prefix: str, hw_out: int, cin: int, cout: int):
    ci = cout // 4
    return [
        ConvLayer(f"{prefix}.reduce2x2s2", "conv", hw_out, hw_out, cin, ci, 2, 2),
        ConvLayer(f"{prefix}.conv3x3", "conv", hw_out, hw_out, ci, ci, 3, 3),
        ConvLayer(f"{prefix}.expand", "conv", hw_out, hw_out, ci, cout, 1, 1),
    ]


def _bottleneck_up(prefix: str, hw_out: int, cin: int, cout: int):
    ci = cout // 4
    return [
        ConvLayer(f"{prefix}.reduce", "conv", hw_out // 2, hw_out // 2, cin, ci, 1, 1),
        ConvLayer(f"{prefix}.deconv3x3s2", "transposed", hw_out, hw_out, ci, ci,
                  3, 3, stride=2, group="transposed"),
        ConvLayer(f"{prefix}.expand", "conv", hw_out, hw_out, ci, cout, 1, 1),
        # skip-branch channel projection
        ConvLayer(f"{prefix}.skip1x1", "conv", hw_out // 2, hw_out // 2, cin, cout, 1, 1),
    ]


def enet_512_layers(num_classes: int = 19) -> list[ConvLayer]:
    L: list[ConvLayer] = []
    # initial block: 3x3/s2 conv, 3 -> 13 (concat 3-ch maxpool -> 16)
    L.append(ConvLayer("initial", "conv", 256, 256, 3, 13, 3, 3))
    # stage 1 (128x128, 64ch): down + 4 regular
    L += _bottleneck_down("b1.0", 128, 16, 64)
    for i in range(1, 5):
        L += _bottleneck_regular(f"b1.{i}", 128, 64)
    # stage 2 (64x64, 128ch): down + reg/dil2/asym/dil4/reg/dil8/asym/dil16
    L += _bottleneck_down("b2.0", 64, 64, 128)
    stage = [
        (0, False), (1, False), (0, True), (3, False),
        (0, False), (7, False), (0, True), (15, False),
    ]
    for i, (D, asym) in enumerate(stage, start=1):
        L += _bottleneck_regular(f"b2.{i}", 64, 128, D=D, asym=asym)
    # stage 3: same as stage 2 minus the downsample
    for i, (D, asym) in enumerate(stage, start=1):
        L += _bottleneck_regular(f"b3.{i}", 64, 128, D=D, asym=asym)
    # stage 4 (decoder, 128x128, 64ch): up + 2 regular
    L += _bottleneck_up("b4.0", 128, 128, 64)
    for i in range(1, 3):
        L += _bottleneck_regular(f"b4.{i}", 128, 64)
    # stage 5 (256x256, 16ch): up + 1 regular
    L += _bottleneck_up("b5.0", 256, 64, 16)
    L += _bottleneck_regular("b5.1", 256, 16)
    # fullconv: 3x3 stride-2 transposed, 16 -> classes, 512x512
    L.append(ConvLayer("fullconv", "transposed", 512, 512, 16, num_classes,
                       3, 3, stride=2, group="transposed"))
    return L


def dilated_layer_sets(layers: list[ConvLayer]) -> dict[int, list[ConvLayer]]:
    """Group dilated layers by D (paper Fig. 11: L1..L4 <-> D = 1,3,7,15)."""
    out: dict[int, list[ConvLayer]] = {}
    for l in layers:
        if l.kind == "dilated":
            out.setdefault(l.D, []).append(l)
    return out


def transposed_layer_sets(layers: list[ConvLayer]) -> dict[int, list[ConvLayer]]:
    """Group transposed layers by output size (paper Fig. 12: 128/256/512)."""
    out: dict[int, list[ConvLayer]] = {}
    for l in layers:
        if l.kind == "transposed":
            out.setdefault(l.h_out, []).append(l)
    return out
