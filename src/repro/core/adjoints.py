"""Adjoint (VJP) machinery for the decomposition engine (DESIGN.md §6).

The paper's central symmetry also governs gradients:

* the input-gradient of a **strided dense** convolution *is* a transposed
  convolution (stride ``s``, flipped/IO-transposed kernel) — route it through
  the weight-decomposition engine;
* the input-gradient of a **transposed** convolution *is* a strided dense
  convolution — route it through the dense engine;
* the input-gradient of a **dilated** convolution (stride 1) is a dilated
  convolution with the same step and the flipped kernel — route it through
  the input-decomposition engine;
* every **weight-gradient** is a batched correlation over strided input
  gathers — ``k**2`` tap slices contracted on the MXU, the same phase/parity
  gather the forward decomposition uses, never touching inserted zeros.

This module holds the engine-agnostic pieces: the kernel flip, the tap-gather
weight-gradient correlation, and the padding arithmetic that maps each
forward geometry to its adjoint geometry.  The Pallas kernels register
``jax.custom_vjp`` rules built from these (see ``repro.kernels``); the XLA
paths in :mod:`repro.core.dilated` / :mod:`repro.core.transposed` are lax
compositions and differentiate natively.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flip_io(w: jax.Array) -> jax.Array:
    """Spatially flip an HWIO kernel and swap its in/out channels.

    ``flip_io(w)[ky, kx, co, ci] == w[k-1-ky, k-1-kx, ci, co]`` — the kernel
    of every input-gradient convolution.
    """
    return w[::-1, ::-1].swapaxes(2, 3)


def tap_correlation(a: jax.Array, b: jax.Array, kh: int, kw: int, *,
                    stride: int = 1, tap_step: int = 1) -> jax.Array:
    """Batched tap-gather correlation: the universal weight-gradient form.

    ``T[ty, tx, ca, cb] = sum_{n,oy,ox} a[n,oy,ox,ca] *
    b[n, stride*oy + tap_step*ty, stride*ox + tap_step*tx, cb]``.

    Each tap is one strided gather of ``b`` (a phase slice — no inserted
    zeros are ever read) contracted against ``a`` as a single
    ``(N*OH*OW, Ca) x (N*OH*OW, Cb)`` matmul on the MXU.  ``b`` must be
    pre-padded so every index is in range: extent
    ``>= tap_step*(k-1) + stride*(OH-1) + 1`` per spatial dim.
    """
    n, oh, ow, ca = a.shape
    cb = b.shape[-1]
    af = a.reshape(n * oh * ow, ca)
    rows = []
    for ty in range(kh):
        cols = []
        for tx in range(kw):
            bs = jax.lax.slice(
                b,
                (0, tap_step * ty, tap_step * tx, 0),
                (n, tap_step * ty + stride * (oh - 1) + 1,
                 tap_step * tx + stride * (ow - 1) + 1, cb),
                (1, stride, stride, 1),
            )
            cols.append(jax.lax.dot_general(
                af, bs.reshape(n * oh * ow, cb), (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ))
        rows.append(jnp.stack(cols))
    return jnp.stack(rows)  # (k, k, Ca, Cb)


def _pad_to(x: jax.Array, lo_h: int, hi_h: int, lo_w: int, hi_w: int) -> jax.Array:
    """Pad (positive) or crop (negative) the spatial dims of an NHWC array."""
    x = x[:, max(-lo_h, 0): x.shape[1] - max(-hi_h, 0),
          max(-lo_w, 0): x.shape[2] - max(-hi_w, 0), :]
    return jnp.pad(x, ((0, 0), (max(lo_h, 0), max(hi_h, 0)),
                       (max(lo_w, 0), max(hi_w, 0)), (0, 0)))


# ---------------------------------------------------------------------------
# dense convolution  y = conv(x, w; stride s, pads (pl, ph) per dim)
# ---------------------------------------------------------------------------

def dense_conv_dx(g: jax.Array, w: jax.Array, stride: int, p_lo: int,
                  h: int, w_in: int, tconv_fn) -> jax.Array:
    """Input-gradient of a strided dense conv == a transposed convolution.

    ``dx[i] = sum_t g[(i + p_lo - t)/s] w[t]`` (divisible terms only) — the
    weight-decomposition engine applied to the cotangent with the flipped
    kernel, low pad ``k-1-p_lo``, output padding chosen so the output extent
    recovers ``(h, w_in)`` (extra high-side rows are gradients w.r.t. the
    forward zero-pad and are cropped).

    ``tconv_fn(g, wf, stride, padding, output_padding)`` is the transposed
    engine of the active backend.
    """
    k = w.shape[0]
    hg, wg = g.shape[1], g.shape[2]
    op_h = h - (hg - 1) * stride - k + 2 * p_lo
    op_w = w_in - (wg - 1) * stride - k + 2 * p_lo
    op = max(0, op_h, op_w)
    dx = tconv_fn(g, flip_io(w), stride, k - 1 - p_lo, op)
    return dx[:, :h, :w_in, :]


def dense_conv_dw(x: jax.Array, g: jax.Array, kh: int, kw: int, stride: int,
                  p_lo_h: int, p_lo_w: int) -> jax.Array:
    """Weight-gradient of a dense conv: ``kh*kw`` strided tap gathers of x."""
    n, h, w_in, _ = x.shape
    _, oh, ow, _ = g.shape
    need_h = (kh - 1) + stride * (oh - 1) + 1
    need_w = (kw - 1) + stride * (ow - 1) + 1
    xp = _pad_to(x, p_lo_h, need_h - h - p_lo_h, p_lo_w, need_w - w_in - p_lo_w)
    t = tap_correlation(g, xp, kh, kw, stride=stride)     # (kh, kw, Cout, Cin)
    return t.transpose(0, 1, 3, 2)


# ---------------------------------------------------------------------------
# transposed convolution  y = tconv(x, w; stride s, pads (p_lo, p_hi))
# ---------------------------------------------------------------------------

def _tconv_grad_pad(g: jax.Array, k: int, p_lo: int, p_hi: int) -> jax.Array:
    """Pad the tconv cotangent to ``(k-1-p_lo, k-1-p_hi)`` per spatial dim.

    Shared by the input- and weight-gradients below; negative amounts
    (``p_hi > k-1``, large ``output_padding``) crop instead.
    """
    return _pad_to(g, k - 1 - p_lo, k - 1 - p_hi, k - 1 - p_lo, k - 1 - p_hi)


def tconv_dx(g: jax.Array, w: jax.Array, stride: int, p_lo: int, p_hi: int,
             conv_fn) -> jax.Array:
    """Input-gradient of a transposed conv == a strided dense convolution.

    ``dx[i] = sum_t g[s*i + p_lo - t] w[t]`` — the dense engine at stride
    ``s`` over the padded cotangent with the flipped kernel; the output
    extent is exactly the forward input extent (no crop needed).

    ``conv_fn(gp, wf, stride)`` is a VALID strided dense conv of the active
    backend.
    """
    k = w.shape[0]
    return conv_fn(_tconv_grad_pad(g, k, p_lo, p_hi), flip_io(w), stride)


def tconv_dw(x: jax.Array, g: jax.Array, k: int, stride: int, p_lo: int,
             p_hi: int) -> jax.Array:
    """Weight-gradient of a transposed conv: tap gathers of the cotangent.

    ``dw[t] = sum_i x[i] g[s*i + p_lo - t]`` — with the cotangent padded as
    in :func:`tconv_dx` the gather index becomes ``s*i + (k-1-t)``: the dense
    tap correlation at flipped tap order.
    """
    gp = _tconv_grad_pad(g, k, p_lo, p_hi)
    t = tap_correlation(x, gp, k, k, stride=stride)       # (k, k, Cin, Cout)
    return t[::-1, ::-1]


# ---------------------------------------------------------------------------
# dilated convolution  y = conv(x, w; dilation d, SAME, stride 1)
# ---------------------------------------------------------------------------

def dilated_conv_dx(g: jax.Array, w: jax.Array, dilation: int,
                    dilated_fn) -> jax.Array:
    """Input-gradient of a SAME dilated conv == the same dilated conv.

    With symmetric SAME padding ``p = d*(k-1)/2`` (odd ``k``), the adjoint
    is exactly the dilated engine applied to the cotangent with the flipped
    kernel — same step, same padding.  ``dilated_fn(g, wf, d)`` is the
    dilated engine of the active backend.
    """
    return dilated_fn(g, flip_io(w), dilation)


def dilated_conv_dw(x: jax.Array, g: jax.Array, k: int, dilation: int) -> jax.Array:
    """Weight-gradient of a SAME dilated conv: tap gathers at step ``d``.

    ``dw[t] = sum_o g[o] x[o - p + d*t]`` — the taps stride the input at the
    dilation step, i.e. each tap reads one phase block (the same gather the
    forward input decomposition performs).
    """
    d = dilation
    p = d * (k - 1) // 2
    xp = _pad_to(x, p, p, p, p)
    t = tap_correlation(g, xp, k, k, tap_step=d)          # (k, k, Cout, Cin)
    return t.transpose(0, 1, 3, 2)


# ---------------------------------------------------------------------------
# fused epilogues (DESIGN.md §7)
# ---------------------------------------------------------------------------

def fused_epilogue_bwd(conv_apply, spec, x, w, eps, g):
    """Backward pass of a fused conv+epilogue kernel by adjoint re-entry.

    The fused forward computes ``E(conv(x, w))`` with ``E`` the elementwise
    epilogue; its pullback is the pullback of the *composition* — so the
    backward differentiates ``apply_reference(spec, conv_apply(x, w), eps)``
    with ``jax.vjp``.  ``conv_apply`` is the engine's own differentiable
    (epilogue-free) kernel, so the conv cotangent re-enters the decomposition
    adjoints of DESIGN.md §6 with fp32 accumulators, while the BN/PReLU/
    residual gradients are cheap elementwise jnp ops computed in fp32.

    The pre-epilogue conv output is *recomputed* here rather than saved by
    the forward — saving it would mean a second HBM write per tile, undoing
    exactly the traffic the fusion removes.

    Returns ``(dx, dw, deps)`` with ``deps`` matching the ``eps`` tuple.
    """
    from repro.kernels import epilogue as _ep

    def f(x, w, eps):
        return _ep.apply_reference(spec, conv_apply(x, w), eps)

    _, vjp = jax.vjp(f, x, w, eps)
    return vjp(g)


__all__ = [
    "flip_io", "tap_correlation", "dense_conv_dx", "dense_conv_dw",
    "tconv_dx", "tconv_dw", "dilated_conv_dx", "dilated_conv_dw",
    "fused_epilogue_bwd",
]
