"""ESPNet @ 512x512 per-layer workload table (second accelerator workload).

Mirrors :mod:`repro.models.espnet` (the compact ESPNet variant: K = 4 pyramid
branches at d = 1, 2, 4, 8, alpha2 = 2, alpha3 = 3, deconv decoder) the same
way :mod:`repro.core.enet_spec` mirrors :mod:`repro.models.enet` — each entry
records the convolution workload only.

Differences from the ENet table that matter to the cycle model:

* dilation rates are *small and mixed* (2/4/8 in one module, vs ENet's
  per-layer 2..16) — the dilated efficiency band is sampled at the high end;
* the downsampling ESP modules are **strided dilated** convolutions — the
  output-class schedule (DESIGN.md §2c), which ENet never exercises;
* the decoder is deconv-only (no skip max-unpool), so the transposed share
  is carried entirely by 3x3/s2 layers at 128/256/512.
"""

from __future__ import annotations

from repro.core.enet_spec import ConvLayer

ESP_DILATIONS = (1, 2, 4, 8)


def esp_module_layers(prefix: str, hw_in: int, cin: int, cout: int,
                      stride: int = 1) -> list[ConvLayer]:
    """ESP module: 1x1 reduce + K parallel 3x3 branches (one per dilation).

    The d = 1 branch is a plain dense conv (group "general"); d > 1 branches
    are dilated convs (group "dilated"), strided when the module downsamples.
    """
    K = len(ESP_DILATIONS)
    cb = cout // K
    hw_out = hw_in // stride
    layers = [ConvLayer(f"{prefix}.reduce", "conv", hw_in, hw_in, cin, cb, 1, 1)]
    for d in ESP_DILATIONS:
        if d == 1:
            layers.append(ConvLayer(f"{prefix}.br_d1", "conv", hw_out, hw_out,
                                    cb, cb, 3, 3, stride=stride))
        else:
            layers.append(ConvLayer(f"{prefix}.br_d{d}", "dilated", hw_out,
                                    hw_out, cb, cb, 3, 3, D=d - 1,
                                    stride=stride, group="dilated"))
    return layers


def espnet_512_layers(num_classes: int = 19, alpha2: int = 2,
                      alpha3: int = 3) -> list[ConvLayer]:
    L: list[ConvLayer] = []
    L.append(ConvLayer("stem", "conv", 256, 256, 3, 16, 3, 3))
    L += esp_module_layers("down1", 256, 16, 64, stride=2)
    for i in range(alpha2):
        L += esp_module_layers(f"l2.{i}", 128, 64, 64)
    L.append(ConvLayer("skip2", "conv", 128, 128, 64, num_classes, 1, 1))
    L += esp_module_layers("down2", 128, 64, 128, stride=2)
    for i in range(alpha3):
        L += esp_module_layers(f"l3.{i}", 64, 128, 128)
    L.append(ConvLayer("head", "conv", 64, 64, 128, num_classes, 1, 1))
    for i, hw in enumerate((128, 256, 512), start=1):
        L.append(ConvLayer(f"up{i}", "transposed", hw, hw, num_classes,
                           num_classes, 3, 3, stride=2, group="transposed"))
    return L
