"""Unified public API for the paper's decomposition technique.

``conv2d`` dispatches to dense / dilated / transposed execution with the
decomposition applied automatically — this is the entry point the model zoo
(ENet, conv frontends) uses, so the technique is a first-class framework
feature rather than a demo.
"""

from __future__ import annotations

import jax

from repro.core import dilated as _dil
from repro.core import transposed as _tr


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    dilation: int = 1,
    transposed: bool = False,
    padding: int | None = None,
    output_padding: int = 0,
    decomposed: bool = True,
    strategy: str = "batched",
) -> jax.Array:
    """General 2-D convolution with the paper's decomposition applied.

    Args:
      x: (N, H, W, Cin) input.
      w: (k, k, Cin, Cout) compact kernel (never zero-inserted by the caller).
      stride: forward-conv stride, or upsampling factor when ``transposed``.
      dilation: dilation step ``d = D + 1`` (forward conv only).
      transposed: run a transposed (fractionally-strided) convolution.
      padding: ``None`` -> SAME for forward conv, ``(k-1)//2`` for transposed.
      output_padding: transposed-conv extra size on the high side.
      decomposed: apply the paper's decomposition (False -> naive zero-laden
        execution, used as the measured baseline).
      strategy: 'batched' (TPU phase-batched) or 'ragged' (paper-faithful) for
        the dilated path.
    """
    k = w.shape[0]
    if transposed:
        if dilation != 1:
            raise ValueError("dilated transposed convolution not used by the paper")
        p = (k - 1) // 2 if padding is None else padding
        if decomposed:
            return _tr.transposed_conv2d_decomposed(x, w, stride, p, output_padding)
        return _tr.transposed_conv2d_naive(x, w, stride, p, output_padding)
    if dilation > 1:
        if stride != 1:
            raise ValueError("strided dilated convolution not used by the paper")
        if decomposed:
            return _dil.dilated_conv2d_decomposed(x, w, dilation, strategy=strategy)
        return _dil.dilated_conv2d_naive(x, w, dilation)
    # plain dense conv (stride >= 1)
    import jax.numpy as jnp  # noqa: F401
    from jax import lax

    p = (k - 1) // 2 if padding is None else padding
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=[(p, p), (p, p)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


__all__ = ["conv2d"]
