"""Unified public API for the paper's decomposition technique.

``conv2d`` dispatches to dense / dilated / transposed execution with the
decomposition applied automatically — this is the entry point the model zoo
(ENet, ESPNet, conv frontends) uses, so the technique is a first-class
framework feature rather than a demo.

The engine is fully general: transposed convolutions accept any square
``(kernel, stride, output_padding)`` via the programmatic parity schedule
(paper §II-C generalised — see DESIGN.md §3), dilated convolutions accept
any ``stride`` via the output-class schedule (DESIGN.md §2c), and dense
convolutions accept rectangular kernels (ENet's 5x1/1x5 asymmetric pair).
``backend`` selects the execution engine: ``"xla"`` composes ``lax``
convolutions, ``"pallas"`` runs the fused Pallas kernels in
:mod:`repro.kernels`.

Two cross-cutting features ride the dispatcher (DESIGN.md §7):

* **fused epilogues** — ``epilogue=EpilogueSpec(...)`` with matching
  ``scale``/``shift``/``alpha``/``residual`` operands folds BN, PReLU and a
  residual add into the kernel's output pass (the XLA backend applies the
  identical :func:`repro.kernels.epilogue.apply_reference` oracle post-conv,
  so both backends compute the same function);
* **autotuned tiling** — when ``th``/``tc`` are left unset, the pallas tile
  shape is resolved per layer geometry through
  :mod:`repro.kernels.autotune` (cached sweep; defaults on a cold miss).

``conv2d`` is fully differentiable on both backends: the XLA paths are lax
compositions, and every fused Pallas kernel registers a ``jax.custom_vjp``
whose backward re-enters the engine through the adjoint symmetry — the
input-gradient of a strided dense conv is a transposed conv, of a transposed
conv a strided dense conv, of a dilated conv the same dilated conv; weight
gradients are tap-gather correlations (DESIGN.md §6,
:mod:`repro.core.adjoints`); fused epilogues differentiate by adjoint
re-entry of the conv∘epilogue composition.  The pallas backend is
first-order differentiable (``jax.custom_vjp`` is not
forward-differentiable).
"""

from __future__ import annotations

import jax

from repro.core import dilated as _dil
from repro.core import transposed as _tr
from repro.kernels.epilogue import EpilogueSpec, apply_reference, pack_args
from repro.kernels.util import canon_dtype


def _resolve_tiles(kind: str, x, w, stride: int, dilation: int,
                   th: int | None, tc: int | None, padding=None,
                   output_padding: int | None = None,
                   epilogue: EpilogueSpec | None = None) -> tuple[int, int]:
    """Fill unset tile dims from the autotune table (DESIGN.md §7).

    The epilogue spec rides into the cache key — fused operands change the
    kernel's VMEM footprint, so each configuration tunes separately.
    """
    from repro.kernels import autotune

    if th is not None and tc is not None:
        return th, tc
    tth, ttc = autotune.get_tiles(kind, tuple(x.shape), tuple(w.shape),
                                  stride=stride, dilation=dilation,
                                  dtype=x.dtype, padding=padding,
                                  output_padding=output_padding,
                                  epilogue=epilogue)
    return (tth if th is None else th), (ttc if tc is None else tc)


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    dilation: int = 1,
    transposed: bool = False,
    padding: int | None = None,
    output_padding: int = 0,
    decomposed: bool = True,
    strategy: str = "batched",
    backend: str = "xla",
    interpret: bool | None = None,
    epilogue: EpilogueSpec | None = None,
    scale: jax.Array | None = None,
    shift: jax.Array | None = None,
    alpha: jax.Array | None = None,
    residual: jax.Array | None = None,
    th: int | None = None,
    tc: int | None = None,
    compute_dtype=None,
    phase_sharding=None,
) -> jax.Array:
    """General 2-D convolution with the paper's decomposition applied.

    Args:
      x: (N, H, W, Cin) input.
      w: (kh, kw, Cin, Cout) compact kernel (never zero-inserted by the
        caller); rectangular ``kh != kw`` supported for plain dense convs.
      stride: forward-conv stride, or upsampling factor when ``transposed``.
      dilation: dilation step ``d = D + 1`` (forward conv only).
      transposed: run a transposed (fractionally-strided) convolution.
      padding: ``None`` -> SAME for forward conv, ``(k-1)//2`` for transposed.
      output_padding: transposed-conv extra size on the high side.
      decomposed: apply the paper's decomposition (False -> naive zero-laden
        execution, used as the measured baseline).
      strategy: 'batched' (TPU phase-batched) or 'ragged' (paper-faithful) for
        the dilated path.
      backend: 'xla' (composable lax convolutions) or 'pallas' (fused kernels
        from :mod:`repro.kernels`).
      interpret: Pallas interpret-mode override (None -> auto-detect; only
        meaningful with ``backend='pallas'``).
      epilogue: optional fused BN/PReLU/residual epilogue spec (DESIGN.md §7)
        with matching ``scale``/``shift``/``alpha``/``residual`` operands;
        fused in-kernel on pallas, applied as the reference oracle on xla.
      th, tc: Pallas tile shape override; ``None`` resolves through the
        autotune table (:mod:`repro.kernels.autotune`).
      compute_dtype: mixed-precision opt-in (DESIGN.md §12): ``None`` keeps
        the input dtype; a dtype (or alias string like ``"bf16"``) casts
        ``x``/``w``/``residual`` to it before dispatch, and the output comes
        back in it — accumulation stays fp32 inside the Pallas kernels, and
        the epilogue's channel operands (scale/shift/alpha) stay fp32
        throughout.  ``bf16`` in -> ``bf16`` out holds on every path.
      phase_sharding: optional hashable ``NamedSharding`` constraining the
        decomposition's phase/parity layout on a mesh (DESIGN.md §13) — the
        folded phase-batch of the dilated path, the per-parity-plane batch of
        the transposed path.  XLA decomposed paths only; usually set through
        :func:`repro.distributed.sharding.shard_conv2d` rather than directly.
    """
    if backend not in ("xla", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")
    cd = canon_dtype(compute_dtype)
    if cd is not None:
        x = x.astype(cd)
        w = w.astype(cd)
        if residual is not None:
            residual = residual.astype(cd)
    if backend == "pallas" and not decomposed:
        # the fused kernels ARE the decomposition; the naive zero-laden
        # baseline only exists as composed XLA convolutions
        raise ValueError("naive execution has no pallas kernel; use backend='xla'")
    spec = EpilogueSpec() if epilogue is None else epilogue
    eps = pack_args(spec, scale=scale, shift=shift, alpha=alpha,
                    residual=residual)
    ep_kw = dict(zip(spec.slots, eps))
    kh, kw = w.shape[0], w.shape[1]
    if transposed:
        if dilation != 1:
            raise ValueError("dilated transposed convolution is not supported")
        if kh != kw:
            raise ValueError("transposed convolution requires square kernels")
        p = (kh - 1) // 2 if padding is None else padding
        if backend == "pallas":
            from repro.kernels.transposed_conv import transposed_conv2d as _ktr

            th, tc = _resolve_tiles("tconv", x, w, stride, 1, th, tc,
                                    padding=p, output_padding=output_padding,
                                    epilogue=spec)
            return _ktr(x, w, stride=stride, padding=p,
                        output_padding=output_padding, th=th, tc=tc,
                        interpret=interpret, epilogue=epilogue, **ep_kw)
        if decomposed:
            y = _tr.transposed_conv2d_decomposed(
                x, w, stride, p, output_padding,
                phase_sharding=phase_sharding)
        else:
            y = _tr.transposed_conv2d_naive(x, w, stride, p, output_padding)
        return apply_reference(spec, y, eps)
    if dilation > 1:
        if kh != kw:
            raise ValueError("dilated convolution requires square kernels")
        if backend == "pallas":
            if strategy != "batched":
                raise ValueError(
                    f"pallas dilated path is phase-batched only, got {strategy!r}")
            from repro.kernels.dilated_conv import dilated_conv2d as _kdil

            th, tc = _resolve_tiles("dilated", x, w, stride, dilation, th, tc,
                                    epilogue=spec)
            return _kdil(x, w, dilation, stride=stride, th=th, tc=tc,
                         interpret=interpret, epilogue=epilogue, **ep_kw)
        if decomposed:
            y = _dil.dilated_conv2d_decomposed(
                x, w, dilation, strategy=strategy, stride=stride,
                phase_sharding=phase_sharding)
        else:
            y = _dil.dilated_conv2d_naive(x, w, dilation, stride=stride)
        return apply_reference(spec, y, eps)
    # plain dense conv (stride >= 1, rectangular kernels welcome)
    if backend == "pallas":
        from repro.kernels.conv2d import conv2d as _kconv

        th, tc = _resolve_tiles("dense", x, w, stride, 1, th, tc,
                                padding=padding, epilogue=spec)
        return _kconv(x, w, stride=stride,
                      padding="SAME" if padding is None else padding,
                      th=th, tc=tc, interpret=interpret, epilogue=epilogue,
                      **ep_kw)
    from jax import lax

    if padding is None:     # SAME, asymmetric for even extents
        pads = [((kh - 1) // 2, kh // 2), ((kw - 1) // 2, kw // 2)]
    else:
        pads = [(padding, padding), (padding, padding)]
    y = lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=pads,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return apply_reference(spec, y, eps)


__all__ = ["conv2d"]
