"""Unified public API for the paper's decomposition technique.

``conv2d`` dispatches to dense / dilated / transposed execution with the
decomposition applied automatically — this is the entry point the model zoo
(ENet, conv frontends) uses, so the technique is a first-class framework
feature rather than a demo.

The engine is fully general: transposed convolutions accept any square
``(kernel, stride, output_padding)`` via the programmatic parity schedule
(paper §II-C generalised — see DESIGN.md §3), and dilated convolutions accept
any ``stride`` via the output-class schedule (DESIGN.md §2c).  ``backend``
selects the execution engine: ``"xla"`` composes ``lax`` convolutions,
``"pallas"`` runs the fused Pallas kernels in :mod:`repro.kernels`.

``conv2d`` is fully differentiable on both backends: the XLA paths are lax
compositions, and every fused Pallas kernel registers a ``jax.custom_vjp``
whose backward re-enters the engine through the adjoint symmetry — the
input-gradient of a strided dense conv is a transposed conv, of a transposed
conv a strided dense conv, of a dilated conv the same dilated conv; weight
gradients are tap-gather correlations (DESIGN.md §6,
:mod:`repro.core.adjoints`).  The pallas backend is first-order
differentiable (``jax.custom_vjp`` is not forward-differentiable).
"""

from __future__ import annotations

import jax

from repro.core import dilated as _dil
from repro.core import transposed as _tr


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    dilation: int = 1,
    transposed: bool = False,
    padding: int | None = None,
    output_padding: int = 0,
    decomposed: bool = True,
    strategy: str = "batched",
    backend: str = "xla",
    interpret: bool | None = None,
) -> jax.Array:
    """General 2-D convolution with the paper's decomposition applied.

    Args:
      x: (N, H, W, Cin) input.
      w: (k, k, Cin, Cout) compact kernel (never zero-inserted by the caller).
      stride: forward-conv stride, or upsampling factor when ``transposed``.
      dilation: dilation step ``d = D + 1`` (forward conv only).
      transposed: run a transposed (fractionally-strided) convolution.
      padding: ``None`` -> SAME for forward conv, ``(k-1)//2`` for transposed.
      output_padding: transposed-conv extra size on the high side.
      decomposed: apply the paper's decomposition (False -> naive zero-laden
        execution, used as the measured baseline).
      strategy: 'batched' (TPU phase-batched) or 'ragged' (paper-faithful) for
        the dilated path.
      backend: 'xla' (composable lax convolutions) or 'pallas' (fused kernels
        from :mod:`repro.kernels`).
      interpret: Pallas interpret-mode override (None -> auto-detect; only
        meaningful with ``backend='pallas'``).
    """
    if backend not in ("xla", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "pallas" and not decomposed:
        # the fused kernels ARE the decomposition; the naive zero-laden
        # baseline only exists as composed XLA convolutions
        raise ValueError("naive execution has no pallas kernel; use backend='xla'")
    k = w.shape[0]
    if transposed:
        if dilation != 1:
            raise ValueError("dilated transposed convolution is not supported")
        p = (k - 1) // 2 if padding is None else padding
        if backend == "pallas":
            from repro.kernels.transposed_conv import transposed_conv2d as _ktr

            return _ktr(x, w, stride=stride, padding=p,
                        output_padding=output_padding, interpret=interpret)
        if decomposed:
            return _tr.transposed_conv2d_decomposed(x, w, stride, p, output_padding)
        return _tr.transposed_conv2d_naive(x, w, stride, p, output_padding)
    if dilation > 1:
        if backend == "pallas":
            if strategy != "batched":
                raise ValueError(
                    f"pallas dilated path is phase-batched only, got {strategy!r}")
            from repro.kernels.dilated_conv import dilated_conv2d as _kdil

            return _kdil(x, w, dilation, stride=stride, interpret=interpret)
        if decomposed:
            return _dil.dilated_conv2d_decomposed(
                x, w, dilation, strategy=strategy, stride=stride)
        return _dil.dilated_conv2d_naive(x, w, dilation, stride=stride)
    # plain dense conv (stride >= 1)
    if backend == "pallas":
        from repro.kernels.conv2d import conv2d as _kconv

        return _kconv(x, w, stride=stride,
                      padding="SAME" if padding is None else padding,
                      interpret=interpret)
    from jax import lax

    p = (k - 1) // 2 if padding is None else padding
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=[(p, p), (p, p)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


__all__ = ["conv2d"]
