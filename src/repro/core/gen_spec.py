"""Generative-decoder workload tables (DCGAN generators, diffusion U-Net
decoder) for the cycle model.

EcoFlow's observation — and the reason the paper's weight decomposition
exists — is that transposed convolutions dominate *generative* networks:
GAN generators and diffusion decoders are chains of stride-2 upsampling
convolutions, where ENet/ESPNet only carry a short decoder tail.  These
tables mirror :mod:`repro.models.dcgan` and :mod:`repro.models.unet_decoder`
the same way :mod:`repro.core.enet_spec` mirrors :mod:`repro.models.enet`:
each entry records the convolution workload the accelerator executes.

Geometry notes that matter to the cycle model:

* DCGAN upsampling is ``k=4, s=2, p_lo=2, output_padding=0`` — the PyTorch
  ``ConvTranspose2d(4, stride=2, padding=1)`` exact-2x geometry.  The pads
  are *not* the framework default ``(k-1)//2``, so every entry records its
  ``padding`` explicitly (``cycle_model.tconv_pads``).
* The U-Net decoder alternates ``k=4`` and ``k=2`` upsampling (both with
  ``p_lo = k//2``) — the even-kernel parity schedules, which the ENet-family
  workloads never exercise.
* DCGAN's initial projection (z -> 4x4xC) is a dense matmul; it is recorded
  as the 1x1-conv-equivalent workload (one ``nz``-deep MAC per output
  pixel), which issues exactly the same MAC count.
"""

from __future__ import annotations

import math

from repro.core.enet_spec import ConvLayer

#: per-level upsampling kernels of the U-Net decoder (k=2 and k=4 both get
#: exercised); ``p_lo = k//2`` with output_padding=0 is the exact-2x geometry
#: for even kernels.
UNET_UP_KERNELS = (4, 2, 4)

#: default U-Net decoder widths: level i runs at ``8 * 2**i`` spatial with
#: this many channels (the skip concat doubles the input of the first conv).
UNET_WIDTHS = (256, 128, 64)


def dcgan_layers(size: int = 64, nz: int = 100, ngf: int = 64,
                 out_ch: int = 3) -> list[ConvLayer]:
    """DCGAN-style generator at 64x64 or 128x128 (Radford et al. 2016).

    Projection to ``4x4 x (ngf * size/8)``, then chained ``k=4, s=2``
    transposed convolutions halving channels and doubling resolution each
    stage, and a ``k=4, s=2`` tanh head to ``out_ch`` — all transposed
    workload except the projection.  Mirrors
    :func:`repro.models.dcgan.init_params` exactly.
    """
    if size not in (64, 128):
        raise ValueError(f"DCGAN generator sizes are 64/128, got {size}")
    n_up = int(math.log2(size // 4))        # 4 stages at 64, 5 at 128
    c = ngf * (size // 8)                   # 512 at 64, 1024 at 128
    L = [ConvLayer("proj", "conv", 4, 4, nz, c, 1, 1)]
    hw = 4
    for i in range(1, n_up):
        hw *= 2
        L.append(ConvLayer(f"up{i}", "transposed", hw, hw, c, c // 2, 4, 4,
                           stride=2, group="transposed", output_padding=0,
                           padding=2))
        c //= 2
    L.append(ConvLayer("head", "transposed", hw * 2, hw * 2, c, out_ch, 4, 4,
                       stride=2, group="transposed", output_padding=0,
                       padding=2))
    return L


def unet_decoder_layers(widths: tuple[int, ...] = UNET_WIDTHS,
                        skip_chs: tuple[int, ...] | None = None,
                        hw: int = 8, out_ch: int = 3) -> list[ConvLayer]:
    """Diffusion-style U-Net decoder block stack (mid 8x8 -> 64x64 image).

    Level ``i`` runs at ``hw * 2**i`` spatial with ``widths[i]`` channels:
    skip-concat (``+ skip_chs[i]``) -> two dense 3x3 convs (GroupNorm-folded
    epilogues) -> ``k in {4, 2}``, s=2 transposed upsample to the next
    level's width (the last level halves).  A dense 3x3 head maps to
    ``out_ch``.  Mirrors :func:`repro.models.unet_decoder.init_params`.
    """
    if skip_chs is None:
        skip_chs = tuple(widths)
    if len(skip_chs) != len(widths):
        raise ValueError(f"{len(skip_chs)} skip widths for {len(widths)} levels")
    L: list[ConvLayer] = []
    for i, (c, cs) in enumerate(zip(widths, skip_chs)):
        k = UNET_UP_KERNELS[i % len(UNET_UP_KERNELS)]
        c_next = widths[i + 1] if i + 1 < len(widths) else widths[-1] // 2
        L.append(ConvLayer(f"lvl{i}.conv1", "conv", hw, hw, c + cs, c, 3, 3))
        L.append(ConvLayer(f"lvl{i}.conv2", "conv", hw, hw, c, c, 3, 3))
        hw *= 2
        L.append(ConvLayer(f"lvl{i}.up_k{k}", "transposed", hw, hw, c, c_next,
                           k, k, stride=2, group="transposed",
                           output_padding=0, padding=k // 2))
    L.append(ConvLayer("head", "conv", hw, hw, widths[-1] // 2, out_ch, 3, 3))
    return L


#: name -> zero-arg table constructor; the benchmark/report surfaces iterate
#: this so a new generative workload is one entry here.
GEN_WORKLOADS = {
    "dcgan64": lambda: dcgan_layers(64),
    "dcgan128": lambda: dcgan_layers(128),
    "unet_dec": lambda: unet_decoder_layers(),
}


__all__ = ["dcgan_layers", "unet_decoder_layers", "GEN_WORKLOADS",
           "UNET_UP_KERNELS", "UNET_WIDTHS"]
