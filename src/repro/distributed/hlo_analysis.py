"""Loop-aware HLO analysis: FLOPs, HBM bytes and collective traffic.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count (verified empirically), which would understate a scanned-layer model by
~num_layers x.  This module parses ``compiled.as_text()`` instead:

  * builds a per-computation symbol table (instruction -> shape),
  * recovers while-loop trip counts from the loop-condition constant,
  * propagates multiplicative trip multipliers through nested loops,
  * sums dot/convolution FLOPs, per-instruction HBM bytes (fusion
    boundaries only, mirroring XLA's bytes-accessed convention), and
  * sizes every collective (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute) both as operand bytes (assignment
    formula) and as ring-model wire bytes per chip.

All shapes in a GSPMD-partitioned module are per-device, so every number
this module returns is per-chip.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast",
                  "all-gather-start", "all-reduce-start")


def _parse_shapes(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """'bf16[8,128]{1,0}' or '(f32[2], s32[])' -> [(dtype, dims), ...]."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype == "token" or dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dtype, shape))
    return out


def _nbytes(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * int(math.prod(sh) or 1)
               for dt, sh in _parse_shapes(type_str))


def _nelems(type_str: str) -> int:
    return sum(int(math.prod(sh) or 1) for _, sh in _parse_shapes(type_str))


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str
    operands: list[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    shapes: dict[str, str] = field(default_factory=dict)


@dataclass
class CollectiveStat:
    op: str
    count: float = 0.0
    operand_bytes: float = 0.0   # assignment formula: sum of operand sizes
    wire_bytes: float = 0.0      # ring model: per-chip bytes on the wire


def parse_module(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line or line.startswith("HloModule"):
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.endswith("{") and "->" in line:
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        paren = line[m.end() - 1:]
        depth = 0
        args = ""
        for ch in paren:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            if ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                args += ch
        operands = _OPERAND_RE.findall(args)
        ins = Instr(name, type_str, op, line, operands)
        cur.instrs.append(ins)
        cur.shapes[name] = type_str
    if entry and entry != "main":
        comps.setdefault("__entry__", comps[entry])
    return comps


def _attr_comp(line: str, key: str) -> str | None:
    m = re.search(key + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _trip_count(cond: Computation) -> int:
    """Largest s32 scalar constant in the loop condition (scan bound)."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant" and ins.type_str.startswith("s32"):
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def compute_multipliers(comps: dict[str, Computation], entry: str
                        ) -> dict[str, float]:
    """Execution-count multiplier per computation (nested loops compose)."""
    mult: dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    # topological-ish fixed point (call graph is a DAG)
    for _ in range(64):
        changed = False
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        for cname, comp in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                if ins.op == "while":
                    body = _attr_comp(ins.line, "body")
                    cond = _attr_comp(ins.line, "condition")
                    if body in comps and cond in comps:
                        trips = _trip_count(comps[cond])
                        new[body] = new.get(body, 0.0) + m * trips
                        new[cond] = new.get(cond, 0.0) + m * (trips + 1)
                elif ins.op in ("fusion", "call", "custom-call"):
                    callee = _attr_comp(ins.line, "calls")
                    if callee in comps:
                        new[callee] = new.get(callee, 0.0) + m
                elif ins.op == "conditional":
                    for callee in re.findall(
                            r"(?:branch_computations=\{([^}]*)\}|"
                            r"(?:true|false)_computation=%?([\w.\-]+))",
                            ins.line):
                        for c in callee:
                            for cc in re.findall(r"[\w.\-]+", c or ""):
                                if cc in comps:
                                    new[cc] = new.get(cc, 0.0) + m
        new_t = {k: v for k, v in new.items()}
        if new_t == mult:
            break
        mult = new_t
        changed = True
    return mult


def _fusion_callees(comps: dict[str, Computation]) -> set[str]:
    out = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                callee = _attr_comp(ins.line, "calls")
                if callee:
                    out.add(callee)
    return out


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = _nelems(ins.type_str)
    if not ins.operands:
        return 0.0
    lhs = comp.shapes.get(ins.operands[0])
    if lhs is None:
        return 0.0
    lhs_shapes = _parse_shapes(lhs)
    if not lhs_shapes:
        return 0.0
    lhs_dims = lhs_shapes[0][1]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contracted = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            if int(d) < len(lhs_dims):
                contracted *= lhs_dims[int(d)]
    return 2.0 * out_elems * contracted


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_elems = _nelems(ins.type_str)
    if len(ins.operands) < 2:
        return 0.0
    rhs = comp.shapes.get(ins.operands[1])
    if rhs is None:
        return 0.0
    rhs_shapes = _parse_shapes(rhs)
    if not rhs_shapes:
        return 0.0
    rhs_dims = rhs_shapes[0][1]
    # kernel contributes (prod of all dims except output-feature dim)
    m = re.search(r"dim_labels=\S*_(\w+)->", ins.line)
    per_out = int(math.prod(rhs_dims))
    if m:
        lbl = m.group(1)  # e.g. 01io or io01
        o_pos = lbl.index("o")
        per_out = per_out // rhs_dims[o_pos]
    return 2.0 * out_elems * per_out


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "iota", "fusion", "call",
}


@dataclass
class HLOAnalysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)
    trip_counts: dict = field(default_factory=dict)

    @property
    def collective_operand_bytes(self) -> float:
        return sum(c.operand_bytes for c in self.collectives.values())

    @property
    def collective_wire_bytes(self) -> float:
        return sum(c.wire_bytes for c in self.collectives.values())


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m and m.group(1):
        first = m.group(1).split("}")[0].strip("{ ")
        return max(1, len([x for x in first.split(",") if x.strip()]))
    return 1


def analyze(hlo_text: str) -> HLOAnalysis:
    comps = parse_module(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line[len("ENTRY "):].strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:  # fall back: computation named main-ish
        entry = next((n for n in comps if "main" in n), next(iter(comps)))
    mult = compute_multipliers(comps, entry)
    fused = _fusion_callees(comps)

    res = HLOAnalysis()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fused
        for ins in comp.instrs:
            if ins.op == "dot":
                res.flops += m * _dot_flops(ins, comp)
            elif ins.op == "convolution":
                res.flops += m * _conv_flops(ins, comp)
            if in_fusion:
                continue  # bytes count at the fusion boundary only
            if ins.op in _SKIP_BYTES_OPS and ins.op != "fusion":
                continue
            out_b = _nbytes(ins.type_str)
            opnd_b = sum(_nbytes(comp.shapes[o]) for o in ins.operands
                         if o in comp.shapes)
            res.hbm_bytes += m * (out_b + opnd_b)

            base_op = ins.op.replace("-start", "")
            if base_op in ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute",
                           "collective-broadcast"):
                g = _group_size(ins.line)
                out_b_c = _nbytes(ins.type_str)
                stat = res.collectives.setdefault(base_op,
                                                  CollectiveStat(base_op))
                stat.count += m
                if base_op == "all-gather":
                    operand = out_b_c / max(g, 1)
                    wire = out_b_c * (g - 1) / max(g, 1)
                elif base_op == "all-reduce":
                    operand = out_b_c
                    wire = 2.0 * out_b_c * (g - 1) / max(g, 1)
                elif base_op == "reduce-scatter":
                    operand = out_b_c * g
                    wire = out_b_c * (g - 1)
                elif base_op == "all-to-all":
                    operand = out_b_c
                    wire = out_b_c * (g - 1) / max(g, 1)
                else:  # permute / broadcast
                    operand = out_b_c
                    wire = out_b_c
                stat.operand_bytes += m * operand
                stat.wire_bytes += m * wire

    for cname, comp in comps.items():
        for ins in comp.instrs:
            if ins.op == "while":
                cond = _attr_comp(ins.line, "condition")
                if cond in comps:
                    res.trip_counts[cname + "/" + ins.name] = _trip_count(
                        comps[cond])
    return res


# ------------------------------------------------------------ roofline ----

V5E = {
    "flops_bf16": 197e12,   # per chip
    "hbm_gbps": 819e9,      # per chip
    "ici_gbps": 50e9,       # per link
}


def roofline_terms(a: HLOAnalysis, hw: dict = V5E) -> dict[str, float]:
    """Per-chip time (s) if each resource were the only bottleneck."""
    return {
        "compute_s": a.flops / hw["flops_bf16"],
        "memory_s": a.hbm_bytes / hw["hbm_gbps"],
        "collective_s": a.collective_wire_bytes / hw["ici_gbps"],
    }
