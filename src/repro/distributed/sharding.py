"""Logical-axis sharding: one rule set drives 1-device smoke tests, the
256-chip single pod and the 512-chip multi-pod mesh.

Mesh axes: ``(pod?, data, model)``.
  * ``data``  — DP for activations, FSDP for parameters/optimizer state.
  * ``model`` — TP (heads / ffn hidden / vocab) and EP (experts).
  * ``pod``   — pure DP across pods: batch shards over it, parameters are
    replicated per pod, gradients all-reduce over pod links.

Two rule families:
  * **activation constraints** — models call ``layers.lc(x, logical_axes)``;
    `install(mesh)` resolves logical names to mesh axes with divisibility
    guards (a constraint that does not divide is dropped, never an error, so
    the same model code runs on any mesh).
  * **parameter specs** — ``param_pspec(path, shape)`` maps parameter tree
    paths to PartitionSpecs by name rules (TP dim) + FSDP on the other dim.
"""

from __future__ import annotations

import re
from contextlib import contextmanager

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import layers as _layers

# logical activation axis -> ordered mesh-axis candidates (first that divides
# the dim and is not already used wins; tuples shard over several axes).
_ACT_CANDIDATES = {
    "data": (("pod", "data"), ("data",)),
    "data_kvseq": (("pod", "data"), ("data",)),
    # KV-cache sequence axis: shard as wide as divisibility allows — over
    # everything for batch-1 long-context decode, over the model axis when
    # the batch already owns the data axes (32k batched decode).
    "kvseq": (("pod", "data", "model"), ("data", "model"), ("pod", "data"),
              ("data",), ("model",)),
    "model": (("model",),),
    "model_kv": (("model",),),
    "expert": (("model",),),
    "fsdp": (("data",),),
    # sequence parallelism: the residual stream between layers shards its
    # sequence dim over the model axis (Megatron-SP); decode (S=1) drops it
    # via the divisibility guard.
    "seq": (("model",),),
    # generative serving (NHWC image state): the spatial height shards over
    # the model axis — the phase-batched conv layouts are batch- and
    # row-parallel, XLA inserts the k-1 halo exchanges.
    "spatial": (("model",),),
    # decomposition phase/parity axis: the d*d (or s*s) sub-problems are
    # independent by construction (paper §II), so the folded (d*d*N) batch
    # of the phase-batched layout shards like data — this is the
    # embarrassingly-parallel axis DESIGN.md §13 scales over.
    "phase": (("pod", "data"), ("data",)),
}


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def resolve_spec(mesh: Mesh, logical: tuple, shape: tuple[int, ...]) -> P:
    """Logical names -> PartitionSpec with divisibility + reuse guards."""
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, logical):
        entry = None
        if name is not None:
            for cand in _ACT_CANDIDATES.get(name, ()):
                cand = tuple(a for a in cand if a in mesh.shape)
                if not cand or any(a in used for a in cand):
                    continue
                if dim % _axes_size(mesh, cand) == 0:
                    entry = cand if len(cand) > 1 else cand[0]
                    used.update(cand)
                    break
        out.append(entry)
    return P(*out)


def install(mesh: Mesh) -> None:
    """Route ``layers.lc`` constraints onto this mesh."""

    def constrain(x, logical):
        spec = resolve_spec(mesh, logical, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    _layers.set_constraint_fn(constrain)


def uninstall() -> None:
    _layers.set_constraint_fn(None)


@contextmanager
def use_mesh(mesh: Mesh):
    install(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        uninstall()


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------
# (path regex, logical axes for the LAST ndim dims). Stacked-layer leading
# axes (repeat/num_layers) are never sharded. "fsdp" -> data axis.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("model", "fsdp")),               # (V, D)
    (r"lm_head$", ("fsdp", "model")),             # (D, V)
    (r"enc_pos$", (None, None)),
    (r"(wq|wk|wv)$", ("fsdp", "model")),          # (D, H*hd)
    (r"wo$", ("model", "fsdp")),                  # (H*hd, D)
    (r"router$", ()),                             # (D, E) tiny, replicated
    # MoE expert-stacked weights: experts -> model axis (EP), fsdp on D
    (r"we_gate$", ("expert", "fsdp", None)),      # (E, D, F)
    (r"we_up$", ("expert", "fsdp", None)),
    (r"we_down$", ("expert", None, "fsdp")),      # (E, F, D)
    (r"(w_gate|w_up)$", ("fsdp", "model")),       # dense FFN (D, F)
    (r"w_down$", ("model", "fsdp")),              # dense FFN (F, D)
    # mamba
    (r"in_proj$", ("fsdp", "model")),
    (r"out_proj$", ("model", "fsdp")),
    (r"x_proj$", ("model", None)),
    (r"dt_proj$", (None, "model")),
    (r"conv_w$", (None, "model")),
    (r"(conv_b|dt_bias|D)$", ("model",)),
    (r"A_log$", ("model", None)),
    # xlstm
    (r"up_proj$", ("fsdp", "model")),
    (r"w_if$", ("model", None)),
    (r"(w_gates|r_gates|ff_up)$", ("fsdp", "model")),
    (r"ff_down$", ("model", "fsdp")),
    # norms / scalars replicated
    (r".*", ()),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspec(mesh: Mesh, path: str, shape: tuple[int, ...]) -> P:
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path):
            if not logical:
                return P()
            # right-align logical axes onto the trailing dims
            full = (None,) * (len(shape) - len(logical)) + tuple(logical)
            return resolve_spec(mesh, full, shape)
    return P()


def make_param_shardings(mesh: Mesh, abstract_params):
    """Pytree of NamedShardings matching an abstract (eval_shape) pytree."""

    def leaf(path, x):
        return NamedSharding(mesh, param_pspec(mesh, _path_str(path), x.shape))

    return jax.tree_util.tree_map_with_path(leaf, abstract_params)


def batch_sharding(mesh: Mesh, ndim: int = 2) -> NamedSharding:
    """Tokens (B, S, ...) shard the batch over (pod, data)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0],
                                 *([None] * (ndim - 1))))


def image_sharding(mesh: Mesh, shape: tuple[int, ...], *,
                   spatial: bool = False) -> NamedSharding:
    """NHWC generative-serving state: batch over (pod, data), optionally the
    spatial height over the model axis (``spatial=True``).

    Used by ``repro.launch.serve_gen`` for the request-batch image state; the
    usual divisibility guards apply, so a 4-request smoke batch on a 1-device
    mesh resolves to fully replicated instead of erroring.
    """
    logical = ("data", "spatial" if spatial else None, None, None)
    return NamedSharding(mesh, resolve_spec(mesh, logical[:len(shape)], shape))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The mesh axes the batch (and the phase/parity fold) shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def data_axis_size(mesh: Mesh) -> int:
    return _axes_size(mesh, data_axes(mesh))


def phase_sharding(mesh: Mesh, nphases: int, batch: int) -> NamedSharding:
    """Sharding for the folded phase/parity axis of a decomposed layout.

    The phase-batched dilated layout stacks the ``d*d`` phase blocks on the
    batch axis (shape ``(d*d*N, H/d, W/d, C)``); each block is an independent
    dense conv, so the folded axis shards over the data axes with the usual
    divisibility guard (a non-dividing fold resolves to replicated).
    """
    spec = resolve_spec(mesh, ("phase", None, None, None),
                        (nphases * batch, 1, 1, 1))
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# Sharded conv2d entry point (DESIGN.md §13)
# ---------------------------------------------------------------------------
# jitted closures cached per (mesh, option set); jax's own cache handles the
# per-shape specialisation underneath.
_SHARD_CONV_CACHE: dict = {}


def pad_batch(x, multiple: int):
    """Zero-pad the leading (batch) dim up to a multiple; returns (x, orig)."""
    b = x.shape[0]
    pad = (-b) % multiple
    if pad:
        import jax.numpy as jnp
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, b


def _shard_conv_fn(mesh: Mesh, spatial: bool, with_grads: bool, kw_items):
    key = (mesh, spatial, with_grads, kw_items)
    fn = _SHARD_CONV_CACHE.get(key)
    if fn is not None:
        return fn
    import jax.numpy as jnp

    from repro.core.decompose import conv2d as _conv2d

    kw = dict(kw_items)

    def fwd(x, w):
        return _conv2d(x, w, **kw)

    if with_grads:
        def call(x, w):
            # gradient of the sum of outputs: zero-padded batch rows are
            # zero inputs to a linear map, so they contribute nothing to dw
            # and their dx rows are sliced off by the caller.
            y, vjp = jax.vjp(fwd, x, w)
            dx, dw = vjp(jnp.ones_like(y))
            return y, dx, dw
    else:
        call = fwd
    fn = jax.jit(call, out_shardings=NamedSharding(mesh, P()))
    _SHARD_CONV_CACHE[key] = fn
    return fn


def shard_conv2d(mesh: Mesh, x, w, *, spatial: bool = False,
                 with_grads: bool = False, **conv_kwargs):
    """Run :func:`repro.core.decompose.conv2d` sharded over ``mesh``.

    The batch is zero-padded up to a multiple of the data-axis extent (uneven
    remainders therefore work; padded rows are sliced off the output), placed
    with :func:`image_sharding` (``spatial=True`` additionally shards H over
    the model axis when divisible — XLA inserts the halo exchanges), and the
    decomposed dilated path gets the folded phase axis constrained via
    :func:`phase_sharding`.  The forward pass is bitwise-equal to the
    single-device result; gradients reduce through GSPMD collectives and are
    allclose, not bitwise (the bitwise training reduction lives in
    :func:`repro.launch.train_recipes.make_sharded_train_step`).

    Returns ``out`` or, with ``with_grads=True``, ``(out, dx, dw)`` where the
    grads are of ``sum(out)``.
    """
    import jax.numpy as jnp

    xp, b = pad_batch(jnp.asarray(x), data_axis_size(mesh))
    kw = dict(conv_kwargs)
    d = kw.get("dilation", 1)
    decomposed_xla = (kw.get("decomposed", True)
                      and kw.get("backend", "xla") == "xla")
    if kw.get("transposed", False) and decomposed_xla:
        # parity planes correlate the un-upsampled input batch-parallel
        kw["phase_sharding"] = NamedSharding(
            mesh, resolve_spec(mesh, ("data", None, None, None),
                               (xp.shape[0], 1, 1, 1)))
    elif (d > 1 and not kw.get("transposed", False) and decomposed_xla
            and kw.get("strategy", "batched") == "batched"):
        kw["phase_sharding"] = phase_sharding(mesh, d * d, xp.shape[0])
    xp = jax.device_put(xp, image_sharding(mesh, xp.shape, spatial=spatial))
    wd = jax.device_put(jnp.asarray(w), replicated(mesh))
    fn = _shard_conv_fn(mesh, spatial, with_grads,
                        tuple(sorted(kw.items(), key=lambda it: it[0])))
    if with_grads:
        y, dx, dw = fn(xp, wd)
        return y[:b], dx[:b], dw
    return fn(xp, wd)[:b]
