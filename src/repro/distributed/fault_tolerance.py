"""Fault tolerance: heartbeats, straggler watchdog, restart controller.

On a real fleet the heartbeat file is a distributed KV entry and the restart
controller is the job scheduler; the *logic* — detect, checkpoint-restore,
re-shard, resume at the exact step with the exact data stream — is what this
module implements and what the failure-injection tests exercise.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field


class Heartbeat:
    """Periodic liveness marker; stale hearts mark dead hosts."""

    def __init__(self, path: str, host_id: int = 0):
        self.path = os.path.join(path, f"heartbeat_{host_id:03d}.json")
        os.makedirs(path, exist_ok=True)

    def beat(self, step: int) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(tmp, self.path)

    @staticmethod
    def dead_hosts(path: str, timeout_s: float) -> list[int]:
        now = time.time()
        dead = []
        if not os.path.isdir(path):
            return dead
        for name in sorted(os.listdir(path)):
            if not name.startswith("heartbeat_"):
                continue
            with open(os.path.join(path, name)) as f:
                hb = json.load(f)
            if now - hb["time"] > timeout_s:
                dead.append(int(name.split("_")[1].split(".")[0]))
        return dead


@dataclass
class StragglerWatchdog:
    """EWMA step-time monitor; flags steps slower than ``threshold`` x EWMA.

    On a fleet the flag triggers hot-spare swap / re-shard; here it feeds the
    training log and the fault-tolerance tests.
    """

    alpha: float = 0.1
    threshold: float = 2.5
    warmup: int = 5
    _ewma: float = 0.0
    _n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._ewma = dt if self._ewma == 0 else (
                self.alpha * dt + (1 - self.alpha) * self._ewma)
            return False
        slow = dt > self.threshold * self._ewma
        if slow:
            self.flagged.append((step, dt, self._ewma))
        else:  # stragglers do not poison the baseline
            self._ewma = self.alpha * dt + (1 - self.alpha) * self._ewma
        return slow


class FailureInjector:
    """Deterministically raise at a given step (tests / chaos drills)."""

    def __init__(self, fail_at_steps: set[int]):
        self.fail_at = set(fail_at_steps)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")
