"""Fault tolerance: heartbeats, straggler watchdog, tick-level fault plane.

On a real fleet the heartbeat file is a distributed KV entry and the restart
controller is the job scheduler; the *logic* — detect, checkpoint-restore,
re-shard, resume at the exact step with the exact data stream — is what this
module implements and what the chaos drills (``tests/test_chaos.py``,
``tests/test_fault_tolerance.py``) exercise.

:class:`FailureInjector` is the chaos plane shared by the serving and
training loops (DESIGN.md §11): a list of :class:`Fault` descriptors, each
scheduled at a tick/step (or armed on every tick), consumed by the loop at
well-defined points:

* ``kill``    — raised outside any recovery machinery: simulates the host
  process dying (the snapshot/restore drills drive this);
* ``raise``   — raised inside the dispatch path, where the serving loop's
  retry/backoff/degrade ladder sees it (optionally conditioned on the
  lane's current ``backend``, so a "pallas is broken" fault stops firing
  once the lane degrades to xla);
* ``corrupt`` — poisons one lane slot's image state with NaNs; the server
  detects the non-finite sample at completion and re-runs the request;
* ``slow``    — stalls the tick by ``seconds`` inside the timed window, so
  the :class:`StragglerWatchdog` observes it.
"""

from __future__ import annotations

import json
import os
import re
import time
from dataclasses import dataclass, field

_HEART_RE = re.compile(r"heartbeat_(\d+)\.json(\.tmp)?")


class Heartbeat:
    """Periodic liveness marker; stale hearts mark dead hosts."""

    def __init__(self, path: str, host_id: int = 0):
        self.path = os.path.join(path, f"heartbeat_{host_id:03d}.json")
        os.makedirs(path, exist_ok=True)

    def beat(self, step: int) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(tmp, self.path)

    @staticmethod
    def dead_hosts(path: str, timeout_s: float) -> list[int]:
        """Hosts without a fresh, *readable* heartbeat.

        A host is alive only if it can prove it: a heartbeat that is
        truncated, corrupt, unreadable, or still a ``.tmp`` (crash inside
        the atomic-rename window) proves nothing, so such a host is
        reported dead rather than crashing the monitor — the monitor is
        the component that must survive everyone else's failures.
        """
        now = time.time()
        if not os.path.isdir(path):
            return []
        seen: set[int] = set()
        alive: set[int] = set()
        for name in sorted(os.listdir(path)):
            m = _HEART_RE.fullmatch(name)
            if m is None:
                continue
            host = int(m.group(1))
            seen.add(host)
            if m.group(2):          # .tmp mid-rename: not a liveness proof
                continue
            try:
                with open(os.path.join(path, name)) as f:
                    hb = json.load(f)
                fresh = now - float(hb["time"]) <= timeout_s
            except (OSError, ValueError, KeyError, TypeError):
                continue            # unreadable/corrupt: cannot prove alive
            if fresh:
                alive.add(host)
        return sorted(seen - alive)


@dataclass
class StragglerWatchdog:
    """EWMA step-time monitor; flags steps slower than ``threshold`` x EWMA.

    On a fleet the flag triggers hot-spare swap / re-shard; here it feeds the
    training log, the serving loop's stuck-tick shedding ladder
    (DESIGN.md §11), and the fault-tolerance tests.
    """

    alpha: float = 0.1
    threshold: float = 2.5
    warmup: int = 5
    _ewma: float = 0.0
    _n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._ewma = dt if self._ewma == 0 else (
                self.alpha * dt + (1 - self.alpha) * self._ewma)
            return False
        slow = dt > self.threshold * self._ewma
        if slow:
            self.flagged.append((step, dt, self._ewma))
        else:  # stragglers do not poison the baseline
            self._ewma = self.alpha * dt + (1 - self.alpha) * self._ewma
        return slow


@dataclass(frozen=True)
class Fault:
    """One scheduled fault (see module docstring for kind semantics).

    ``at`` is the scheduler tick / train step the fault arms at; ``None``
    arms it on *every* tick (a persistent failure).  ``target`` restricts a
    serving fault to one lane (workload name); ``backend`` restricts it to
    lanes currently dispatching on that backend — the handle that lets a
    degraded lane escape a persistent backend fault.  ``once`` faults
    disarm after their first firing (transient failures); persistent
    faults (``once=False``) re-fire until their condition stops matching.
    """
    at: int | None
    kind: str = "raise"         # kill | raise | corrupt | slow
    target: str | None = None   # lane workload (serving faults)
    slot: int = 0               # corrupt: which lane slot to poison
    seconds: float = 0.0        # slow: injected stall inside the tick
    backend: str | None = None  # raise: only fire on this lane backend
    once: bool = True

    def __post_init__(self):
        if self.kind not in ("kill", "raise", "corrupt", "slow"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FailureInjector:
    """Deterministic tick-level fault plane (tests / chaos drills).

    Constructed either the seed way — ``FailureInjector({12})`` raises at
    step 12, the training loop's original contract — or with explicit
    :class:`Fault` descriptors via ``faults=``.  Loops consume faults at
    their injection points with :meth:`take`; a consumed ``once`` fault
    never fires again.
    """

    def __init__(self, fail_at_steps: set[int] | tuple = (),
                 faults: tuple[Fault, ...] | list = ()):
        self.fail_at = set(fail_at_steps)
        self.faults: list[Fault] = [Fault(at=s, kind="raise")
                                    for s in sorted(self.fail_at)]
        self.faults += list(faults)
        self.fired: list[Fault] = []

    def take(self, step: int, *, kind: str, target: str | None = None,
             backend: str | None = None) -> list[Fault]:
        """Arm-and-consume the ``kind`` faults matching this tick.

        ``target``/``backend`` describe the *consumer* (the lane asking);
        a fault with a ``None`` field matches any consumer.
        """
        hits = []
        for f in self.faults:
            if f.kind != kind:
                continue
            if f.at is not None and f.at != step:
                continue
            if f.target is not None and target is not None \
                    and f.target != target:
                continue
            if f.backend is not None and backend is not None \
                    and f.backend != backend:
                continue
            if f.once and f in self.fired:
                continue
            self.fired.append(f)
            hits.append(f)
        return hits

    def maybe_fail(self, step: int) -> None:
        """Raise if a ``raise``/``kill`` fault is scheduled at ``step`` —
        the training loop's injection point (both kinds land in its
        checkpoint-restore-resume path)."""
        for kind in ("raise", "kill"):
            if self.take(step, kind=kind):
                raise RuntimeError(f"injected node failure at step {step}")

    def sleep_faults(self, step: int) -> float:
        """Total injected stall (s) scheduled at ``step``; consumes them."""
        return sum(f.seconds for f in self.take(step, kind="slow"))


def failure_faults(*, kill_at: int | None = None,
                   backend_broken: str | None = None) -> FailureInjector:
    """The two canonical chaos recipes, pre-packaged for drills and the
    serving benchmark: ``kill_at`` schedules process death at that tick
    (recovery = snapshot restore); ``backend_broken`` arms a persistent
    dispatch failure for lanes on that backend — it keeps firing until the
    lane degrades off the backend, at which point it stops matching."""
    faults: list[Fault] = []
    if kill_at is not None:
        faults.append(Fault(at=kill_at, kind="kill"))
    if backend_broken is not None:
        faults.append(Fault(at=None, kind="raise", backend=backend_broken,
                            once=False))
    return FailureInjector(faults=faults)
