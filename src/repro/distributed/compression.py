"""Gradient compression for cross-pod links (distributed-optimization trick).

Two schemes, applied to gradients *before* they cross the slow pod boundary:

  * ``bf16``  — cast f32 grads to bf16 (2x wire reduction, negligible loss).
  * ``int8``  — per-tensor symmetric int8 quantization with **error
    feedback**: the quantization residual is carried in optimizer-adjacent
    state and added to the next step's gradient, making the scheme unbiased
    over time (1-bit-Adam-style convergence behaviour at 4x reduction).

Under GSPMD the cast happens before the pod-axis ``psum`` so the all-reduce
operand (what the §Roofline collective parser sizes) is genuinely int8/bf16 —
the wire saving is visible in the compiled HLO, not simulated.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    # initial=0.0 keeps zero-size leaves legal (a reduction over an empty
    # array has no identity otherwise) — a bias-free layer's empty grad leaf
    # must round-trip, not crash the whole compressed all-reduce.
    scale = jnp.maximum(jnp.max(jnp.abs(g), initial=0.0), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_int8_ef(grads, errors):
    """Returns (q_tree, scale_tree, new error-feedback tree)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    eleaves = treedef.flatten_up_to(errors)
    qs, scales, new_es = [], [], []
    for g, e in zip(leaves, eleaves):
        g = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g)
        qs.append(q)
        scales.append(scale)
        new_es.append(g - dequantize_int8(q, scale))
    unflat = jax.tree_util.tree_unflatten
    return unflat(treedef, qs), unflat(treedef, scales), unflat(treedef,
                                                                new_es)


def decompress_int8(q_tree, scale_tree):
    return jax.tree.map(dequantize_int8, q_tree, scale_tree)


# ---------------------------------------------------------------------------
# Wire packing (link-DMA format for the quantized tree)
# ---------------------------------------------------------------------------

def pack_int8(q_tree, *, word: int = 4):
    """Flatten an int8 tree into ONE padded wire buffer.

    Each leaf is raveled and zero-padded up to a multiple of ``word`` bytes
    (link DMA granularity), then the chunks concatenate into a single int8
    buffer — one transfer per step instead of one per leaf.  Odd-length,
    scalar and zero-size leaves all pack; the manifest records each leaf's
    shape, buffer offset and true (unpadded) length so :func:`unpack_int8`
    restores the tree exactly.
    """
    if word < 1:
        raise ValueError(f"word must be >= 1, got {word}")
    leaves, treedef = jax.tree_util.tree_flatten(q_tree)
    chunks, entries, off = [], [], 0
    for leaf in leaves:
        flat = jnp.ravel(leaf).astype(jnp.int8)
        padded = flat.size + (-flat.size % word)
        chunks.append(jnp.pad(flat, (0, padded - flat.size)))
        entries.append((tuple(leaf.shape), off, flat.size))
        off += padded
    buf = (jnp.concatenate(chunks) if chunks
           else jnp.zeros((0,), jnp.int8))
    return buf, (treedef, tuple(entries))


def unpack_int8(buf, manifest):
    """Inverse of :func:`pack_int8`: wire buffer -> int8 tree."""
    treedef, entries = manifest
    leaves = [
        jnp.reshape(jax.lax.dynamic_slice_in_dim(buf, off, size), shape)
        for shape, off, size in entries
    ]
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Mesh all-reduce (the shard_map-side consumer, DESIGN.md §13)
# ---------------------------------------------------------------------------

#: gradient wire formats for :func:`mesh_allreduce`
TRANSPORTS = ("dense", "bf16")


def mesh_allreduce(grads, axis_name: str, *, transport: str = "dense"):
    """Fixed-order all-reduce of per-chunk gradient stacks.

    Called inside a ``shard_map`` body where every leaf carries a leading
    *virtual-shard* axis (the per-chunk gradients).  Each device all-gathers
    the full chunk stack and reduces it with a single fixed-order
    ``sum(axis=0)`` — the reduction tree is therefore identical on every mesh
    size, which is what makes the sharded train step 1-device ≡ N-device
    *bitwise* (a ``psum`` tree reassociates with the mesh and is not).

    ``transport="bf16"`` casts the stacks to bf16 *before* the gather, so the
    collective operand on the wire is genuinely 2x smaller in the compiled
    HLO; decompression back to fp32 happens before the fixed-order sum.
    Dense stays bitwise; bf16 trades bitwise parity for wire bandwidth and is
    gated by convergence-bound tests instead.
    """
    if transport not in TRANSPORTS:
        raise ValueError(f"unknown transport {transport!r}; known: {TRANSPORTS}")
    if transport == "bf16":
        grads = compress_bf16(grads)
    gathered = jax.tree.map(
        lambda g: jax.lax.all_gather(g, axis_name, axis=0, tiled=True), grads)
    if transport == "bf16":
        gathered = decompress_bf16(gathered)
    return jax.tree.map(lambda g: jnp.sum(g, axis=0), gathered)
