"""Gradient compression for cross-pod links (distributed-optimization trick).

Two schemes, applied to gradients *before* they cross the slow pod boundary:

  * ``bf16``  — cast f32 grads to bf16 (2x wire reduction, negligible loss).
  * ``int8``  — per-tensor symmetric int8 quantization with **error
    feedback**: the quantization residual is carried in optimizer-adjacent
    state and added to the next step's gradient, making the scheme unbiased
    over time (1-bit-Adam-style convergence behaviour at 4x reduction).

Under GSPMD the cast happens before the pod-axis ``psum`` so the all-reduce
operand (what the §Roofline collective parser sizes) is genuinely int8/bf16 —
the wire saving is visible in the compiled HLO, not simulated.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_int8_ef(grads, errors):
    """Returns (q_tree, scale_tree, new error-feedback tree)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    eleaves = treedef.flatten_up_to(errors)
    qs, scales, new_es = [], [], []
    for g, e in zip(leaves, eleaves):
        g = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g)
        qs.append(q)
        scales.append(scale)
        new_es.append(g - dequantize_int8(q, scale))
    unflat = jax.tree_util.tree_unflatten
    return unflat(treedef, qs), unflat(treedef, scales), unflat(treedef,
                                                                new_es)


def decompress_int8(q_tree, scale_tree):
    return jax.tree.map(dequantize_int8, q_tree, scale_tree)
