"""Whisper conv frontend on the repo's conv engine (demo driver).

Runs :func:`repro.models.whisper.frontend` — the real model's two-conv mel
frontend expressed as (H=1) 2-D convolutions through
``repro.core.decompose.conv2d`` — and checks output shape, finiteness, and
parity against the ``lax.conv_general_dilated`` reference.

  PYTHONPATH=src python examples/whisper_frontend_demo.py            # canonical
  PYTHONPATH=src python examples/whisper_frontend_demo.py --smoke    # CI tier-1
"""

import argparse

import jax
import jax.numpy as jnp

from repro.models import whisper


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry (CI tier-1): B=1, T=64, d_model=32")
    ap.add_argument("--batch", type=int, default=2)
    ns = ap.parse_args()

    if ns.smoke:
        b, t, mel, d = 1, 64, 16, 32
    else:
        b, t, mel, d = ns.batch, whisper.N_FRAMES, whisper.N_MELS, 384

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = whisper.init_frontend_params(k1, n_mels=mel, d_model=d)
    x = jax.random.normal(k2, (b, t, mel))

    frames = whisper.frontend(params, x)
    ref = whisper.frontend_reference(params, x)
    err = float(jnp.max(jnp.abs(frames - ref)))

    print(f"mel {x.shape} -> frames {frames.shape} "
          f"(max |engine - lax reference| = {err:.2e})")
    assert frames.shape == (b, (t + 1) // 2, d), frames.shape
    assert bool(jnp.all(jnp.isfinite(frames)))
    assert err < 1e-4, err
    print("whisper frontend via repro.core.decompose: OK "
          "(transformer stack uses the input_specs stub per the assignment)")


if __name__ == "__main__":
    main()
