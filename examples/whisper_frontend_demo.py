"""Whisper conv frontend built from the repo's own conv engine.

The assignment stubs the audio frontend (input_specs supplies precomputed
frame embeddings), but the two 1-D convs of the real frontend are expressible
with `repro.core.decompose.conv2d` — this demo shows them and checks shapes:
mel (B, 3000, 80) -> conv k=3 s=1 -> gelu -> conv k=3 s=2 -> (B, 1500, D).

  PYTHONPATH=src python examples/whisper_frontend_demo.py
"""

import jax
import jax.numpy as jnp

from repro.core.decompose import conv2d

B, T, MEL, D = 2, 3000, 80, 384
key = jax.random.PRNGKey(0)
k1, k2, k3 = jax.random.split(key, 3)

mel = jax.random.normal(k1, (B, T, MEL))
# 1-D convs as (H=1) 2-D convs: (B, 1, T, C) with k=(1,3)
x = mel[:, None]                                     # (B, 1, T, MEL)
w1 = jax.random.normal(k2, (1, 3, MEL, D)) * 0.02
w2 = jax.random.normal(k3, (1, 3, D, D)) * 0.02

h = jax.nn.gelu(conv2d(x, w1))                        # stride 1, SAME
h = jax.nn.gelu(conv2d(h, w2, stride=2))              # stride 2 -> T/2
frames = h[:, 0]                                      # (B, 1500, D)
print("mel", mel.shape, "-> frames", frames.shape)
assert frames.shape == (B, T // 2, D)
assert bool(jnp.all(jnp.isfinite(frames)))
print("whisper frontend via repro.core.decompose: OK "
      "(production path uses the stub per the assignment)")
