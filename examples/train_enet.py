"""End-to-end driver: train ENet on synthetic Cityscapes-like data with every
dilated/transposed convolution running through the paper's decomposition.

  PYTHONPATH=src python examples/train_enet.py --steps 200 --hw 64

``--backend pallas`` trains through the fused Pallas engine end to end: the
forward runs the decomposed kernels and the backward runs their custom VJPs
(input-gradients re-enter the engine through the adjoint symmetry, weight
gradients are tap-gather correlations — DESIGN.md §6).

(~100M-MAC-scale model; a few hundred steps on CPU at --hw 64.  The pallas
backend on a CPU host runs in interpret mode — use small --steps/--hw there.)
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SegDataPipeline
from repro.launch import train_recipes
from repro.models import enet
from repro.optim import adamw_init, adamw_update, cosine_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--hw", type=int, default=64)
    ap.add_argument("--classes", type=int, default=19)
    ap.add_argument("--lr", type=float, default=5e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--backend", choices=("xla", "pallas"), default="xla",
                    help="execution engine for every conv (fwd AND bwd)")
    ap.add_argument("--dtype", choices=("fp32", "bf16"), default="fp32",
                    help="compute dtype of the forward/backward activations; "
                         "bf16 trains through the mixed-precision recipe "
                         "(fp32 masters + dynamic loss scaling, DESIGN.md "
                         "§12)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run (caps steps/batch/hw)")
    ap.add_argument("--naive", action="store_true",
                    help="run the zero-laden baseline (no decomposition; "
                         "xla backend only)")
    args = ap.parse_args()
    decomposed = not args.naive
    if args.naive and args.backend == "pallas":
        ap.error("--naive has no pallas kernels; use --backend xla")
    if args.smoke:
        args.steps = min(args.steps, 3)
        args.batch = min(args.batch, 1)
        args.hw = min(args.hw, 16)
        args.log_every = 1

    params = enet.init_params(jax.random.PRNGKey(0), args.classes)
    pipe = SegDataPipeline(args.batch, hw=args.hw, classes=args.classes)

    if args.dtype == "bf16":
        # the mixed-precision recipe owns the optimizer + loss scaling
        state = train_recipes.init_state(params)
        recipe_step = train_recipes.make_train_step(
            "enet", backend=args.backend, decomposed=decomposed,
            compute_dtype="bf16", lr=args.lr, weight_decay=1e-4)

        def train_step(params, opt, image, label, lr):
            nonlocal state
            state = state._replace(params=params, opt=opt)
            state, m = recipe_step(state,
                                   {"image": image, "label": label})
            return state.params, state.opt, m["loss"], m["grad_norm"]

        opt = state.opt
    else:
        opt = adamw_init(params)

        @jax.jit
        def train_step(params, opt, image, label, lr):
            def loss_fn(p):
                logits = enet.forward(p, image, decomposed=decomposed,
                                      backend=args.backend)
                logp = jax.nn.log_softmax(logits.astype(jnp.float32),
                                          axis=-1)
                nll = -jnp.take_along_axis(logp, label[..., None], axis=-1)
                return jnp.mean(nll)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt, gnorm = adamw_update(grads, opt, params, lr=lr,
                                              weight_decay=1e-4)
            return params, opt, loss, gnorm

    losses = []
    for step in range(args.steps):
        b = pipe.batch_at(step)
        lr = cosine_schedule(jnp.int32(step), args.steps // 10, args.steps,
                             args.lr)
        t0 = time.time()
        params, opt, loss, gnorm = train_step(
            params, opt, jnp.asarray(b["image"]), jnp.asarray(b["label"]), lr)
        losses.append(float(loss))
        if step % args.log_every == 0:
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.3f} dt {(time.time()-t0)*1e3:.0f}ms",
                  flush=True)
        if not np.isfinite(losses[-1]):
            raise SystemExit(f"non-finite loss at step {step}")

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss: first10={first:.4f} last10={last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    # pixel accuracy on a fresh batch
    b = pipe.batch_at(10_000)
    cd = "bf16" if args.dtype == "bf16" else None
    pred = jnp.argmax(enet.forward(params, jnp.asarray(b["image"]),
                                   decomposed=decomposed,
                                   backend=args.backend,
                                   compute_dtype=cd), -1)
    acc = float(jnp.mean(pred == jnp.asarray(b["label"])))
    print(f"pixel accuracy on held-out batch: {acc:.3f} "
          f"(chance = {1.0 / args.classes:.3f})")


if __name__ == "__main__":
    main()
