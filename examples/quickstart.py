"""Quickstart: the paper's decomposition as a library, in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dilated, transposed
from repro.core.decompose import conv2d

key = jax.random.PRNGKey(0)
k1, k2 = jax.random.split(key)

# --- dilated convolution: input decomposition (paper §II-B) ---------------
x = jax.random.normal(k1, (1, 64, 64, 8))      # NHWC
w = jax.random.normal(k2, (3, 3, 8, 16))       # compact 3x3 kernel, HWIO
D = 7                                          # paper's "L3" layer: d = 8

naive = dilated.dilated_conv2d_naive(x, w, D + 1)        # zero-laden kernel
fast = dilated.dilated_conv2d_decomposed(x, w, D + 1)    # the paper's method
np.testing.assert_allclose(np.asarray(naive), np.asarray(fast),
                           rtol=1e-4, atol=1e-4)
skip = dilated.macs_dense(64, 64, 8, 16, 3, D + 1) / \
    dilated.macs_decomposed(64, 64, 8, 16, 3, D + 1)
print(f"dilated D={D}: exact output, {skip:.0f}x fewer MACs issued")

# --- transposed convolution: weight decomposition (paper §II-C) -----------
xt = jax.random.normal(k1, (1, 32, 32, 8))
wt = jax.random.normal(k2, (3, 3, 8, 8))
up_naive = transposed.transposed_conv2d_naive(xt, wt, 2, 1, 1)
up_fast = transposed.transposed_conv2d_decomposed(xt, wt, 2, 1, 1)
np.testing.assert_allclose(np.asarray(up_naive), np.asarray(up_fast),
                           rtol=1e-4, atol=1e-4)
print(f"transposed s=2: exact {xt.shape[1]}x{xt.shape[2]} -> "
      f"{up_fast.shape[1]}x{up_fast.shape[2]} upsample, ~4x fewer MACs")

# --- unified API (what the model zoo calls) -------------------------------
y = conv2d(x, w, dilation=8)                   # decomposed dilated
z = conv2d(xt, wt, stride=2, transposed=True, output_padding=1)
print(f"unified conv2d: dilated {y.shape}, transposed {z.shape}")

# --- the accelerator model: paper Fig. 10 headline ------------------------
from repro.core import cycle_model as cm
from repro.core.enet_spec import enet_512_layers

rep = cm.report(enet_512_layers())
print(f"ENet@512x512 on the modeled 168-MAC array: "
      f"{rep['cycle_reduction_pct']:.1f}% cycles removed, "
      f"{rep['overall_speedup']:.1f}x speedup (paper: 87.8%, 8.2x)")

# --- where the weight decomposition matters most ---------------------------
# generative decoders (DCGAN generators, diffusion U-Net decoder) are
# transposed-conv-dominated — run examples/generate_dcgan.py for the
# end-to-end demo and the naive-vs-decomposed cycle table
from repro.core.gen_spec import dcgan_layers

rg = cm.report(dcgan_layers(64))
print(f"DCGAN@64x64 (examples/generate_dcgan.py): "
      f"{rg['share_transposed_pct']:.0f}% transposed cycles, "
      f"{rg['speedup_vs_naive']:.1f}x vs the naive array schedule")
