"""Serve diffusion sampling requests through the batched generative server.

Drives :class:`repro.launch.serve_gen.GenServer` — the continuous-batching
DDIM loop over the U-Net decoder denoiser (DESIGN.md §9) — with a queue of
requests at *mixed* step budgets, then checks the served output of one
request against an unbatched reference sampling loop (the issue's 1e-5
parity bar: mixed-timestep batching must not change any request's result),
and prints the cycle-model steady-state serving table (decomposed vs naive
array schedule) for the generative workloads.

  PYTHONPATH=src python examples/sample_diffusion.py
  PYTHONPATH=src python examples/sample_diffusion.py --backend pallas --smoke
  PYTHONPATH=src python examples/sample_diffusion.py --smoke   # CI widths
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core import cycle_model as cm
from repro.core.gen_spec import GEN_WORKLOADS
from repro.launch.serve_gen import GenServer, reference_sample
from repro.models import unet_decoder


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--steps", default="8,5,3",
                    help="comma list of DDIM step budgets, cycled")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--backend", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny widths + short trajectories (CI)")
    ns = ap.parse_args(argv)
    if ns.smoke:
        ns.requests, ns.steps = min(ns.requests, 5), "4,2,3"

    # interpret-mode pallas on CPU needs tiny widths to stay tractable —
    # same gate as examples/generate_dcgan.py
    small = ns.smoke or (ns.backend == "pallas"
                         and jax.default_backend() == "cpu")
    widths, hw = ((8, 8), 4) if small else ((32, 16, 8), 4)
    step_list = [int(s) for s in ns.steps.split(",")]

    params = unet_decoder.init_denoiser_params(
        jax.random.PRNGKey(ns.seed), widths=widths)
    server = GenServer(batch=ns.batch, backend=ns.backend,
                       unet_widths=widths, unet_hw=hw,
                       params={"unet_dec": params})
    reqs = {}
    for i in range(ns.requests):
        steps = step_list[i % len(step_list)]
        reqs[server.submit("unet_dec", steps=steps, seed=ns.seed + i)] = steps
    images = server.run()
    st = server.stats()
    size = hw * 2 ** len(widths)
    print(f"served {st['requests']:.0f} requests (steps "
          f"{sorted(set(reqs.values()))}) on backend={ns.backend}: "
          f"{size}x{size} images, {st['ticks']:.0f} ticks / "
          f"{st['device_steps']:.0f} device steps, "
          f"{st['images_per_s']:.2f} img/s, mean queue wait "
          f"{st['mean_wait_ticks']:.1f} ticks")

    # parity: the request with the LONGEST trajectory lived alongside the
    # most churn (neighbours completed and were replaced mid-flight), so it
    # is the strongest witness that mixed-timestep batching is lossless
    rid = max(reqs, key=lambda r: reqs[r])
    ref = reference_sample(params, steps=reqs[rid], seed=ns.seed + rid,
                           image_size=size, backend=ns.backend)
    dev = float(np.abs(images[rid] - ref).max())
    print(f"max deviation served-vs-unbatched reference "
          f"(request {rid}, {reqs[rid]} steps): {dev:.2e} (bar: 1e-5)")
    assert dev <= 1e-5, dev

    print("\n== cycle model: steady-state serving on the paper's array "
          "(decomposed vs naive) ==")
    hdr = (f"{'workload':<10} {'steps':>5} {'img/s ours':>11} "
           f"{'img/s naive':>12} {'speedup':>8} {'latency ms':>11}")
    print(hdr + "\n" + "-" * len(hdr))
    for name, fn in GEN_WORKLOADS.items():
        steps = 25 if name == "unet_dec" else 1
        rep = cm.serve_report(fn(), steps=steps, batch=ns.batch)
        print(f"{name:<10} {steps:>5} {rep['images_per_s_ours']:>11.1f} "
              f"{rep['images_per_s_naive']:>12.1f} "
              f"{rep['serve_speedup_vs_naive']:>7.2f}x "
              f"{rep['latency_ms_ours']:>11.1f}")


if __name__ == "__main__":
    main()
