"""Generate images with the DCGAN generator on the decomposition engine.

Runs a (randomly initialised or checkpointed) DCGAN-style generator — a
chain of k=4/s=2 transposed convolutions, the workload the paper's weight
decomposition exists for — end-to-end, and prints the cycle-model
naive-vs-decomposed table for the generative workloads (DCGAN 64/128,
diffusion U-Net decoder).  Cross-backend parity (xla vs the fused pallas
kernels, 1e-5 bar) is checked whenever it is tractable: always with
``--smoke``/``--ngf 16``, and at any width on a compiled accelerator
backend; full canonical width on CPU skips it (interpret-mode pallas).

  PYTHONPATH=src python examples/generate_dcgan.py
  PYTHONPATH=src python examples/generate_dcgan.py --size 128 --backend pallas
  PYTHONPATH=src python examples/generate_dcgan.py --smoke   # CI: tiny ngf
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cycle_model as cm
from repro.core.gen_spec import GEN_WORKLOADS
from repro.models import dcgan


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", type=int, default=64, choices=(64, 128))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--nz", type=int, default=100)
    ap.add_argument("--ngf", type=int, default=64,
                    help="width multiplier (canonical DCGAN: 64)")
    ap.add_argument("--backend", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny widths + parity check only (CI)")
    ns = ap.parse_args(argv)
    if ns.smoke:
        ns.ngf, ns.nz, ns.batch = 4, 16, 2

    # pallas on CPU is interpret mode: tractable at demo widths, ~hours at
    # the canonical ngf=64 — refuse the hang up front (also gates the
    # cross-backend parity check below)
    pallas_ok = ns.ngf <= 16 or jax.default_backend() != "cpu"
    if ns.backend == "pallas" and not pallas_ok:
        ap.error("backend=pallas at full width runs interpret mode on CPU "
                 "(~hours); rerun with --smoke / --ngf 16, or on an "
                 "accelerator backend")

    key = jax.random.PRNGKey(ns.seed)
    params = dcgan.init_params(key, size=ns.size, nz=ns.nz, ngf=ns.ngf)
    z = jax.random.normal(jax.random.PRNGKey(ns.seed + 1), (ns.batch, ns.nz))

    imgs = np.asarray(dcgan.forward(params, z, backend=ns.backend))
    print(f"generated {imgs.shape} on backend={ns.backend} "
          f"(range [{imgs.min():+.3f}, {imgs.max():+.3f}], tanh-bounded)")

    # cross-backend parity: the fused parity-plane kernels against the XLA
    # reference (the issue's acceptance bar is 1e-5 in fp32); gated by the
    # same interpret-mode tractability check as above.
    if pallas_ok:
        other = "pallas" if ns.backend == "xla" else "xla"
        dev = float(jnp.abs(dcgan.forward(params, z, backend=other)
                            - jnp.asarray(imgs)).max())
        print(f"max deviation vs backend={other}: {dev:.2e} (bar: 1e-5)")
        assert dev <= 1e-5, dev
    else:
        print("skipping cross-backend parity at full width on CPU "
              "(interpret-mode pallas; rerun with --smoke or --ngf 16)")

    print("\n== cycle model: generative decoder workloads "
          "(naive array schedule vs decomposed) ==")
    hdr = f"{'workload':<10} {'naive Mcyc':>11} {'ours Mcyc':>10} " \
          f"{'speedup':>8} {'cut %':>6} {'tconv %':>8}"
    print(hdr + "\n" + "-" * len(hdr))
    for name, fn in GEN_WORKLOADS.items():
        rep = cm.report(fn())
        print(f"{name:<10} {rep['naive_cycles'] / 1e6:>11.1f} "
              f"{rep['our_cycles'] / 1e6:>10.1f} "
              f"{rep['speedup_vs_naive']:>7.2f}x "
              f"{rep['cycle_reduction_vs_naive_pct']:>6.1f} "
              f"{rep['share_transposed_pct']:>8.1f}")
    print("\n(EcoFlow's point, reproduced: the weight decomposition covers "
          ">99% of a generator's\n cycles, vs ~5% of ENet's — the whole net "
          "runs at the transposed-class speedup.)")


if __name__ == "__main__":
    main()
