"""Serve a small LM with batched requests through the production serve path
(prefill -> KV-cached decode), on any of the 10 assigned architectures.

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-12b
(reduced config on CPU; --full would use the published size.)
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.launch.serve import Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=ARCH_IDS)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    if cfg.encoder_layers:
        raise SystemExit("use whisper example for enc-dec serving")
    print(f"[serve_lm] {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"pattern={cfg.block_pattern}")
    server = Server(cfg, batch=args.batch,
                    max_len=args.prompt_len + args.gen_len + 1)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    t0 = time.time()
    out = server.generate(prompts, args.gen_len)
    dt = time.time() - t0
    print(f"[serve_lm] {out.shape[0]} requests x {out.shape[1]} new tokens "
          f"in {dt:.2f}s ({out.size / dt:.1f} tok/s)")
    print("[serve_lm] greedy decode is deterministic:", out[:, :6].tolist())


if __name__ == "__main__":
    main()
