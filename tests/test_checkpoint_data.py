"""Checkpoint roundtrip/atomicity/GC + data-pipeline determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, load_extra, load_flat,
                              restore_checkpoint, save_checkpoint)
from repro.checkpoint.ckpt import all_steps
from repro.data import LMDataPipeline, SegDataPipeline


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((2,), jnp.bfloat16)},
            "step_scalar": jnp.int32(7)}


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    abstract = jax.eval_shape(lambda: tree)
    restored = restore_checkpoint(str(tmp_path), 5, abstract)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_background_save_and_gc(tmp_path):
    tree = _tree()
    threads = [save_checkpoint(str(tmp_path), s, tree, keep=2,
                               background=True) for s in (1, 2, 3)]
    for t in threads:
        t.join()
    # keep=2: only the newest two survive
    assert all_steps(str(tmp_path))[-1] == 3
    assert len(all_steps(str(tmp_path))) <= 2


def test_uncommitted_checkpoint_ignored(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 5, tree)
    # simulate a crash mid-write: step dir without COMMITTED marker
    os.makedirs(tmp_path / "step_000009")
    assert latest_step(str(tmp_path)) == 5


def test_restore_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    bad = jax.eval_shape(lambda: {"w": jnp.zeros((3, 3))})
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(str(tmp_path), 1, bad)


def test_background_save_failure_surfaces_on_join(tmp_path, monkeypatch):
    """Regression: the background-save thread used to print a failed
    serialization to stderr and drop it — the step silently never landed.
    The returned future must re-raise on join(), and the failed step must
    not look committed."""
    import repro.checkpoint.ckpt as ckpt_mod

    def _boom(*a, **k):
        raise OSError("disk full (doctored)")

    monkeypatch.setattr(ckpt_mod.np, "savez", _boom)
    fut = save_checkpoint(str(tmp_path), 7, _tree(), background=True)
    with pytest.raises(OSError, match="disk full"):
        fut.join()
    assert not fut.is_alive()
    assert latest_step(str(tmp_path)) is None       # nothing committed


def test_flat_dict_roundtrip_with_extra(tmp_path):
    """The serving layer's snapshot transport: a flat {name: array} dict
    plus a JSON extra payload round-trips without an abstract tree."""
    flat = {"lane:unet:x": np.arange(6, dtype=np.float32).reshape(2, 3),
            "done:00000001": np.ones((4,), np.float32)}
    extra = {"tick": 9, "pending": [{"rid": 2}]}
    save_checkpoint(str(tmp_path), 9, flat, extra=extra)
    arrays, got_extra = load_flat(str(tmp_path), 9)
    assert got_extra == extra
    assert load_extra(str(tmp_path), 9) == extra
    assert sorted(arrays) == sorted(flat)
    for k in flat:
        np.testing.assert_array_equal(arrays[k], flat[k])


def test_load_flat_rejects_tree_checkpoints(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())      # nested: not flat
    with pytest.raises(ValueError, match="flat"):
        load_flat(str(tmp_path), 1)


def test_lm_pipeline_deterministic_and_restartable():
    p1 = LMDataPipeline(4, 16, 100, seed=3, process_index=0, process_count=1)
    s0, b0 = next(p1)
    s1, b1 = next(p1)
    assert (s0, s1) == (0, 1)
    p1.seek(1)
    s1b, b1b = next(p1)
    assert s1b == 1
    np.testing.assert_array_equal(b1["tokens"], b1b["tokens"])
    # pure function of step
    np.testing.assert_array_equal(p1.batch_at(1)["tokens"], b1["tokens"])
    p1.close()


def test_lm_pipeline_host_sharding():
    full = LMDataPipeline(8, 4, 50, process_index=0, process_count=1)
    h0 = LMDataPipeline(8, 4, 50, process_index=0, process_count=2)
    h1 = LMDataPipeline(8, 4, 50, process_index=1, process_count=2)
    assert h0.local_batch == h1.local_batch == 4
    # different hosts produce different (independent) shards
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])
    for p in (full, h0, h1):
        p.close()


def test_seg_pipeline():
    p = SegDataPipeline(2, hw=64, classes=5)
    b = p.batch_at(0)
    assert b["image"].shape == (2, 64, 64, 3)
    assert b["label"].shape == (2, 64, 64)
    assert b["label"].max() < 5
    np.testing.assert_array_equal(b["label"], p.batch_at(0)["label"])
