"""Sharded ENet train-step parity on the simulated mesh (DESIGN.md §13).

The acceptance bar of the sharding issue: a 3-step sharded ENet run on the
8-device CPU mesh is BITWISE identical to the 1-device run — same params,
same losses — because (a) the batch is pre-chunked into mesh-independent
virtual shards, (b) per-chunk gradients come from ONE compiled per-chunk
graph (``lax.map``, not a width-dependent vmap), and (c) the cross-device
reduction is an all-gather plus fixed-order sum (``mesh_allreduce``), never
a mesh-shaped psum tree.

The bf16 wire transport halves the collective operand and is held to a
loss-level convergence bound instead (its params legitimately drift: AdamW
divides by rounding-scale gradient moments).

Everything here shares one module-scoped fixture — each mesh config costs a
full ENet fwd+bwd compile, so runs are computed once and asserted many
times.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import train_recipes as tr
from repro.launch.mesh import make_train_mesh
from repro.models import enet

_B, _HW, _NC = 8, 16, 4
_STEPS = 3


def _batch():
    rng = np.random.default_rng(0)
    return {
        "image": jnp.asarray(rng.normal(size=(_B, _HW, _HW, 3)),
                             jnp.float32),
        "label": jnp.asarray(rng.integers(0, _NC, (_B, _HW, _HW)),
                             jnp.int32),
    }


def _init_state():
    params = enet.init_params(jax.random.PRNGKey(0), num_classes=_NC)
    return tr.init_state(params)


def _run_sharded(nd, transport):
    mesh = make_train_mesh(nd)
    step = tr.make_sharded_train_step("enet", mesh, grad_transport=transport)
    state = tr.place_state(mesh, _init_state())
    chunks = tr.shard_batch(mesh, _batch())
    losses = []
    for _ in range(_STEPS):
        state, metrics = step(state, chunks)
        losses.append(float(metrics["loss"]))
        assert float(metrics["skipped"]) == 0.0
    return jax.device_get(state.params), losses


@pytest.fixture(scope="module")
def runs(mesh_devices):
    if mesh_devices < 8:
        pytest.skip(f"mesh parity fixture wants 8 devices, have "
                    f"{mesh_devices}")
    return {
        (1, "dense"): _run_sharded(1, "dense"),
        (8, "dense"): _run_sharded(8, "dense"),
        (8, "bf16"): _run_sharded(8, "bf16"),
    }


@pytest.mark.mesh
def test_enet_sharded_step_bitwise_1_vs_8(runs):
    p1, l1 = runs[(1, "dense")]
    p8, l8 = runs[(8, "dense")]
    assert l1 == l8                      # float-exact loss trace
    leaves1 = jax.tree_util.tree_leaves(p1)
    leaves8 = jax.tree_util.tree_leaves(p8)
    assert len(leaves1) == len(leaves8)
    for a, b in zip(leaves1, leaves8):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.mesh
def test_bf16_transport_loss_convergence_bound(runs):
    _, dense = runs[(8, "dense")]
    _, bf16 = runs[(8, "bf16")]
    # the wire cast rounds gradients, not the loss: each step's objective
    # must track the dense run tightly even as params drift
    for ld, lb in zip(dense, bf16):
        assert abs(ld - lb) <= 5e-3 * max(abs(ld), 1.0), (dense, bf16)
    assert bf16[-1] < bf16[0]            # and it still trains


@pytest.mark.mesh
def test_unsharded_step_agrees_on_loss(runs):
    """The sharded chunk-mean-of-means equals the plain batch mean up to
    reassociation — the single-graph recipe step must see the same first
    loss to float tolerance."""
    step = tr.make_train_step("enet")
    state, metrics = step(_init_state(), _batch())
    _, losses = runs[(1, "dense")]
    np.testing.assert_allclose(float(metrics["loss"]), losses[0],
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------- plumbing guards ---

def test_shard_batch_validation(mesh_devices):
    mesh = make_train_mesh(min(4, mesh_devices))
    with pytest.raises(ValueError, match="virtual_shards"):
        tr.shard_batch(mesh, _batch(), virtual_shards=6)
    with pytest.raises(ValueError, match="not divisible"):
        tr.shard_batch(mesh, {"image": jnp.zeros((6, 4, 4, 3))},
                       virtual_shards=4)
    chunks = tr.shard_batch(mesh, _batch(), virtual_shards=8)
    assert chunks["image"].shape == (8, _B // 8, _HW, _HW, 3)
    assert chunks["label"].shape == (8, _B // 8, _HW, _HW)


def test_sharded_step_rejects_pallas():
    mesh = make_train_mesh(1)
    with pytest.raises(ValueError, match="xla"):
        tr.make_sharded_train_step("enet", mesh, backend="pallas")
