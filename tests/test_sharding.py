"""Sharding-rule resolution (pure logic — no multi-device requirement) and a
subprocess 8-device lower/compile check."""

import json
import subprocess
import sys
import textwrap

import pytest


# resolve_spec needs a Mesh only for .shape: use a lightweight stand-in.
class _FakeMesh:
    def __init__(self, **axes):
        self.shape = axes


from repro.distributed.sharding import param_pspec, resolve_spec  # noqa: E402


def P(*args):
    from jax.sharding import PartitionSpec
    return PartitionSpec(*args)


MESH = _FakeMesh(data=16, model=16)
MESH_POD = _FakeMesh(pod=2, data=16, model=16)


def test_activation_batch_sharding():
    spec = resolve_spec(MESH_POD, ("data", None, None), (256, 4096, 5120))
    assert spec == P(("pod", "data"), None, None)


def test_divisibility_guard_drops():
    # batch 1 cannot shard over data -> dropped
    spec = resolve_spec(MESH, ("data", None), (1, 64))
    assert spec == P(None, None)


def test_image_sharding_spec_resolution():
    """Generative-serving NHWC state (launch.serve_gen): batch over data,
    spatial height over model only when requested AND divisible."""
    spec = resolve_spec(MESH, ("data", "spatial", None, None),
                        (32, 64, 64, 3))
    assert spec == P("data", "model", None, None)
    # smoke batch of 4 with 16-way data axis -> batch axis dropped; 15 rows
    # don't divide the model axis -> spatial dropped too
    spec = resolve_spec(MESH, ("data", "spatial", None, None),
                        (4, 15, 15, 3))
    assert spec == P(None, None, None, None)


def test_image_sharding_on_real_mesh():
    import jax

    from repro.distributed.sharding import image_sharding
    from repro.launch.mesh import make_smoke_mesh

    mesh = make_smoke_mesh()
    sh = image_sharding(mesh, (4, 16, 16, 3), spatial=True)
    x = jax.device_put(jax.numpy.zeros((4, 16, 16, 3)), sh)
    assert x.shape == (4, 16, 16, 3)


def test_axis_reuse_guard():
    # both dims want the model axis; only the first gets it
    spec = resolve_spec(MESH, ("model", "expert"), (64, 128))
    assert spec == P("model", None)


def test_kvseq_widens_for_batch1():
    # long-context decode: batch 1 -> sequence takes every axis
    spec = resolve_spec(MESH_POD, ("data_kvseq", "kvseq", "model_kv", None),
                        (1, 524288, 8, 256))
    assert spec == P(None, ("pod", "data", "model"), None, None)


def test_kvseq_model_only_when_batch_sharded():
    spec = resolve_spec(MESH_POD, ("data_kvseq", "kvseq", "model_kv", None),
                        (128, 32768, 8, 128))
    assert spec == P(("pod", "data"), ("model",)[0], None, None)


def test_param_rules():
    assert param_pspec(MESH, "blocks/0/mixer/wq", (64, 2048, 8192)) == \
        P(None, "data", "model")
    assert param_pspec(MESH, "blocks/0/mixer/wo", (64, 8192, 2048)) == \
        P(None, "model", "data")
    assert param_pspec(MESH, "embed", (151936, 5120)) == P("model", "data")
    assert param_pspec(MESH, "blocks/0/ffn/we_gate", (64, 16, 8192, 768)) == \
        P(None, "model", "data", None)  # expert dim -> model (EP)
    assert param_pspec(MESH, "blocks/0/norm1", (64, 5120)) == P()
    assert param_pspec(MESH, "blocks/0/ffn/router", (5120, 128)) == P()


def test_moe_dense_ffn_rules_distinct():
    # dense-FFN w_gate vs expert-stacked we_gate must get different rules
    assert param_pspec(MESH, "blocks/1/ffn/w_gate", (24, 2048, 5632)) == \
        P(None, "data", "model")
    assert param_pspec(MESH, "blocks/1/ffn/we_down", (24, 16, 768, 2048)) == \
        P(None, "model", None, "data")


# --------------------------------------------------------------------------
# Multi-device conv parity grid (DESIGN.md §13) — runs in-process on the
# simulated 8-device CPU mesh (the opt-in XLA_FLAGS fake-device session,
# see conftest.py).  Forward sharding is GSPMD over the
# batch (plus the decomposed phase/parity fold) and must be BITWISE equal to
# the single-device result; gradients recompose through different fusion
# boundaries, so they are held to allclose.
# --------------------------------------------------------------------------

import numpy as np  # noqa: E402

#: the three engine kinds of the paper's decomposition, with uneven extents
#: (B=5, H=13 divide none of the mesh sizes — the pad_batch remainder path)
_ENGINES = {
    "dense": dict(dilation=1),
    "dilated": dict(dilation=2),
    "tconv": dict(transposed=True, stride=2),
}


def _conv_case(kind):
    import jax
    import jax.numpy as jnp

    kx, kw = jax.random.split(jax.random.PRNGKey(11))
    x = jax.random.normal(kx, (5, 13, 13, 3), jnp.float32)
    w = jax.random.normal(kw, (3, 3, 3, 4), jnp.float32)
    return x, w, dict(_ENGINES[kind])


@pytest.mark.mesh
@pytest.mark.parametrize("nd", [1, 2, 4, 8])
@pytest.mark.parametrize("kind", sorted(_ENGINES))
def test_shard_conv2d_parity_grid(kind, nd, mesh_devices):
    import jax
    import jax.numpy as jnp

    from repro.core.decompose import conv2d
    from repro.distributed.sharding import shard_conv2d
    from repro.launch.mesh import make_train_mesh

    if nd > mesh_devices:
        pytest.skip(f"need {nd} devices, have {mesh_devices}")
    x, w, kw = _conv_case(kind)
    mesh = make_train_mesh(nd)

    ref = conv2d(x, w, **kw)
    y, dx, dw = shard_conv2d(mesh, x, w, with_grads=True, **kw)
    assert np.array_equal(np.asarray(y), np.asarray(ref)), kind

    ry, vjp = jax.vjp(lambda xx, ww: conv2d(xx, ww, **kw), x, w)
    rdx, rdw = vjp(jnp.ones_like(ry))
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rdx),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(rdw),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.mesh
def test_shard_conv2d_spatial_dilated(mesh_devices):
    """Spatial (H) sharding on top of the batch axis: the dilated phase
    fold subdivides H by the dilation, so the halo-free phase view must
    still match the single-device result bitwise."""
    from repro.core.decompose import conv2d
    from repro.distributed.sharding import shard_conv2d
    from repro.launch.mesh import make_smoke_mesh

    x, w, kw = _conv_case("dilated")
    mesh = make_smoke_mesh(min(4, mesh_devices))
    y = shard_conv2d(mesh, x, w, spatial=True, **kw)
    assert np.array_equal(np.asarray(y), np.asarray(conv2d(x, w, **kw)))


@pytest.mark.slow
def test_small_mesh_lower_and_compile():
    """Subprocess with 8 fake devices: reduced arch lowers + compiles with
    collectives on both step kinds."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        from repro.configs import get_reduced
        from repro.launch.steps import lower_cell
        import repro.launch.shapes as shapes
        from repro.distributed import hlo_analysis as ha

        shapes.SHAPES["t"] = shapes.ShapeCell("t", 64, 8, "train")
        shapes.SHAPES["d"] = shapes.ShapeCell("d", 64, 8, "decode")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        out = {}
        for cell in ("t", "d"):
            lowered, _ = lower_cell(get_reduced("qwen3-moe-30b-a3b"), cell,
                                    mesh)
            a = ha.analyze(lowered.compile().as_text())
            out[cell] = {"flops": a.flops,
                         "colls": sorted(a.collectives)}
        print(json.dumps(out))
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["t"]["flops"] > 0
    assert "all-reduce" in out["t"]["colls"]  # grad reduction exists
