"""Sharding-rule resolution (pure logic — no multi-device requirement) and a
subprocess 8-device lower/compile check."""

import json
import subprocess
import sys
import textwrap

import pytest


# resolve_spec needs a Mesh only for .shape: use a lightweight stand-in.
class _FakeMesh:
    def __init__(self, **axes):
        self.shape = axes


from repro.distributed.sharding import param_pspec, resolve_spec  # noqa: E402


def P(*args):
    from jax.sharding import PartitionSpec
    return PartitionSpec(*args)


MESH = _FakeMesh(data=16, model=16)
MESH_POD = _FakeMesh(pod=2, data=16, model=16)


def test_activation_batch_sharding():
    spec = resolve_spec(MESH_POD, ("data", None, None), (256, 4096, 5120))
    assert spec == P(("pod", "data"), None, None)


def test_divisibility_guard_drops():
    # batch 1 cannot shard over data -> dropped
    spec = resolve_spec(MESH, ("data", None), (1, 64))
    assert spec == P(None, None)


def test_image_sharding_spec_resolution():
    """Generative-serving NHWC state (launch.serve_gen): batch over data,
    spatial height over model only when requested AND divisible."""
    spec = resolve_spec(MESH, ("data", "spatial", None, None),
                        (32, 64, 64, 3))
    assert spec == P("data", "model", None, None)
    # smoke batch of 4 with 16-way data axis -> batch axis dropped; 15 rows
    # don't divide the model axis -> spatial dropped too
    spec = resolve_spec(MESH, ("data", "spatial", None, None),
                        (4, 15, 15, 3))
    assert spec == P(None, None, None, None)


def test_image_sharding_on_real_mesh():
    import jax

    from repro.distributed.sharding import image_sharding
    from repro.launch.mesh import make_smoke_mesh

    mesh = make_smoke_mesh()
    sh = image_sharding(mesh, (4, 16, 16, 3), spatial=True)
    x = jax.device_put(jax.numpy.zeros((4, 16, 16, 3)), sh)
    assert x.shape == (4, 16, 16, 3)


def test_axis_reuse_guard():
    # both dims want the model axis; only the first gets it
    spec = resolve_spec(MESH, ("model", "expert"), (64, 128))
    assert spec == P("model", None)


def test_kvseq_widens_for_batch1():
    # long-context decode: batch 1 -> sequence takes every axis
    spec = resolve_spec(MESH_POD, ("data_kvseq", "kvseq", "model_kv", None),
                        (1, 524288, 8, 256))
    assert spec == P(None, ("pod", "data", "model"), None, None)


def test_kvseq_model_only_when_batch_sharded():
    spec = resolve_spec(MESH_POD, ("data_kvseq", "kvseq", "model_kv", None),
                        (128, 32768, 8, 128))
    assert spec == P(("pod", "data"), ("model",)[0], None, None)


def test_param_rules():
    assert param_pspec(MESH, "blocks/0/mixer/wq", (64, 2048, 8192)) == \
        P(None, "data", "model")
    assert param_pspec(MESH, "blocks/0/mixer/wo", (64, 8192, 2048)) == \
        P(None, "model", "data")
    assert param_pspec(MESH, "embed", (151936, 5120)) == P("model", "data")
    assert param_pspec(MESH, "blocks/0/ffn/we_gate", (64, 16, 8192, 768)) == \
        P(None, "model", "data", None)  # expert dim -> model (EP)
    assert param_pspec(MESH, "blocks/0/norm1", (64, 5120)) == P()
    assert param_pspec(MESH, "blocks/0/ffn/router", (5120, 128)) == P()


def test_moe_dense_ffn_rules_distinct():
    # dense-FFN w_gate vs expert-stacked we_gate must get different rules
    assert param_pspec(MESH, "blocks/1/ffn/w_gate", (24, 2048, 5632)) == \
        P(None, "data", "model")
    assert param_pspec(MESH, "blocks/1/ffn/we_down", (24, 16, 768, 2048)) == \
        P(None, "model", None, "data")


@pytest.mark.slow
def test_small_mesh_lower_and_compile():
    """Subprocess with 8 fake devices: reduced arch lowers + compiles with
    collectives on both step kinds."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        from repro.configs import get_reduced
        from repro.launch.steps import lower_cell
        import repro.launch.shapes as shapes
        from repro.distributed import hlo_analysis as ha

        shapes.SHAPES["t"] = shapes.ShapeCell("t", 64, 8, "train")
        shapes.SHAPES["d"] = shapes.ShapeCell("d", 64, 8, "decode")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        out = {}
        for cell in ("t", "d"):
            lowered, _ = lower_cell(get_reduced("qwen3-moe-30b-a3b"), cell,
                                    mesh)
            a = ha.analyze(lowered.compile().as_text())
            out[cell] = {"flops": a.flops,
                         "colls": sorted(a.collectives)}
        print(json.dumps(out))
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["t"]["flops"] > 0
    assert "all-reduce" in out["t"]["colls"]  # grad reduction exists
