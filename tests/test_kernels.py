"""Per-Pallas-kernel validation: shape/dtype sweeps vs pure-jnp oracles.

All kernels run in interpret mode (CPU executes the kernel body; BlockSpec
tiling and grid semantics are fully exercised).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ops, ref


def _pair(key, xshape, wshape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    return (jax.random.normal(k1, xshape, dtype),
            jax.random.normal(k2, wshape, dtype))


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- conv2d ---

CONV_CASES = [
    # (n, h, w, cin, cout, k, stride, padding)
    (2, 16, 16, 8, 16, 3, 1, "SAME"),
    (1, 17, 13, 3, 5, 3, 1, "SAME"),
    (1, 16, 16, 4, 8, 2, 2, "VALID"),
    (2, 32, 32, 8, 13, 3, 2, "SAME"),
    (1, 8, 8, 16, 32, 1, 1, "SAME"),
    (1, 12, 20, 3, 7, 5, 1, "SAME"),
]


@pytest.mark.parametrize("case", CONV_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv2d_kernel(case, dtype):
    n, h, w, cin, cout, k, s, pad = case
    x, wt = _pair(n * h + w, (n, h, w, cin), (k, k, cin, cout), dtype)
    got = ops.conv2d(x, wt, stride=s, padding=pad)
    want = ref.conv2d_ref(x, wt, stride=s, padding=pad)
    assert got.shape == want.shape
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    **_tol(dtype))


@pytest.mark.parametrize("ks", [(5, 1), (1, 5), (2, 3), (4, 1)])
@pytest.mark.parametrize("stride", [1, 2])
def test_conv2d_kernel_rectangular(ks, stride):
    """Rectangular kernels (ENet's 5x1/1x5 asymmetric pair) are first-class:
    per-dim SAME pads, per-dim tap loops, per-dim halo."""
    kh, kw = ks
    x, wt = _pair(kh * 7 + kw, (1, 14, 11, 3), (kh, kw, 3, 5), jnp.float32)
    got = ops.conv2d(x, wt, stride=stride, padding="SAME")
    want = ref.conv2d_ref(x, wt, stride=stride, padding="SAME")
    assert got.shape == want.shape
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# --------------------------------------------------------- dilated conv ---

@pytest.mark.parametrize("dilation", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dilated_kernel(dilation, dtype):
    x, wt = _pair(dilation, (1, 24, 20, 6), (3, 3, 6, 10), dtype)
    got = ops.dilated_conv2d(x, wt, dilation)
    want = ref.dilated_conv2d_ref(x, wt, dilation)
    assert got.shape == want.shape == (1, 24, 20, 10)
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    **_tol(dtype))


def test_dilated_kernel_enet_shapes():
    """The actual ENet translation-stage shapes (64x64, 32ch, D=1,3,7,15)."""
    for D in [1, 3, 7, 15]:
        x, wt = _pair(D, (1, 64, 64, 8), (3, 3, 8, 8), jnp.float32)
        got = ops.dilated_conv2d(x, wt, D + 1)
        want = ref.dilated_conv2d_ref(x, wt, D + 1)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


# ------------------------------------------------------ transposed conv ---

@pytest.mark.parametrize("hw", [(4, 4), (8, 8), (13, 7), (16, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_transposed_kernel(hw, dtype):
    h, w = hw
    x, wt = _pair(h * w, (2, h, w, 6), (3, 3, 6, 9), dtype)
    got = ops.transposed_conv2d(x, wt, stride=2)
    want = ref.transposed_conv2d_ref(x, wt, stride=2, padding=1,
                                     output_padding=1)
    assert got.shape == want.shape == (2, 2 * h, 2 * w, 9)
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    **_tol(dtype))


def test_transposed_kernel_matches_core_decomposition():
    """Pallas fused path == composable jnp decomposition == oracle."""
    from repro.core.transposed import transposed_conv2d_decomposed

    x, wt = _pair(0, (1, 8, 8, 4), (3, 3, 4, 4), jnp.float32)
    a = ops.transposed_conv2d(x, wt, stride=2)
    b = transposed_conv2d_decomposed(x, wt, 2, 1, 1)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("k,s", [(2, 2), (4, 2), (5, 3), (3, 4)])
def test_transposed_kernel_general_ks(k, s):
    """The fused kernel serves any (k, s) via the programmatic schedule."""
    x, wt = _pair(k * s, (1, 6, 9, 4), (k, k, 4, 6), jnp.float32)
    got = ops.transposed_conv2d(x, wt, stride=s)
    want = ref.transposed_conv2d_ref(x, wt, stride=s, padding=(k - 1) // 2,
                                     output_padding=1)
    assert got.shape == want.shape
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("d,s", [(2, 2), (3, 2), (4, 2)])
def test_dilated_kernel_strided(d, s):
    """Phase-batched Pallas path with an output stride (class schedule)."""
    from repro.core.dilated import dilated_conv2d_reference

    x, wt = _pair(d * 7 + s, (1, 18, 14, 4), (3, 3, 4, 6), jnp.float32)
    got = ops.dilated_conv2d(x, wt, d, stride=s)
    want = dilated_conv2d_reference(x, wt, d, s)
    assert got.shape == want.shape
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- matmul ---

@pytest.mark.parametrize("mnk", [(16, 16, 16), (128, 128, 128),
                                 (100, 60, 36), (256, 512, 128), (1, 128, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel(mnk, dtype):
    m, n, k = mnk
    a, b = _pair(m + n + k, (m, k), (k, n), dtype)
    got = ops.matmul(a, b)
    want = ref.matmul_ref(a, b)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    **tol)


# ------------------------------------------------------- flash attention ---

@pytest.mark.parametrize("shape", [(1, 2, 128, 64), (2, 4, 100, 32),
                                   (1, 1, 257, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(shape, causal):
    b, h, s, d = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(s), 3)
    q = jax.random.normal(k1, shape, jnp.float32)
    k = jax.random.normal(k2, shape, jnp.float32)
    v = jax.random.normal(k3, shape, jnp.float32)
    got = ops.attention(q, k, v, causal=causal)
    want = ref.attention_ref(q, k, v, causal=causal)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    shape = (1, 2, 64, 64)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, shape, jnp.bfloat16)
    k = jax.random.normal(k2, shape, jnp.bfloat16)
    v = jax.random.normal(k3, shape, jnp.bfloat16)
    got = ops.attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    rtol=3e-2, atol=3e-2)
