"""The general parity-plane decomposition engine: arbitrary (kernel, stride).

Covers the three layers the unified dispatcher routes through:

* the fused Pallas transposed-conv kernel (programmatic parity schedule),
* the strided-dilated output-class path (XLA and Pallas phase-batched),
* the generalized cycle model ((k, s) schedules; invariants vs naive).

All equivalence tests compare against the naive zero-inserted references in
``repro.core`` / ``repro.kernels.ref``.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import cycle_model as cm
from repro.core import dilated as dil
from repro.core import transposed as tr
from repro.core.decompose import conv2d
from repro.core.enet_spec import ConvLayer, enet_512_layers
from repro.kernels import ops
from repro.kernels.transposed_conv import parity_schedule


def _pair(seed, xshape, wshape, dtype=jnp.float32):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(k1, xshape, dtype),
            jax.random.normal(k2, wshape, dtype))


# ------------------------------------------------- parity schedule shape ---

def test_parity_schedule_covers_every_tap_once():
    """Each kernel tap lands in exactly one parity (paper §II-C, Fig. 6)."""
    for k in (2, 3, 4, 5):
        for s in (2, 3, 4):
            sched = parity_schedule(k, s, (k - 1) // 2)
            taps = [t for taps in sched for t, _ in taps]
            assert sorted(taps) == list(range(k))
            # sub-kernel extent is ceil(k/s) or less per parity
            assert all(len(taps) <= math.ceil(k / s) for taps in sched)


def test_parity_schedule_enet_case_matches_fig6():
    """k=3, s=2, p=1: center 1 tap, endpoints 2 taps (Fig. 6)."""
    sched = parity_schedule(3, 2, 1)
    assert [t for t, _ in sched[0]] == [1]      # even parity: center
    assert [t for t, _ in sched[1]] == [0, 2]   # odd parity: endpoints


# --------------------------------- fused Pallas transposed conv, general ---

@pytest.mark.parametrize("k", [2, 3, 4, 5])
@pytest.mark.parametrize("s", [2, 3, 4])
@pytest.mark.parametrize("output_padding", [0, 1])
def test_pallas_tconv_general(k, s, output_padding):
    p = (k - 1) // 2
    x, w = _pair(k * 16 + s, (1, 6, 7, 3), (k, k, 3, 5))
    ref = tr.transposed_conv2d_naive(x, w, s, p, output_padding)
    got = ops.transposed_conv2d(x, w, stride=s, padding=p,
                                output_padding=output_padding)
    assert got.shape == ref.shape
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("h,w", [(5, 5), (8, 6), (9, 13)])
def test_pallas_tconv_odd_even_sizes(h, w):
    """Odd/even spatial extents exercise the parity-plane crop."""
    x, wt = _pair(h * w, (2, h, w, 4), (3, 3, 4, 4))
    ref = tr.transposed_conv2d_naive(x, wt, 3, 1, 0)
    got = ops.transposed_conv2d(x, wt, stride=3, output_padding=0)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_pallas_tconv_bf16():
    x, wt = _pair(3, (1, 8, 8, 4), (4, 4, 4, 6), jnp.bfloat16)
    ref = tr.transposed_conv2d_naive(x, wt, 3, 1, 1)
    got = ops.transposed_conv2d(x, wt, stride=3)
    assert_allclose(np.asarray(got, np.float32), np.asarray(ref, np.float32),
                    rtol=3e-2, atol=3e-2)


# ------------------------------------------------ strided dilated, exact ---

@pytest.mark.parametrize("d", [2, 3, 4])
@pytest.mark.parametrize("s", [2, 3, 4])
@pytest.mark.parametrize("strategy", ["ragged", "batched"])
def test_strided_dilated_decomposed(d, s, strategy):
    x, w = _pair(d * 10 + s, (2, 13, 11, 3), (3, 3, 3, 4))
    ref = dil.dilated_conv2d_naive(x, w, d, s)
    got = dil.dilated_conv2d_decomposed(x, w, d, strategy=strategy, stride=s)
    assert got.shape == ref.shape
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("d,s", [(2, 2), (4, 2), (3, 2), (2, 3), (6, 4)])
def test_strided_dilated_pallas_path(d, s):
    x, w = _pair(d + s, (1, 12, 10, 4), (3, 3, 4, 4))
    ref = dil.dilated_conv2d_reference(x, w, d, s)
    got = ops.dilated_conv2d(x, w, d, stride=s)
    assert got.shape == ref.shape
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_stride_class_schedule_reduces_to_paper_for_s1():
    """s=1 degenerates to the paper's d**2-phase schedule."""
    q, sb, sched = dil.stride_class_schedule(4, 1, 3, 16)
    assert (q, sb) == (4, 1)
    assert sorted(r for r, _, _ in sched) == [0, 1, 2, 3]


def test_stride_class_schedule_gcd_folding():
    """gcd(s, d) folds classes: d=4, s=2 -> 2 classes at block stride 1."""
    q, sb, _ = dil.stride_class_schedule(4, 2, 3, 16)
    assert (q, sb) == (2, 1)
    q, sb, _ = dil.stride_class_schedule(3, 2, 3, 16)
    assert (q, sb) == (3, 2)


# -------------------------------------------------- unified dispatcher -----

@pytest.mark.parametrize("k,s", [(2, 2), (3, 3), (4, 2), (5, 4)])
def test_dispatcher_transposed_general(k, s):
    """decompose.conv2d accepts general (k, s) transposed cases."""
    x, w = _pair(k + s, (1, 6, 6, 2), (k, k, 2, 3))
    got = conv2d(x, w, stride=s, transposed=True, output_padding=1)
    ref = conv2d(x, w, stride=s, transposed=True, output_padding=1,
                 decomposed=False)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("d,s", [(2, 2), (3, 2), (4, 3), (5, 4)])
def test_dispatcher_strided_dilated(d, s):
    """decompose.conv2d accepts strided dilated cases (no more ValueError)."""
    x, w = _pair(d * s, (1, 14, 14, 2), (3, 3, 2, 2))
    got = conv2d(x, w, stride=s, dilation=d)
    ref = conv2d(x, w, stride=s, dilation=d, decomposed=False)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_pallas_dense_conv_tiny_inputs():
    """Phase blocks can shrink to 1x1 (e.g. ENet d=16 on 16x16 maps): the
    dense Pallas conv must serve tiles smaller than its halo."""
    from repro.kernels import ref

    for h, w in ((1, 1), (2, 1), (1, 5)):
        x, wt = _pair(h * 10 + w, (2, h, w, 4), (3, 3, 4, 4))
        got = ops.conv2d(x, wt)
        want = ref.conv2d_ref(x, wt)
        assert got.shape == want.shape
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_enet_forward_pallas_backend_matches_xla():
    """The whole ENet net runs through the fused Pallas engine."""
    from repro.models import enet

    key = jax.random.PRNGKey(0)
    params = enet.init_params(key, num_classes=4)
    x = jax.random.normal(key, (1, 64, 64, 3))
    y_xla = enet.forward(params, x)
    y_pal = enet.forward(params, x, backend="pallas")
    assert_allclose(np.asarray(y_pal), np.asarray(y_xla), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("transposed", [False, True])
def test_dispatcher_pallas_backend(transposed):
    """backend='pallas' routes through the fused kernels, same numbers."""
    x, w = _pair(7, (1, 8, 8, 3), (3, 3, 3, 4))
    kw = (dict(stride=2, transposed=True, output_padding=1) if transposed
          else dict(dilation=2))
    got = conv2d(x, w, backend="pallas", **kw)
    ref = conv2d(x, w, backend="xla", **kw)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_dispatcher_pallas_rejects_naive_and_ragged():
    """The fused kernels ARE the decomposition: incompatible flags are loud."""
    x, w = _pair(11, (1, 8, 8, 2), (3, 3, 2, 2))
    with pytest.raises(ValueError, match="naive execution has no pallas"):
        conv2d(x, w, dilation=2, backend="pallas", decomposed=False)
    with pytest.raises(ValueError, match="phase-batched only"):
        conv2d(x, w, dilation=2, backend="pallas", strategy="ragged")


# ----------------------------------------------- cycle-model invariants ----

def _tconv_layer(h_out, k, s, cin=8, cout=8, output_padding=1):
    return ConvLayer("t", "transposed", h_out, h_out, cin, cout, k, k,
                     stride=s, group="transposed",
                     output_padding=output_padding)


@pytest.mark.parametrize("k,s", [(2, 2), (3, 2), (3, 3), (4, 2), (5, 2),
                                 (4, 3), (5, 4)])
def test_cycle_model_general_tconv_beats_naive(k, s):
    """Decomposed cycles <= naive dense cycles for any (k, s) schedule."""
    op = min(1, s - 1)
    l = _tconv_layer(48, k, s, output_padding=op)
    assert cm.cycles_our_decomposed(l) <= cm.cycles_our_general(l)
    assert cm.ideal_sparse_macs(l) <= cm.ideal_dense_macs(l)


@pytest.mark.parametrize("D,s", [(1, 1), (3, 1), (1, 2), (3, 2), (2, 3)])
def test_cycle_model_dilated_beats_naive(D, s):
    l = ConvLayer("d", "dilated", 32, 32, 16, 16, 3, 3, D=D, stride=s,
                  group="dilated")
    assert cm.cycles_our_decomposed(l) <= cm.cycles_our_general(l)


def test_cycle_model_decomposed_beats_naive_all_enet_layers():
    for l in enet_512_layers():
        assert cm.cycles_our_decomposed(l) <= cm.cycles_our_general(l), l.name


def _brute_force_live_macs(h_in, w_in, oh, ow, k, s, p, cin, cout):
    """Independent O(oh*ow) reimplementation: count in-bounds nonzero taps."""

    def live(out_len, in_len):
        c = 0
        for y in range(out_len):
            for t in range(k):
                num = y + t - p
                if num % s == 0 and 0 <= num // s < in_len:
                    c += 1
        return c

    return live(oh, h_in) * live(ow, w_in) * cin * cout


def test_enet_decoder_nonzero_macs_match_analytic():
    """Cycle-model sparse MACs == brute-force nonzero count, and the engine's
    parity-sum MAC count brackets it, for every ENet decoder layer."""
    for l in enet_512_layers():
        if l.kind != "transposed":
            continue
        h_in, w_in = cm.tconv_input_size(l)
        assert (h_in, w_in) == (l.h_out // l.stride, l.w_out // l.stride)
        p = (l.kh - 1) // 2
        brute = _brute_force_live_macs(h_in, w_in, l.h_out, l.w_out, l.kh,
                                       l.stride, p, l.cin, l.cout)
        assert cm.ideal_sparse_macs(l) == brute, l.name
        # the engine issues every parity tap incl. boundary pads: >= in-bounds
        # nonzero MACs, and exactly s*s-fold fewer than the naive execution
        issued = tr.macs_decomposed_transposed(
            h_in, w_in, l.cin, l.cout, l.kh, l.stride, p, p + l.output_padding)
        naive = tr.macs_naive(
            h_in, w_in, l.cin, l.cout, l.kh, l.stride, p, p + l.output_padding)
        assert brute <= issued <= naive, l.name
        assert issued * 3.9 < naive < issued * 4.1, l.name  # s=2 -> ~4x skip


def test_general_tconv_input_size_inversion():
    """tconv_input_size inverts out_size for general (k, s, op)."""
    for k in (2, 3, 4, 5):
        for s in (2, 3, 4):
            for h_in in (7, 16):
                for op in (0, 1):
                    p = (k - 1) // 2
                    oh = tr.out_size(h_in, s, k, p, p + op)
                    if oh <= 0:
                        continue
                    l = _tconv_layer(oh, k, s, output_padding=op)
                    assert cm.tconv_input_size(l)[0] == h_in, (k, s, h_in, op)


def test_dilated_strided_sparse_macs_interior_bound():
    """Strided ideal-sparse is bounded by the k*k interior approximation."""
    l = ConvLayer("d", "dilated", 16, 16, 4, 4, 3, 3, D=3, stride=2,
                  group="dilated")
    assert cm.ideal_sparse_macs(l) <= dil.macs_decomposed(32, 32, 4, 4, 3, 4, 2)


# ------------------------------------------------------- MAC accounting ----

@pytest.mark.parametrize("k,s", [(2, 2), (3, 2), (4, 3), (5, 4)])
def test_transposed_mac_skip_ratio(k, s):
    """Decomposition skips ~s*s of the naive MACs in the interior."""
    naive = tr.macs_naive(64, 64, 8, 8, k, s, (k - 1) // 2, (k - 1) // 2 + 1)
    dec = tr.macs_decomposed_transposed(64, 64, 8, 8, k, s, (k - 1) // 2,
                                        (k - 1) // 2 + 1)
    ratio = naive / dec
    assert s * s * 0.7 < ratio <= s * s * 1.3
