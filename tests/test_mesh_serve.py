"""Multi-device generative serving on the simulated mesh (DESIGN.md §13).

The serving acceptance bar: a GenServer whose lanes span a 4-device
``(data,)`` mesh (PR 5's ``image_sharding`` hook carrying real shards at
last) drains a mixed-step queue to images BITWISE equal to the unbatched
single-device reference loop — GSPMD moves the slots, never the bits.
Snapshot/restore round-trips the mesh geometry, including a *resharded*
restore onto a different device count, and the cycle model's
``serve_report(devices=N)`` prices the collective-free data parallelism.
"""

import jax
import numpy as np
import pytest

from repro.core import cycle_model as cm
from repro.core.gen_spec import GEN_WORKLOADS
from repro.launch.mesh import make_train_mesh
from repro.launch.serve_gen import GenServer, reference_sample

_WIDTHS = (8, 8)
_HW = 4
_SIZE = _HW * 2 ** len(_WIDTHS)

_KW = dict(batch=4, unet_widths=_WIDTHS, unet_hw=_HW, dcgan_nz=16,
           dcgan_ngf=4, scan_steps=2)

_STEPS = (4, 2, 3, 5, 1, 6)


def _submit(server):
    return [server.submit("unet_dec", steps=s, seed=40 + i)
            for i, s in enumerate(_STEPS)]


@pytest.mark.mesh
def test_4device_drain_matches_unbatched_reference(mesh_devices):
    nd = min(4, mesh_devices)
    srv = GenServer(mesh=make_train_mesh(nd), **_KW)
    rids = _submit(srv)
    images = srv.run()
    assert sorted(images) == sorted(rids)
    denoiser = srv._lanes["unet_dec"].params
    for i, rid in enumerate(rids):
        ref = reference_sample(denoiser, steps=_STEPS[i], seed=40 + i,
                               image_size=_SIZE)
        np.testing.assert_array_equal(images[rid], ref), rid


@pytest.mark.mesh
def test_dcgan_lane_spans_mesh_bitwise(mesh_devices):
    """The single-shot GAN lane places its latent slots over the mesh's
    data axes; same seeds => same bits as the un-meshed server."""
    plain = GenServer(**_KW)
    rids = [plain.submit("dcgan64", seed=7 + i) for i in range(4)]
    ref = plain.run()

    meshed = GenServer(mesh=make_train_mesh(min(4, mesh_devices)), **_KW)
    rids_m = [meshed.submit("dcgan64", seed=7 + i) for i in range(4)]
    out = meshed.run()
    for r, m in zip(rids, rids_m):
        np.testing.assert_array_equal(out[m], ref[r])


@pytest.mark.mesh
def test_resharded_restore_round_trip(tmp_path, mesh_devices):
    """A meshed drain snapshotted mid-flight restores (a) onto the SAME
    rebuilt mesh geometry by default and (b) onto a DIFFERENT device count
    via the ``mesh=`` override — both finish bitwise-equal to the
    uninterrupted run (lane state snapshots as plain host arrays; the mesh
    is where work lands, not what the bits depend on)."""
    nd = min(4, mesh_devices)
    ref_srv = GenServer(mesh=make_train_mesh(nd), scan_steps=1,
                        **{k: v for k, v in _KW.items()
                           if k != "scan_steps"})
    _submit(ref_srv)
    ref = ref_srv.run()

    kw = dict(_KW, scan_steps=1)
    d = str(tmp_path / "snap")
    srv = GenServer(mesh=make_train_mesh(nd), snapshot_dir=d,
                    snapshot_every=1, **kw)
    _submit(srv)
    srv.step()
    srv.step()                          # mid-flight snapshots on disk

    same = GenServer.restore(d)
    assert same.mesh is not None
    assert dict(same.mesh.shape) == {"data": nd}     # geometry rebuilt
    imgs_same = same.run()
    assert sorted(imgs_same) == sorted(ref)
    for rid in ref:
        np.testing.assert_array_equal(imgs_same[rid], ref[rid]), rid

    resharded = GenServer.restore(d, mesh=make_train_mesh(2))
    assert dict(resharded.mesh.shape) == {"data": 2}
    imgs_re = resharded.run()
    for rid in ref:
        np.testing.assert_array_equal(imgs_re[rid], ref[rid]), rid


# ------------------------------------------------------------ cycle model ---

def test_serve_report_devices_scaling():
    """Phase/parity data parallelism is collective-free: N devices divide
    the compute cycles exactly, so modeled throughput scales linearly and
    per-image latency drops N-fold; dispatch bookkeeping is per-request
    and does not shrink."""
    layers = GEN_WORKLOADS["dcgan64"]()
    base = cm.serve_report(layers, steps=1)
    quad = cm.serve_report(layers, steps=1, devices=4)
    assert base["devices"] == 1 and quad["devices"] == 4
    np.testing.assert_allclose(quad["images_per_s_ours"],
                               4 * base["images_per_s_ours"], rtol=1e-9)
    np.testing.assert_allclose(quad["latency_ms_ours"],
                               base["latency_ms_ours"] / 4, rtol=1e-9)
    assert quad["dispatches_per_image"] == base["dispatches_per_image"]
    # speedup vs naive is device-count-invariant (both sides scale)
    np.testing.assert_allclose(quad["serve_speedup_vs_naive"],
                               base["serve_speedup_vs_naive"], rtol=1e-9)


def test_serve_report_devices_validation():
    layers = GEN_WORKLOADS["dcgan64"]()
    with pytest.raises(ValueError, match="devices"):
        cm.serve_report(layers, devices=0)
    with pytest.raises(ValueError, match="devices"):
        cm.serve_percentiles(layers, [1, 1], devices=-1)
