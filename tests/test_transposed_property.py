"""Property-based transposed-convolution grid: seeded random geometries.

The generative-decoder workloads (DCGAN k=4/s=2/p_lo=2 chains, U-Net k=2
upsampling — ``repro.core.gen_spec``) pushed the transposed engine into
even-kernel, non-default-padding territory the ENet-era tests never sampled.
This harness draws seeded random geometries over

    k in 2..5  x  s in 2..4  x  p_lo in 0..k-1  x  output_padding in 0..s-1
    x odd/even H, W  x  cin/cout NOT multiples of 8/128

and asserts the three-way equivalence ``pallas == xla-decomposed ==
lax.conv_transpose`` (the framework oracle) for forward and gradients.  A
fast subset runs in tier-1; the full grid is marked ``slow``.

The draws are seeded (``_RNG_SEED``) so failures reproduce exactly; bump the
seed only together with the pinned case count.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from numpy.testing import assert_allclose

from repro.core import transposed as tr
from repro.core.decompose import conv2d

_RNG_SEED = 20240731
_N_FAST = 8        # tier-1 forward cases
_N_FULL = 40       # additional slow-grid cases
_DIMS = ("NHWC", "HWIO", "NHWC")

# channel counts deliberately not multiples of the fp32 tile lanes (8 / 128):
# the kernels must mask, not assume aligned extents
_CHANNELS = (1, 2, 3, 5, 6, 7, 9, 11, 13)


def _draw_cases(n: int, seed: int = _RNG_SEED) -> list[tuple]:
    """Seeded random geometry draws; rejects degenerate output extents."""
    rng = np.random.default_rng(seed)
    cases = []
    while len(cases) < n:
        k = int(rng.integers(2, 6))
        s = int(rng.integers(2, 5))
        p_lo = int(rng.integers(0, k))
        op = int(rng.integers(0, s))
        h = int(rng.integers(2, 14))
        w = int(rng.integers(2, 14))
        cin = int(rng.choice(_CHANNELS))
        cout = int(rng.choice(_CHANNELS))
        oh = tr.out_size(h, s, k, p_lo, p_lo + op)
        ow = tr.out_size(w, s, k, p_lo, p_lo + op)
        if oh <= 0 or ow <= 0:
            continue
        cases.append((h, w, cin, cout, k, s, p_lo, op))
    return cases


_FAST = _draw_cases(_N_FAST)
_FULL = _draw_cases(_N_FAST + _N_FULL)[_N_FAST:]


def _operands(case):
    h, w, cin, cout, k, s, p_lo, op = case
    k1, k2 = jax.random.split(jax.random.PRNGKey(hash(case) & 0x7FFFFFFF))
    x = jax.random.normal(k1, (2, h, w, cin), jnp.float32)
    wgt = jax.random.normal(k2, (k, k, cin, cout), jnp.float32)
    return x, wgt


def _lax_oracle(x, wgt, s, p_lo, op):
    """The framework oracle: ``lax.conv_transpose`` with explicit pads.

    With an explicit padding list, ``conv_transpose`` is the lhs-dilated
    correlation at exactly our ``(p_lo, p_hi)`` convention (verified here so
    the repo's semantics can never drift from the framework's).
    """
    return lax.conv_transpose(
        x, wgt, (s, s), [(p_lo, p_lo + op), (p_lo, p_lo + op)],
        dimension_numbers=_DIMS, transpose_kernel=False)


def _check_forward(case):
    h, w, cin, cout, k, s, p_lo, op = case
    x, wgt = _operands(case)
    oracle = _lax_oracle(x, wgt, s, p_lo, op)
    dec = tr.transposed_conv2d_decomposed(x, wgt, s, p_lo, op)
    pal = conv2d(x, wgt, stride=s, transposed=True, padding=p_lo,
                 output_padding=op, backend="pallas")
    assert dec.shape == pal.shape == oracle.shape
    assert_allclose(np.asarray(dec), np.asarray(oracle), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(pal), np.asarray(oracle), rtol=1e-5, atol=1e-5)


def _check_grads(case):
    h, w, cin, cout, k, s, p_lo, op = case
    x, wgt = _operands(case)

    def loss(fn):
        return lambda xx, ww: jnp.sum(fn(xx, ww) ** 2)

    gx_o, gw_o = jax.grad(loss(
        lambda xx, ww: _lax_oracle(xx, ww, s, p_lo, op)), (0, 1))(x, wgt)
    gx_p, gw_p = jax.grad(loss(
        lambda xx, ww: conv2d(xx, ww, stride=s, transposed=True,
                              padding=p_lo, output_padding=op,
                              backend="pallas")), (0, 1))(x, wgt)
    assert_allclose(np.asarray(gx_p), np.asarray(gx_o), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(gw_p), np.asarray(gw_o), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------ tier-1 fast subset ---

@pytest.mark.parametrize("case", _FAST, ids=lambda c: "h{}w{}c{}x{}k{}s{}p{}op{}".format(*c))
def test_random_geometry_forward(case):
    _check_forward(case)


@pytest.mark.parametrize("case", _FAST[:3], ids=lambda c: "h{}w{}c{}x{}k{}s{}p{}op{}".format(*c))
def test_random_geometry_grads(case):
    _check_grads(case)


def test_dcgan_and_unet_geometries_exact():
    """The exact-2x even-kernel geometries the generative models run:
    DCGAN (k=4, p_lo=2) and U-Net (k=2, p_lo=1), both output_padding=0."""
    for k in (2, 4):
        case = (6, 5, 3, 5, k, 2, k // 2, 0)
        _check_forward(case)
        x, wgt = _operands(case)
        y = _lax_oracle(x, wgt, 2, k // 2, 0)
        assert y.shape[1:3] == (12, 10)       # exact 2x upsample


def test_zero_conv_planes_k_lt_s():
    """k < s leaves whole output parities with no live tap: those planes are
    identically zero on every backend (the k=2, s=3 regression for the
    zero-conv-plane schedule)."""
    case = (5, 4, 3, 2, 2, 3, 1, 0)
    h, w, cin, cout, k, s, p_lo, op = case
    x, wgt = _operands(case)
    y = np.asarray(conv2d(x, wgt, stride=s, transposed=True, padding=p_lo,
                          output_padding=op, backend="pallas"))
    _check_forward(case)
    dead_r = [r for r in range(s) if not tr.parity_taps(k, s, p_lo, r)]
    assert dead_r                           # k < s guarantees a dead parity
    for r in dead_r:
        assert np.all(y[:, r::s, :, :] == 0.0)
        assert np.all(y[:, :, r::s, :] == 0.0)


# ------------------------------------------------- sharded sweep (DESIGN §13) ---

@pytest.mark.mesh
@pytest.mark.parametrize("nd", [2, 4, 8])
@pytest.mark.parametrize("case", _FAST[:3],
                         ids=lambda c: "h{}w{}c{}x{}k{}s{}p{}op{}".format(*c))
def test_random_geometry_sharded(case, nd, mesh_devices):
    """Seeded random geometries on the simulated mesh: the sharded engine
    must equal the unsharded decomposed result BITWISE (same decomposition,
    same per-device arithmetic — GSPMD only moves the batch/parity tiles)
    and stay within the engine-parity bar of the ``lax`` oracle."""
    from repro.distributed.sharding import shard_conv2d
    from repro.launch.mesh import make_train_mesh

    if nd > mesh_devices:
        pytest.skip(f"need {nd} devices, have {mesh_devices}")
    h, w, cin, cout, k, s, p_lo, op = case
    x, wgt = _operands(case)
    unsharded = tr.transposed_conv2d_decomposed(x, wgt, s, p_lo, op)
    sharded = shard_conv2d(make_train_mesh(nd), x, wgt, stride=s,
                           transposed=True, padding=p_lo, output_padding=op)
    assert np.array_equal(np.asarray(sharded), np.asarray(unsharded))
    assert_allclose(np.asarray(sharded),
                    np.asarray(_lax_oracle(x, wgt, s, p_lo, op)),
                    rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- full slow grid ---

@pytest.mark.slow
@pytest.mark.parametrize("case", _FULL, ids=lambda c: "h{}w{}c{}x{}k{}s{}p{}op{}".format(*c))
def test_random_geometry_forward_full(case):
    _check_forward(case)


@pytest.mark.slow
@pytest.mark.parametrize("case", _FULL[:8], ids=lambda c: "h{}w{}c{}x{}k{}s{}p{}op{}".format(*c))
def test_random_geometry_grads_full(case):
    _check_grads(case)
