"""Loop-aware HLO analyzer: exact FLOPs on known programs, collective sizing."""

import jax
import jax.numpy as jnp
import pytest

from repro.distributed.hlo_analysis import (CollectiveStat, analyze,
                                            roofline_terms)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_multiplied_by_trip_count():
    def body(c, _):
        return c @ c, None

    def f(x):
        return jax.lax.scan(body, x, None, length=8)[0]

    x = jnp.ones((128, 128), jnp.float32)
    a = analyze(_compile(f, x))
    assert a.flops == pytest.approx(8 * 2 * 128 ** 3)


def test_nested_scan_flops():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            return jax.lax.scan(inner, c, None, length=8)[0], None
        return jax.lax.scan(outer, x, None, length=4)[0]

    x = jnp.ones((64, 64), jnp.float32)
    a = analyze(_compile(f, x))
    assert a.flops == pytest.approx(32 * 2 * 64 ** 3)


def test_plain_matmul_flops_and_bytes():
    def f(a, b):
        return a @ b

    a_ = jnp.ones((256, 512), jnp.bfloat16)
    b_ = jnp.ones((512, 128), jnp.bfloat16)
    a = analyze(_compile(f, a_, b_))
    assert a.flops == pytest.approx(2 * 256 * 512 * 128)
    # dot reads both operands + writes output at least once
    min_bytes = (256 * 512 + 512 * 128 + 256 * 128) * 2
    assert a.hbm_bytes >= min_bytes


def test_xla_cost_analysis_is_loop_unaware():
    """Documents WHY this module exists: XLA counts the body once."""
    def body(c, _):
        return c @ c, None

    def f(x):
        return jax.lax.scan(body, x, None, length=8)[0]

    x = jnp.ones((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict], newer returns dict
        cost = cost[0]
    xla_flops = cost["flops"]
    ours = analyze(compiled.as_text()).flops
    assert xla_flops == pytest.approx(2 * 128 ** 3)          # 1 iteration
    assert ours == pytest.approx(8 * xla_flops)


def test_collective_wire_model():
    s = CollectiveStat("all-reduce")
    # formulas validated by construction in analyze(); check the ring model
    # numbers on a synthetic record
    from repro.distributed.hlo_analysis import V5E

    a = analyze("""
HloModule m, entry_computation_layout={()->f32[]}

ENTRY %main.1 () -> f32[] {
  %c = f32[1024,1024]{1,0} constant(0)
  %ar = f32[1024,1024]{1,0} all-reduce(%c), replica_groups=[16,16]<=[256], to_apply=%x
  ROOT %r = f32[] constant(0)
}
""")
    ar = a.collectives["all-reduce"]
    size = 1024 * 1024 * 4
    assert ar.operand_bytes == pytest.approx(size)
    assert ar.wire_bytes == pytest.approx(2 * size * 15 / 16)
    t = roofline_terms(a)
    assert t["collective_s"] == pytest.approx(ar.wire_bytes / V5E["ici_gbps"])


def test_roofline_terms_dimensions():
    def f(a, b):
        return jnp.sum(a @ b)

    a_ = jnp.ones((512, 512), jnp.float32)
    t = roofline_terms(analyze(_compile(f, a_, a_)))
    assert set(t) == {"compute_s", "memory_s", "collective_s"}
    assert t["compute_s"] > 0 and t["memory_s"] > 0
    assert t["collective_s"] == 0.0  # single device: no collectives
