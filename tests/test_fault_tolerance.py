"""Fault tolerance: failure-injected training resumes bit-exactly; straggler
watchdog flags slow steps; gradient compression bounds error."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.distributed.compression import (compress_bf16, compress_int8_ef,
                                           decompress_int8,
                                           init_error_feedback)
from repro.distributed.fault_tolerance import (failure_faults, Fault,
                                               FailureInjector, Heartbeat,
                                               StragglerWatchdog)
from repro.launch.train import train


def test_training_with_injected_failure_recovers(tmp_path):
    """Kill step 12, resume from the step-10 checkpoint, finish, and match
    the loss of an uninterrupted run (bit-exact data stream + state)."""
    cfg = get_reduced("stablelm-1.6b")
    clean = train(cfg, steps=15, global_batch=4, seq_len=16,
                  ckpt_dir=str(tmp_path / "clean"), ckpt_every=5,
                  log_every=100)
    faulty = train(cfg, steps=15, global_batch=4, seq_len=16,
                   ckpt_dir=str(tmp_path / "faulty"), ckpt_every=5,
                   injector=FailureInjector({12}), log_every=100)
    assert faulty["final_step"] == clean["final_step"] == 15
    assert float(faulty["loss"]) == pytest.approx(float(clean["loss"]),
                                                  rel=1e-5)


def test_restart_from_checkpoint_continues(tmp_path):
    cfg = get_reduced("stablelm-1.6b")
    train(cfg, steps=10, global_batch=4, seq_len=16,
          ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100)
    out = train(cfg, steps=20, global_batch=4, seq_len=16,
                ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100)
    assert out["final_step"] == 20


def test_straggler_watchdog():
    wd = StragglerWatchdog(warmup=3, threshold=2.0)
    for s in range(10):
        assert not wd.observe(s, 0.1)
    assert wd.observe(10, 0.5)          # 5x slower -> flagged
    assert len(wd.flagged) == 1
    assert not wd.observe(11, 0.1)      # baseline not poisoned


def test_heartbeat_detects_dead_hosts(tmp_path):
    hb = Heartbeat(str(tmp_path), host_id=0)
    hb.beat(1)
    assert Heartbeat.dead_hosts(str(tmp_path), timeout_s=60) == []
    assert Heartbeat.dead_hosts(str(tmp_path), timeout_s=0.0) == [0]


def test_heartbeat_monitor_survives_corrupt_and_partial_files(tmp_path):
    """Regression: a truncated/corrupt heartbeat or a crash inside the
    atomic-rename window used to raise ``JSONDecodeError`` and take the
    *monitor* down.  An unprovable heartbeat now reads as dead instead."""
    Heartbeat(str(tmp_path), host_id=0).beat(1)
    # host 1: truncated mid-write (invalid JSON)
    (tmp_path / "heartbeat_001.json").write_text('{"step": 3, "ti')
    # host 2: crashed inside the rename window — only the .tmp exists
    (tmp_path / "heartbeat_002.json.tmp").write_text(
        '{"step": 3, "time": 1.0}')
    # host 3: valid JSON, wrong schema
    (tmp_path / "heartbeat_003.json").write_text('{"steps": []}')
    dead = Heartbeat.dead_hosts(str(tmp_path), timeout_s=60)
    assert dead == [1, 2, 3]
    # a host whose committed beat is fresh stays alive even if a stale
    # .tmp from an interrupted *later* beat is lying around
    Heartbeat(str(tmp_path), host_id=1).beat(2)
    (tmp_path / "heartbeat_001.json.tmp").write_text("{")
    assert Heartbeat.dead_hosts(str(tmp_path), timeout_s=60) == [2, 3]


def test_fault_take_matches_and_consumes():
    """take() semantics the chaos drills rely on: kind/tick/target/backend
    filters, None-matches-anything, once-faults disarm after firing."""
    inj = FailureInjector(faults=[
        Fault(at=2, kind="raise", target="unet_dec"),
        Fault(at=None, kind="raise", backend="pallas", once=False),
        Fault(at=3, kind="slow", seconds=0.5),
    ])
    # wrong kind / wrong tick / wrong target: no hit
    assert inj.take(1, kind="corrupt") == []
    assert inj.take(1, kind="raise", target="unet_dec",
                    backend="xla") == []
    # the persistent backend fault fires on pallas every tick, never on a
    # degraded (xla) consumer
    assert len(inj.take(2, kind="raise", target="unet_dec",
                        backend="pallas")) == 2     # targeted + persistent
    assert len(inj.take(2, kind="raise", target="unet_dec",
                        backend="pallas")) == 1     # once-fault consumed
    assert inj.take(5, kind="raise", backend="xla") == []
    assert inj.sleep_faults(3) == 0.5
    assert inj.sleep_faults(3) == 0.0               # consumed


def test_fault_kind_validated():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(at=0, kind="explode")


def test_failure_faults_recipes():
    inj = failure_faults(kill_at=4, backend_broken="pallas")
    assert inj.take(3, kind="kill") == []
    assert len(inj.take(4, kind="kill")) == 1
    # the broken-backend fault is persistent until the consumer degrades
    for tick in (0, 1, 2):
        assert len(inj.take(tick, kind="raise", backend="pallas")) == 1
    assert inj.take(3, kind="raise", backend="xla") == []


def test_injector_seed_contract_unchanged():
    """The original train-loop contract: ``FailureInjector({12})`` raises
    at step 12, once."""
    inj = FailureInjector({12})
    inj.maybe_fail(11)
    with pytest.raises(RuntimeError, match="injected node failure"):
        inj.maybe_fail(12)
    inj.maybe_fail(12)                              # once: recovery passes


def test_bf16_compression_halves_bytes():
    g = {"w": jnp.ones((64, 64), jnp.float32)}
    c = compress_bf16(g)
    assert c["w"].dtype == jnp.bfloat16


def test_int8_error_feedback_unbiased():
    """With error feedback the *accumulated* dequantised gradient converges
    to the true accumulated gradient (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(256,)).astype(np.float32)) * 0.1
    errors = init_error_feedback({"g": g_true})
    acc_deq = jnp.zeros_like(g_true)
    steps = 50
    for _ in range(steps):
        qs, scales, errors = compress_int8_ef({"g": g_true}, errors)
        acc_deq = acc_deq + decompress_int8(qs, scales)["g"]
    # average dequantised gradient ~= true gradient
    np.testing.assert_allclose(np.asarray(acc_deq / steps),
                               np.asarray(g_true), atol=2e-3)
    # one-shot (no feedback) would leave error ~ scale/2 per element
    q1, s1 = (lambda t: (t[0], t[1]))(
        compress_int8_ef({"g": g_true},
                         init_error_feedback({"g": g_true}))[:2])
    one_shot_err = np.abs(np.asarray(
        decompress_int8(q1, s1)["g"] - g_true)).mean()
    ef_err = np.abs(np.asarray(acc_deq / steps - g_true)).mean()
    assert ef_err < one_shot_err
