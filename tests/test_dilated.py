"""Property + unit tests: input decomposition for dilated convolutions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import dilated as dil

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


@pytest.mark.parametrize("dilation", [1, 2, 3, 4, 8, 16])
@pytest.mark.parametrize("strategy", ["ragged", "batched"])
def test_decomposed_matches_reference(dilation, strategy):
    key = jax.random.PRNGKey(dilation)
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (2, 17, 19, 3))
    w = _rand(k2, (3, 3, 3, 5))
    ref = dil.dilated_conv2d_reference(x, w, dilation)
    got = dil.dilated_conv2d_decomposed(x, w, dilation, strategy=strategy)
    assert got.shape == ref.shape == (2, 17, 19, 5)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dilation", [2, 3, 7, 15])
def test_naive_matches_reference(dilation):
    """The zero-inserted dense execution is numerically the oracle."""
    key = jax.random.PRNGKey(99 + dilation)
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (1, 16, 16, 4))
    w = _rand(k2, (3, 3, 4, 4))
    ref = dil.dilated_conv2d_reference(x, w, dilation)
    got = dil.dilated_conv2d_naive(x, w, dilation)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_paper_fig4_block_shapes():
    """7x7 input: D=1 -> 4 blocks (4x4,4x3,3x4,3x3); D=2 -> 9 blocks (Fig. 4)."""
    x = jnp.zeros((1, 7, 7, 1))
    blocks = dil.phase_split(x, 2)
    shapes = [b.shape[1:3] for row in blocks for b in row]
    assert shapes == [(4, 4), (4, 3), (3, 4), (3, 3)]
    blocks = dil.phase_split(x, 3)
    shapes = [b.shape[1:3] for row in blocks for b in row]
    assert shapes == [(3, 3), (3, 2), (3, 2), (2, 3), (2, 2), (2, 2), (2, 3), (2, 2), (2, 2)]


def test_effective_kernel_size_matches_paper():
    """Paper Fig. 2: enlarged kernel is (2D+3)x(2D+3) for a 3x3 base."""
    for D in [1, 2, 3, 7, 15]:
        assert dil.effective_kernel_size(3, D + 1) == 2 * D + 3


# parametrized grid over the same (shape, dilation, kernel, strategy) space
# the former hypothesis property test sampled from
_GRID_HW = [(5, 5), (7, 12), (16, 9), (24, 24), (11, 6)]


@pytest.mark.parametrize("h,w", _GRID_HW)
@pytest.mark.parametrize("dilation", [1, 2, 3, 4, 5])
@pytest.mark.parametrize("k", [1, 3, 5])
@pytest.mark.parametrize("strategy", ["ragged", "batched"])
def test_grid_decomposition_exact(h, w, dilation, k, strategy):
    cin, cout = (h % 4) + 1, (w % 4) + 1
    key = jax.random.PRNGKey(h * 1000 + w * 10 + dilation)
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (1, h, w, cin))
    wgt = _rand(k2, (k, k, cin, cout))
    ref = dil.dilated_conv2d_reference(x, wgt, dilation)
    got = dil.dilated_conv2d_decomposed(x, wgt, dilation, strategy=strategy)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_mac_counts():
    """Decomposition issues exactly the nonzero MACs; naive issues (2D+3)^2."""
    h = w = 64
    cin, cout, k = 8, 16, 3
    for D in [1, 3, 7, 15]:
        d = D + 1
        naive = dil.macs_dense(h, w, cin, cout, k, d)
        dec = dil.macs_decomposed(h, w, cin, cout, k, d)
        assert naive == h * w * cin * cout * (2 * D + 3) ** 2
        assert dec == h * w * cin * cout * 9
        assert naive / dec == ((2 * D + 3) ** 2) / 9


def test_dtype_sweep():
    for dtype in [jnp.float32, jnp.bfloat16]:
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        x = _rand(k1, (1, 12, 12, 2), dtype)
        w = _rand(k2, (3, 3, 2, 2), dtype)
        ref = dil.dilated_conv2d_reference(x, w, 3)
        got = dil.dilated_conv2d_decomposed(x, w, 3)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
        )
