"""Full-model backend parity: ENet and ESPNet forward/backward agree across
``backend='xla'``, ``backend='pallas'`` and the naive (``decomposed=False``)
baseline within fp32 tolerance.

Tiny inputs keep the pallas-interpret paths fast enough for tier-1; the
model-level pallas *gradient* parity (the expensive double pass) is marked
``slow`` — the kernel-level gradients are pinned in ``test_gradients.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.models import enet, espnet

_HW = 16   # divisible by 8: both nets downsample 3x and upsample back


@pytest.fixture(scope="module")
def enet_setup():
    params = enet.init_params(jax.random.PRNGKey(0), num_classes=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, _HW, _HW, 3))
    return params, x


@pytest.fixture(scope="module")
def espnet_setup():
    params = espnet.init_params(jax.random.PRNGKey(2), num_classes=4)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, _HW, _HW, 3))
    return params, x


def _forwards(model, params, x):
    y_dec = model.forward(params, x)                        # xla, decomposed
    y_naive = model.forward(params, x, decomposed=False)    # zero-laden
    y_pal = model.forward(params, x, backend="pallas")      # fused kernels
    return y_dec, y_naive, y_pal


@pytest.mark.parametrize("which", ["enet", "espnet"])
def test_forward_three_way_parity(which, enet_setup, espnet_setup):
    model, (params, x) = ((enet, enet_setup) if which == "enet"
                          else (espnet, espnet_setup))
    y_dec, y_naive, y_pal = _forwards(model, params, x)
    assert y_dec.shape == (1, _HW, _HW, 4)
    # batch norm over a tiny batch amplifies fp32 accumulation-order noise
    # through the depth of the net (per-op exactness is pinned at 1e-5 in
    # test_kernels/test_gradients) — bound the *relative* error so a real
    # decomposition/schedule bug (O(1) mismatch) still fails loudly
    assert_allclose(np.asarray(y_dec), np.asarray(y_naive),
                    rtol=1e-3, atol=1e-3)
    d, p = np.asarray(y_dec), np.asarray(y_pal)
    rel = np.linalg.norm(p - d) / np.linalg.norm(d)
    assert rel < 5e-3, rel
    assert np.abs(p - d).max() < 0.05 * np.abs(d).max()


def _loss(model, params, x, backend):
    logits = model.forward(params, x, backend=backend)
    lab = jnp.zeros(logits.shape[:3], jnp.int32)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    return -jnp.mean(jnp.take_along_axis(lp, lab[..., None], -1))


@pytest.mark.parametrize("which", ["enet", "espnet"])
def test_grad_runs_xla(which, enet_setup, espnet_setup):
    """jax.grad of a scalar loss through the whole net (xla backend)."""
    model, (params, x) = ((enet, enet_setup) if which == "enet"
                          else (espnet, espnet_setup))
    loss, grads = jax.value_and_grad(
        lambda p: _loss(model, p, x, "xla"))(params)
    assert np.isfinite(float(loss))
    norms = jax.tree_util.tree_map(lambda g: float(jnp.linalg.norm(g)), grads)
    flat = jax.tree_util.tree_leaves(norms)
    assert all(np.isfinite(n) for n in flat)
    assert any(n > 0 for n in flat)


def test_grad_runs_pallas_espnet(espnet_setup):
    """jax.grad through the full ESPNet on the pallas backend (custom VJPs
    of all three fused kernels fire: dense, dilated incl. strided, tconv)."""
    params, x = espnet_setup
    lx, gx = jax.value_and_grad(lambda p: _loss(espnet, p, x, "xla"))(params)
    lp, gp = jax.value_and_grad(lambda p: _loss(espnet, p, x, "pallas"))(params)
    assert float(lx) == pytest.approx(float(lp), rel=1e-4)
    # per-leaf gradient parity (batch-norm over tiny batches amplifies fp32
    # noise through the depth of the net — tolerance is loose but bounded)
    for a, b in zip(jax.tree_util.tree_leaves(gp),
                    jax.tree_util.tree_leaves(gx)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-2, atol=5e-3)


@pytest.mark.slow
def test_grad_runs_pallas_enet(enet_setup):
    """jax.grad through the full ENet on the pallas backend."""
    params, x = enet_setup
    lx, _ = jax.value_and_grad(lambda p: _loss(enet, p, x, "xla"))(params)
    lp, gp = jax.value_and_grad(lambda p: _loss(enet, p, x, "pallas"))(params)
    assert float(lx) == pytest.approx(float(lp), rel=1e-4)
    flat = [float(jnp.linalg.norm(g)) for g in jax.tree_util.tree_leaves(gp)]
    assert all(np.isfinite(n) for n in flat) and any(n > 0 for n in flat)
