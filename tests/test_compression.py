"""Gradient-compression unit tests (DESIGN.md §13).

The sharded train step routes its cross-device reduction through
``repro.distributed.compression``; these tests pin the pieces standalone:
quantization error bounds, the error-feedback accumulator's unbiasedness,
wire packing of awkward leaves (odd-length, scalar, zero-size — a bias-free
layer contributes an EMPTY grad leaf — and non-contiguous numpy views), and
the fixed-order ``mesh_allreduce`` that makes the train step bitwise
mesh-invariant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed import compression as C

#: leaf shapes chosen to stress the wire format: odd length, scalar,
#: zero-size, word-aligned, and > one word
_SHAPES = ((3,), (), (0, 2), (4,), (5, 7))


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {f"leaf{i}": jnp.asarray(rng.normal(size=s).astype(np.float32))
            for i, s in enumerate(_SHAPES)}


# ------------------------------------------------------------ quantization ---

def test_bf16_round_trip_dtype_and_error():
    g = _tree()
    out = C.decompress_bf16(C.compress_bf16(g))
    for k, leaf in g.items():
        assert out[k].dtype == jnp.float32
        # bf16 keeps 8 mantissa bits: relative error < 2^-8
        np.testing.assert_allclose(out[k], leaf, rtol=1 / 256, atol=1e-6)


def test_int8_error_bounded_by_half_step():
    g = jnp.asarray(np.random.default_rng(1).normal(size=(64,)).astype(np.float32))
    q, scale = C.quantize_int8(g)
    err = np.abs(np.asarray(C.dequantize_int8(q, scale)) - np.asarray(g))
    assert err.max() <= float(scale) / 2 + 1e-7


def test_int8_empty_leaf_regression():
    """A zero-size grad leaf must quantize (scale from ``initial=0.0``),
    not crash the reduction with an empty-max error."""
    q, scale = C.quantize_int8(jnp.zeros((0, 3), jnp.float32))
    assert q.shape == (0, 3) and np.isfinite(float(scale))
    qt, st, et = C.compress_int8_ef(_tree(), C.init_error_feedback(_tree()))
    assert qt["leaf2"].shape == (0, 2)
    out = C.decompress_int8(qt, st)
    assert out["leaf2"].shape == (0, 2)


def test_error_feedback_unbiased_over_steps():
    """Residual carry makes repeated int8 compression unbiased: the sum of
    dequantized gradients tracks the sum of true gradients to within one
    quantization step, independent of the step count."""
    g = _tree(seed=2)
    errors = C.init_error_feedback(g)
    total = jax.tree.map(jnp.zeros_like, g)
    n = 25
    for _ in range(n):
        q, s, errors = C.compress_int8_ef(g, errors)
        total = jax.tree.map(lambda t, d: t + d, total, C.decompress_int8(q, s))
    for k in g:
        if g[k].size == 0:
            continue
        step = float(jnp.max(jnp.abs(g[k]))) / 127.0
        np.testing.assert_allclose(np.asarray(total[k]) / n, np.asarray(g[k]),
                                   atol=2 * step / n + 1e-7)


# ------------------------------------------------------------- wire packing ---

@pytest.mark.parametrize("word", [1, 4, 8])
def test_pack_unpack_round_trip(word):
    q_tree, _, _ = C.compress_int8_ef(_tree(3), C.init_error_feedback(_tree(3)))
    buf, manifest = C.pack_int8(q_tree, word=word)
    assert buf.dtype == jnp.int8 and buf.size % word == 0
    out = C.unpack_int8(buf, manifest)
    for k in q_tree:
        assert out[k].shape == q_tree[k].shape
        assert np.array_equal(np.asarray(out[k]), np.asarray(q_tree[k])), k


def test_pack_non_contiguous_and_odd_leaves():
    """numpy views (negative stride, strided slice) and odd-length leaves
    must pack to the same bytes as their contiguous copies."""
    base = np.arange(60, dtype=np.int8).reshape(6, 10)
    tree = {"rev": base[::-1], "strided": base[:, ::3], "odd": base.ravel()[:7]}
    buf, manifest = C.pack_int8(tree)
    out = C.unpack_int8(buf, manifest)
    for k in tree:
        assert np.array_equal(np.asarray(out[k]), np.asarray(tree[k])), k
    contig = {k: np.ascontiguousarray(v) for k, v in tree.items()}
    buf2, _ = C.pack_int8(contig)
    assert np.array_equal(np.asarray(buf), np.asarray(buf2))


def test_pack_word_validation_and_empty_tree():
    with pytest.raises(ValueError, match="word"):
        C.pack_int8({"a": jnp.zeros((3,), jnp.int8)}, word=0)
    buf, manifest = C.pack_int8({})
    assert buf.size == 0 and C.unpack_int8(buf, manifest) == {}


# ----------------------------------------------------------- mesh allreduce ---

def _stacks(chunks=8, seed=4):
    rng = np.random.default_rng(seed)
    return {f"leaf{i}": jnp.asarray(
        rng.normal(size=(chunks,) + s).astype(np.float32))
        for i, s in enumerate(((3, 5), (7,), ()))}


def _reduce_on(nd, stacks, transport):
    mesh = jax.make_mesh((nd,), ("data",))
    fn = shard_map(
        lambda s: C.mesh_allreduce(s, "data", transport=transport),
        mesh=mesh, in_specs=(P("data"),), out_specs=P(), check_rep=False)
    return jax.jit(fn)(stacks)


@pytest.mark.mesh
@pytest.mark.parametrize("nd", [2, 4, 8])
def test_mesh_allreduce_dense_bitwise_mesh_invariant(nd, mesh_devices):
    """The §13 pillar: all_gather + ONE fixed-order sum gives the same bits
    on every mesh size (a psum tree would reassociate with the mesh)."""
    if nd > mesh_devices:
        pytest.skip(f"need {nd} devices, have {mesh_devices}")
    stacks = _stacks()
    ref = _reduce_on(1, stacks, "dense")
    out = _reduce_on(nd, stacks, "dense")
    for k in ref:
        assert np.array_equal(np.asarray(out[k]), np.asarray(ref[k])), k
        # and the fixed order IS plain sum-over-chunks
        assert np.array_equal(np.asarray(ref[k]),
                              np.asarray(jnp.sum(stacks[k], axis=0))), k


@pytest.mark.mesh
def test_mesh_allreduce_bf16_transport_close(mesh_devices):
    nd = min(4, mesh_devices)
    stacks = _stacks(seed=5)
    dense = _reduce_on(nd, stacks, "dense")
    bf16 = _reduce_on(nd, stacks, "bf16")
    for k in dense:
        np.testing.assert_allclose(np.asarray(bf16[k]), np.asarray(dense[k]),
                                   rtol=0.05, atol=0.05)


def test_mesh_allreduce_unknown_transport_raises():
    with pytest.raises(ValueError, match="transport"):
        C.mesh_allreduce({"g": jnp.zeros((2, 3))}, "data", transport="int4")
