"""Per-architecture smoke tests: reduced config, one forward/train/decode
step on CPU, asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import encdec, transformer
from repro.models.layers import softmax_cross_entropy

B, S = 2, 32


def _is_encdec(cfg):
    return cfg.encoder_layers > 0


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


def test_full_config_loads(arch):
    cfg = get_config(arch)
    counts = cfg.param_counts()
    assert counts["total"] > 0 and counts["active"] <= counts["total"]


def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    if _is_encdec(cfg):
        params = encdec.init_params(key, cfg)
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.encoder_ctx, cfg.d_model))
        logits = encdec.forward(params, tokens, frames, cfg)
    else:
        params = transformer.init_params(key, cfg)
        logits = transformer.forward(params, tokens, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_train_step_decreases_loss(arch):
    """One SGD step on one batch must reduce that batch's loss."""
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                                cfg.vocab)
    inp, lbl = tokens[:, :-1], tokens[:, 1:]
    if _is_encdec(cfg):
        params = encdec.init_params(key, cfg)
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.encoder_ctx, cfg.d_model))

        def loss_fn(p):
            return softmax_cross_entropy(encdec.forward(p, inp, frames, cfg),
                                         lbl)
    else:
        params = transformer.init_params(key, cfg)

        def loss_fn(p):
            return softmax_cross_entropy(transformer.forward(p, inp, cfg),
                                         lbl)

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss0))
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype), params,
                           grads)
    loss1 = loss_fn(params2)
    assert bool(jnp.isfinite(loss1))
    assert float(loss1) < float(loss0), (arch, float(loss0), float(loss1))


def test_decode_step(arch):
    cfg = get_reduced(arch)
    if not cfg.decode_supported:
        pytest.skip("no decode for this arch")
    key = jax.random.PRNGKey(0)
    token = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab)
    if _is_encdec(cfg):
        params = encdec.init_params(key, cfg)
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.encoder_ctx, cfg.d_model))
        enc_out = encdec.encode(params, frames, cfg)
        caches = encdec.init_caches(cfg, B, 64)
        logits, caches2 = encdec.decode_step(params, token, enc_out, caches,
                                             jnp.int32(0), cfg)
    else:
        params = transformer.init_params(key, cfg)
        caches = transformer.init_caches(cfg, B, 64)
        logits, caches2 = transformer.decode_step(params, token, caches,
                                                  jnp.int32(0), cfg)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache must actually change
    leaves0 = jax.tree.leaves(caches)
    leaves1 = jax.tree.leaves(caches2)
    assert any(bool(jnp.any(a != b)) for a, b in zip(leaves0, leaves1))


def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = get_reduced(arch)
    if not cfg.decode_supported or _is_encdec(cfg):
        pytest.skip("covered elsewhere")
    # f32: this asserts *algorithmic* equivalence of the parallel and
    # recurrent paths; bf16 adds rounding noise between the two orderings
    # (recurrences especially), which is not what this test is about.
    cfg = cfg.replace(dtype="float32")
    if cfg.moe is not None:
        # the dropped-token dispatch drops differently for grouped prefill vs
        # single-token decode; give the test enough capacity that no token is
        # ever dropped, making the equivalence exact.
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    full = transformer.forward(params, toks, cfg)

    caches = transformer.init_caches(cfg, 1, 32)
    outs = []
    for t in range(8):
        logits, caches = transformer.decode_step(
            params, toks[:, t:t + 1], caches, jnp.int32(t), cfg)
        outs.append(logits[:, 0])
    step = jnp.stack(outs, axis=1)
    import numpy as np
    np.testing.assert_allclose(
        np.asarray(step, np.float32), np.asarray(full, np.float32),
        rtol=2e-3, atol=2e-3)
