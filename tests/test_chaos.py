"""Chaos drills for the fault-tolerance layer (DESIGN.md §11).

Claim families from the robustness issue:

* **exact resume** — a drain killed by an injected failure at an early /
  mid / last tick and restored via ``GenServer.restore`` produces the same
  rid set with bitwise-identical samples (xla) as an uninterrupted run,
  mixed SLO classes included; cross-backend the recovered drain stays
  within the engine-parity bar (<= 1e-5);
* **graceful degradation** — a persistent pallas dispatch failure walks
  the retry/backoff ladder into per-lane xla fallback and the server
  finishes the drain with ``stats()["degraded"] >= 1`` instead of raising;
  a transient failure is absorbed by a retry with no degradation;
* **corruption recovery** — a NaN-poisoned slot is caught by the
  completion-time finiteness gate and re-run from its seed to the
  bitwise-correct sample (or lands terminal as ``"corrupt"`` once the
  requeue budget is spent);
* **stuck-tick shedding** — consecutive straggler flags shed the
  lowest-priority pending class first (the PR-7 SLO ladder as
  back-pressure relief), never in-flight work;
* **train-loop chaos** — injected kills recover at the exact step
  (counted in metrics), injected stalls land inside the watchdog's timed
  window.

Tiny widths (8, 8) / 16x16 images keep every drill inside tier-1.
"""

import jax
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import get_reduced
from repro.distributed.fault_tolerance import (FailureInjector, Fault,
                                               StragglerWatchdog,
                                               failure_faults)
from repro.launch.serve_gen import GenServer
from repro.launch.train import train
from repro.models import unet_decoder

_WIDTHS = (8, 8)
_HW = 4

_KW = dict(batch=3, unet_widths=_WIDTHS, unet_hw=_HW, dcgan_nz=16,
           dcgan_ngf=4, scan_steps=2)

#: (workload, steps, slo) mix used by the kill/restore drills — mixed step
#: budgets AND mixed SLO classes, plus a single-shot DCGAN request, so the
#: snapshot covers every kind of scheduler state at once
_MIX = [("unet_dec", 6, "realtime"), ("unet_dec", 4, "standard"),
        ("unet_dec", 7, "batch"), ("dcgan64", 1, "standard"),
        ("unet_dec", 5, "batch")]


def _submit_mix(server):
    return [server.submit(wl, steps=s, seed=100 + i, slo=slo)
            for i, (wl, s, slo) in enumerate(_MIX)]


def _assert_bitwise_equal(imgs, ref_imgs):
    assert sorted(imgs) == sorted(ref_imgs)
    for rid in ref_imgs:
        assert np.array_equal(imgs[rid], ref_imgs[rid]), rid


# ----------------------------------------------------------- exact resume ---

def test_kill_restore_bitwise_sweep(tmp_path):
    """Kill at an early, mid, and last tick; every restore finishes the
    drain bitwise-equal to the uninterrupted run (exact-resume bar)."""
    ref = GenServer(**_KW)
    _submit_mix(ref)
    ref_imgs = ref.run()
    ticks = ref._tick
    assert ticks >= 3, ticks
    for kill_tick in (1, ticks // 2, ticks - 1):
        d = str(tmp_path / f"kill{kill_tick}")
        server = GenServer(snapshot_dir=d, snapshot_every=1,
                           faults=failure_faults(kill_at=kill_tick), **_KW)
        _submit_mix(server)
        with pytest.raises(RuntimeError, match="injected server kill"):
            server.run()
        restored = GenServer.restore(d)
        assert restored._tick == kill_tick      # resumed at the kill point
        _assert_bitwise_equal(restored.run(), ref_imgs)
        st = restored.stats()
        assert st["recoveries"] >= 1
        assert st["snapshots"] >= kill_tick     # cadence carried over


def test_restore_with_sparse_snapshots_replays_lost_ticks(tmp_path):
    """A coarse snapshot cadence loses post-snapshot ticks to the crash;
    the restored drain replays them deterministically to the same images —
    including requests that *completed* between snapshot and kill."""
    ref = GenServer(**_KW)
    _submit_mix(ref)
    ref_imgs = ref.run()
    d = str(tmp_path / "snap")
    # an odd kill tick: with snapshot_every=2 the newest snapshot is then
    # strictly older than the crash, so the restore genuinely replays
    kill_tick = ref._tick - 1
    if kill_tick % 2 == 0:
        kill_tick -= 1
    assert kill_tick >= 1
    server = GenServer(snapshot_dir=d, snapshot_every=2,
                       faults=failure_faults(kill_at=kill_tick), **_KW)
    _submit_mix(server)
    with pytest.raises(RuntimeError, match="injected server kill"):
        server.run()
    restored = GenServer.restore(d)
    assert restored._tick < kill_tick           # genuinely replaying
    _assert_bitwise_equal(restored.run(), ref_imgs)


def test_restore_cross_backend_within_parity_bar(tmp_path):
    """A drain killed and recovered on xla matches an uninterrupted pallas
    drain to the engine-parity tolerance."""
    reqs = [("unet_dec", 3, 0), ("unet_dec", 2, 1)]
    pal = GenServer(**dict(_KW, batch=2, backend="pallas", interpret=True))
    for wl, s, seed in reqs:
        pal.submit(wl, steps=s, seed=seed)
    pal_imgs = pal.run()
    d = str(tmp_path / "xb")
    server = GenServer(snapshot_dir=d, snapshot_every=1,
                       faults=failure_faults(kill_at=1),
                       **dict(_KW, batch=2))
    for wl, s, seed in reqs:
        server.submit(wl, steps=s, seed=seed)
    with pytest.raises(RuntimeError, match="injected server kill"):
        server.run()
    imgs = GenServer.restore(d).run()
    assert sorted(imgs) == sorted(pal_imgs)
    for rid in imgs:        # the repo's engine-parity bar: 1e-5 relative
        scale = max(np.abs(pal_imgs[rid]).max(), 1.0)
        assert np.abs(imgs[rid] - pal_imgs[rid]).max() / scale <= 1e-5


def test_restore_without_snapshot_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        GenServer.restore(str(tmp_path / "empty"))


def test_snapshot_roundtrips_custom_params(tmp_path):
    """Lane parameters travel in the snapshot: a server built with override
    params restores to the same samples without being handed them again."""
    params = unet_decoder.init_denoiser_params(jax.random.PRNGKey(7),
                                               widths=_WIDTHS)
    ref = GenServer(params={"unet_dec": params}, **_KW)
    rid = ref.submit("unet_dec", steps=4, seed=3)
    ref_img = ref.run()[rid]
    d = str(tmp_path / "p")
    server = GenServer(params={"unet_dec": params}, snapshot_dir=d,
                       snapshot_every=1, faults=failure_faults(kill_at=1),
                       **_KW)
    assert server.submit("unet_dec", steps=4, seed=3) == rid
    with pytest.raises(RuntimeError, match="injected server kill"):
        server.run()
    restored = GenServer.restore(d)        # note: no params= handed over
    assert np.array_equal(restored.run()[rid], ref_img)


# ---------------------------------------------------- degradation + retry ---

def test_persistent_pallas_fault_degrades_lane_to_xla():
    """The acceptance bar: an injected pallas-backend fault degrades the
    lane to xla and the server finishes the drain instead of raising."""
    server = GenServer(faults=failure_faults(backend_broken="pallas"),
                       max_retries=1, retry_backoff_s=1e-4,
                       **dict(_KW, backend="pallas", interpret=True))
    rids = [server.submit("unet_dec", steps=4, seed=i) for i in range(3)]
    imgs = server.run()
    st = server.stats()
    assert sorted(imgs) == sorted(rids)
    assert st["degraded"] >= 1
    assert st["retries"] >= 1
    assert server._lanes["unet_dec"].backend == "xla"
    # the degraded lane ran the whole drain on xla: bitwise vs a clean
    # xla server (the fault fired before any pallas dispatch)
    clean = GenServer(**_KW)
    for i in range(3):
        clean.submit("unet_dec", steps=4, seed=i)
    _assert_bitwise_equal(imgs, clean.run())


def test_transient_fault_retries_and_recovers():
    """A once-fault is absorbed by one backoff retry: no degradation, and
    the drain is bitwise-unchanged (the retry re-enters with untouched
    lane state)."""
    inj = FailureInjector(faults=[Fault(at=1, kind="raise")])
    server = GenServer(faults=inj, retry_backoff_s=1e-4, **_KW)
    _submit_mix(server)
    imgs = server.run()
    st = server.stats()
    assert st["retries"] == 1 and st["recoveries"] == 1
    assert st["degraded"] == 0
    ref = GenServer(**_KW)
    _submit_mix(ref)
    _assert_bitwise_equal(imgs, ref.run())


def test_xla_lane_exhausting_retries_propagates():
    """There is no rung below xla: a persistent fault on the fallback
    engine surfaces after the retry budget instead of looping forever."""
    inj = FailureInjector(faults=[Fault(at=None, kind="raise", once=False)])
    server = GenServer(faults=inj, max_retries=2, retry_backoff_s=1e-4,
                       **_KW)
    server.submit("unet_dec", steps=2, seed=0)
    with pytest.raises(RuntimeError, match="injected xla dispatch failure"):
        server.run()
    assert server.stats()["retries"] == 2


# ------------------------------------------------------------- corruption ---

def test_corrupt_slot_requeued_and_rerun_bitwise():
    inj = FailureInjector(faults=[Fault(at=1, kind="corrupt", slot=0)])
    server = GenServer(faults=inj, **_KW)
    rid = server.submit("unet_dec", steps=4, seed=7)
    imgs = server.run()
    req = server.request(rid)
    assert req.requeues == 1 and req.status == "done"
    assert server.stats()["recoveries"] == 1
    clean = GenServer(**_KW)
    crid = clean.submit("unet_dec", steps=4, seed=7)
    assert np.array_equal(imgs[rid], clean.run()[crid])


def test_corrupt_slot_exhausting_requeues_is_terminal():
    """Every admission of the request is poisoned; after ``max_requeues``
    the request lands terminal as ``"corrupt"``, never surfacing NaNs."""
    inj = FailureInjector(
        faults=[Fault(at=None, kind="corrupt", slot=0, once=False)])
    server = GenServer(faults=inj, max_requeues=1, **dict(_KW, batch=1))
    rid = server.submit("unet_dec", steps=3, seed=0)
    imgs = server.run()
    assert imgs == {}
    req = server.request(rid)
    assert req.status == "corrupt" and req.result is None
    assert req.requeues == 1
    assert server.stats()["corrupt"] == 1


# ------------------------------------------------------ stuck-tick ladder ---

def test_watchdog_sheds_batch_class_first():
    """Consecutive injected stalls trip the stuck ladder; only pending
    batch-class work is shed — higher classes and in-flight work finish."""
    inj = FailureInjector(faults=[Fault(at=t, kind="slow", seconds=0.25)
                                  for t in range(3, 9)])
    wd = StragglerWatchdog(alpha=1.0, threshold=3.0, warmup=1)
    server = GenServer(faults=inj, watchdog=wd, stuck_shed_after=2,
                       **dict(_KW, batch=2))
    rids = [server.submit("unet_dec", steps=8, seed=i,
                          slo="standard" if i < 4 else "batch")
            for i in range(6)]
    imgs = server.run()
    st = server.stats()
    assert st["shed"] == 2.0, st
    assert all(server.request(r).status == "done" for r in rids[:4])
    assert all(server.request(r).status == "shed" for r in rids[4:])
    assert sorted(imgs) == sorted(rids[:4])


# -------------------------------------------------------- train-loop chaos --

def test_train_loop_counts_recoveries_and_stalls(tmp_path):
    """Injected kill -> checkpoint-restore-resume counted in metrics;
    injected stall lands inside the watchdog's timed window."""
    cfg = get_reduced("stablelm-1.6b")
    inj = FailureInjector(
        {5}, faults=[Fault(at=7, kind="slow", seconds=0.5)])
    out = train(cfg, steps=8, global_batch=4, seq_len=16,
                ckpt_dir=str(tmp_path), ckpt_every=3, injector=inj,
                log_every=100)
    assert out["final_step"] == 8
    assert out["recoveries"] == 1
    # the stall was consumed inside the timed window (whether the watchdog
    # flags it depends on the compile-laden EWMA, pinned separately in
    # test_fault_tolerance)
    assert any(f.kind == "slow" for f in inj.fired)


# ----------------------------------------------------- snapshot mechanics ---

def test_auto_snapshot_cadence_and_gc(tmp_path):
    d = str(tmp_path / "cad")
    server = GenServer(snapshot_dir=d, snapshot_every=2, snapshot_keep=2,
                       **_KW)
    _submit_mix(server)
    server.run()
    st = server.stats()
    assert st["snapshots"] == server._tick // 2
    steps = ckpt.all_steps(d)
    assert len(steps) <= 2                  # keep= GC bound holds
    assert steps[-1] <= server._tick


# ------------------------------------------------- heartbeat failover ---

def test_pool_drain_no_fault_bitwise(tmp_path):
    """A multi-host pool with nobody dying is just a scheduler shuffle:
    every image must match the single-server reference bitwise."""
    from repro.launch.failover import FailoverPool

    ref = GenServer(**_KW)
    rids = _submit_mix(ref)
    ref_imgs = ref.run()

    pool = FailoverPool(str(tmp_path / "hb"), hosts=2, timeout_s=30.0,
                        server_kw=_KW)
    toks = [pool.submit(wl, steps=s, seed=100 + i, slo=slo)
            for i, (wl, s, slo) in enumerate(_MIX)]
    out = pool.drain()
    assert pool.stats()["dead_hosts"] == 0 and not pool.failovers
    _assert_bitwise_equal({rids[i]: out[t] for i, t in enumerate(toks)},
                          ref_imgs)


def test_heartbeat_failover_drain_bitwise(tmp_path):
    """The DESIGN.md §13 chaos drill: a host dies before serving anything
    it owns — it stops beating, the monitor flags the stale heartbeat, its
    requests reassign to survivors, and the completed drain is bitwise
    equal to the no-fault run (requests are pure functions of
    ``(workload, steps, seed)``, so a different host must produce the
    same bits)."""
    import time

    from repro.launch.failover import FailoverPool

    ref = GenServer(**_KW)
    rids = _submit_mix(ref)
    ref_imgs = ref.run()

    pool = FailoverPool(str(tmp_path / "hb"), hosts=3, timeout_s=0.1,
                        server_kw=_KW)
    toks = [pool.submit(wl, steps=s, seed=100 + i, slo=slo)
            for i, (wl, s, slo) in enumerate(_MIX)]
    victim = 1
    owned = [t for t, (h, _) in pool._where.items() if h == victim]
    assert owned                            # round-robin gave it work
    pool.kill_host(victim)
    time.sleep(0.15)                        # let the last beat go stale
    out = pool.drain()

    st = pool.stats()
    assert st["dead_hosts"] == 1 and st["completed"] == len(_MIX)
    moved = {t for t, _, _ in pool.failovers}
    assert moved == set(owned)              # exactly the victim's inventory
    assert all(frm == victim and to != victim
               for _, frm, to in pool.failovers)
    _assert_bitwise_equal({rids[i]: out[t] for i, t in enumerate(toks)},
                          ref_imgs)
