"""MoE dispatch correctness: the grouped-capacity einsum dispatch must equal
a direct per-token gather-and-compute reference when capacity is unbounded,
and degrade only by dropping when bounded."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.models import moe
from repro.models.config import ModelConfig, MoEConfig


def _cfg(e=8, k=2, cf=100.0, group=64):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=2,
        kv_heads=2, head_dim=16, d_ff=0, vocab=64,
        moe=MoEConfig(num_experts=e, top_k=k, d_ff_expert=48,
                      capacity_factor=cf, group_size=group), remat=False)


def _dense_reference(p, x, cfg):
    """Route each token independently; compute its top-k experts directly."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    gates, idx = jax.lax.top_k(logits, cfg.moe.top_k)
    gates = jax.nn.softmax(gates, axis=-1)
    out = jnp.zeros_like(xt, dtype=jnp.float32)
    for slot in range(cfg.moe.top_k):
        e_idx = idx[:, slot]
        wg = p["we_gate"][e_idx]          # (T, D, F)
        wu = p["we_up"][e_idx]
        wd = p["we_down"][e_idx]
        h = jax.nn.silu(jnp.einsum("td,tdf->tf", xt, wg)) * \
            jnp.einsum("td,tdf->tf", xt, wu)
        y = jnp.einsum("tf,tfd->td", h, wd)
        out = out + gates[:, slot:slot + 1] * y.astype(jnp.float32)
    return out.reshape(b, s, d).astype(x.dtype)


def test_moe_matches_dense_reference_unbounded_capacity():
    cfg = _cfg(cf=100.0)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    got = moe.moe_ffn(p, x, cfg)
    want = _dense_reference(p, x, cfg)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_moe_top1_with_shared_expert():
    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=2,
        kv_heads=2, head_dim=16, d_ff=0, vocab=64,
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=48,
                      shared_expert_ff=48, capacity_factor=100.0,
                      group_size=64), remat=False)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    got = moe.moe_ffn(p, x, cfg)
    # shared expert runs densely alongside: removing it changes the output
    p2 = dict(p)
    p2.pop("shared")
    got2 = moe.moe_ffn(p2, x, cfg)
    assert got.shape == (1, 64, 32)
    assert bool(jnp.any(jnp.abs(got - got2) > 1e-6))


def test_moe_capacity_drops_tokens():
    """With capacity factor ~0, (almost) everything drops -> near-zero out."""
    cfg = _cfg(cf=100.0)
    tiny = dataclasses.replace(cfg.moe, capacity_factor=1e-9)
    cfg_tiny = cfg.replace(moe=tiny)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg_tiny, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    full = moe.moe_ffn(p, x, cfg)
    dropped = moe.moe_ffn(p, x, cfg_tiny)
    # capacity 1 per expert -> most tokens zeroed
    assert float(jnp.mean(jnp.abs(dropped))) < float(jnp.mean(jnp.abs(full)))


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives loss == 1 (Switch normalisation)."""
    g, t, e = 2, 32, 8
    logits = jnp.zeros((g, t, e))
    idx = jnp.tile(jnp.arange(e), (g, t // e))[..., None]
    loss = moe.aux_load_balance_loss(logits, idx, e)
    assert float(loss) == pytest.approx(1.0, rel=1e-5)


def test_moe_grad_flows_to_router_and_experts():
    cfg = _cfg()
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))

    def loss(p_):
        return jnp.sum(moe.moe_ffn(p_, x, cfg) ** 2)

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["we_gate"]))) > 0
    assert float(jnp.sum(jnp.abs(g["we_down"]))) > 0
