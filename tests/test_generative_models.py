"""Generative decoder workloads: DCGAN generator + diffusion U-Net decoder.

Backend parity (xla decomposed / xla naive / pallas fused kernels) for
forward and gradients, plus consistency between the models and their
cycle-model workload tables (``repro.core.gen_spec``).  These are the first
consumers of the even-kernel (k=4, k=2) transposed parity schedules and the
non-default ``p_lo`` geometry, chained 3-5 stages deep.

Acceptance bar from the issue: forward deviation <= 1e-5 (fp32) between the
pallas kernels and the XLA reference.  Tiny widths keep the interpret-mode
pallas paths inside the tier-1 budget; the 128x128 generator (one more
chained stage) is ``slow``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import gen_spec
from repro.models import dcgan, unet_decoder

_WIDTHS = (16, 8, 8)        # tiny U-Net decoder: 4x4 mid -> 32x32 out


@pytest.fixture(scope="module")
def dcgan_setup():
    params = dcgan.init_params(jax.random.PRNGKey(0), size=64, nz=16, ngf=4)
    z = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
    return params, z


@pytest.fixture(scope="module")
def unet_setup():
    params = unet_decoder.init_params(jax.random.PRNGKey(2), widths=_WIDTHS,
                                      out_ch=3)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 4, _WIDTHS[0]))
    skips = tuple(
        jax.random.normal(jax.random.PRNGKey(10 + i), (1, 4 * 2 ** i, 4 * 2 ** i, c))
        for i, c in enumerate(_WIDTHS))
    return params, x, skips


# ----------------------------------------------------------- forward parity ---

def test_dcgan_forward_three_way(dcgan_setup):
    params, z = dcgan_setup
    y = dcgan.forward(params, z)
    assert y.shape == (2, 64, 64, 3)
    assert float(jnp.abs(y).max()) <= 1.0           # tanh head
    y_naive = dcgan.forward(params, z, decomposed=False)
    y_pal = dcgan.forward(params, z, backend="pallas")
    assert_allclose(np.asarray(y_naive), np.asarray(y), rtol=1e-5, atol=1e-5)
    assert np.abs(np.asarray(y_pal) - np.asarray(y)).max() <= 1e-5


def test_unet_decoder_forward_three_way(unet_setup):
    params, x, skips = unet_setup
    y = unet_decoder.forward(params, x, skips)
    assert y.shape == (1, 32, 32, 3)
    y_naive = unet_decoder.forward(params, x, skips, decomposed=False)
    y_pal = unet_decoder.forward(params, x, skips, backend="pallas")
    assert_allclose(np.asarray(y_naive), np.asarray(y), rtol=1e-5, atol=1e-5)
    assert np.abs(np.asarray(y_pal) - np.asarray(y)).max() <= 1e-5


@pytest.mark.slow
def test_dcgan128_forward_parity():
    """The 128x128 generator chains one more k=4/s=2 stage (5 deep)."""
    params = dcgan.init_params(jax.random.PRNGKey(4), size=128, nz=8, ngf=2)
    z = jax.random.normal(jax.random.PRNGKey(5), (1, 8))
    y = dcgan.forward(params, z)
    assert y.shape == (1, 128, 128, 3)
    y_pal = dcgan.forward(params, z, backend="pallas")
    assert np.abs(np.asarray(y_pal) - np.asarray(y)).max() <= 1e-5


# ---------------------------------------------------------- gradient parity ---

def _dcgan_loss(params, z, backend):
    return jnp.mean(dcgan.forward(params, z, backend=backend) ** 2)


def _unet_loss(params, x, skips, backend):
    return jnp.mean(unet_decoder.forward(params, x, skips,
                                         backend=backend) ** 2)


def test_dcgan_grad_parity(dcgan_setup):
    params, z = dcgan_setup
    lx, gx = jax.value_and_grad(lambda p: _dcgan_loss(p, z, "xla"))(params)
    lp, gp = jax.value_and_grad(lambda p: _dcgan_loss(p, z, "pallas"))(params)
    assert float(lx) == pytest.approx(float(lp), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(gp),
                    jax.tree_util.tree_leaves(gx)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5)


def test_unet_decoder_grad_parity(unet_setup):
    params, x, skips = unet_setup
    lx, gx = jax.value_and_grad(
        lambda p: _unet_loss(p, x, skips, "xla"))(params)
    lp, gp = jax.value_and_grad(
        lambda p: _unet_loss(p, x, skips, "pallas"))(params)
    assert float(lx) == pytest.approx(float(lp), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(gp),
                    jax.tree_util.tree_leaves(gx)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5)


def test_dcgan_grad_flows_to_all_params(dcgan_setup):
    params, z = dcgan_setup
    grads = jax.grad(lambda p: _dcgan_loss(p, z, "xla"))(params)
    norms = {k: sum(float(jnp.linalg.norm(leaf))
                    for leaf in jax.tree_util.tree_leaves(g))
             for k, g in grads.items()}
    assert all(np.isfinite(n) for n in norms.values()), norms
    # every conv kernel and the projection receive signal
    assert all(n > 0 for k, n in norms.items()
               if k == "proj" or k.startswith(("up", "head"))), norms


# ------------------------------------------------- spec-table consistency ---

def test_dcgan_spec_mirrors_model():
    """gen_spec's layer table records exactly the convs the model executes:
    same kernels, channels and output extents, at full canonical widths."""
    for size in (64, 128):
        params = dcgan.init_params(jax.random.PRNGKey(0), size=size)
        layers = gen_spec.dcgan_layers(size)
        tconvs = [l for l in layers if l.kind == "transposed"]
        # chained upsampling covers 4x4 -> size with exact-2x stages
        assert tconvs[0].h_out == 8 and tconvs[-1].h_out == size
        for i, l in enumerate(tconvs):
            w = params["head" if i == len(tconvs) - 1 else f"up{i + 1}"]
            assert w.shape == (l.kh, l.kw, l.cin, l.cout)
            assert (l.stride, l.padding, l.output_padding) == (2, 2, 0)
        proj = layers[0]
        assert params["proj"].shape == (proj.cin,
                                        proj.h_out * proj.w_out * proj.cout)


def test_unet_spec_mirrors_model():
    widths = gen_spec.UNET_WIDTHS
    params = unet_decoder.init_params(jax.random.PRNGKey(0), widths=widths)
    layers = gen_spec.unet_decoder_layers(widths)
    tconvs = [l for l in layers if l.kind == "transposed"]
    assert [l.kh for l in tconvs] == list(gen_spec.UNET_UP_KERNELS)
    for i, l in enumerate(tconvs):
        assert params[f"l{i}_up"].shape == (l.kh, l.kw, l.cin, l.cout)
        assert l.padding == l.kh // 2 and l.output_padding == 0
    convs = [l for l in layers if l.kind == "conv"]
    for i in range(len(widths)):
        assert params[f"l{i}_conv1"].shape[2] == 2 * widths[i]  # skip concat
    assert params["head"].shape == (3, 3, widths[-1] // 2, 3)
    assert convs[-1].h_out == 8 * 2 ** len(widths)


def test_group_norm_fold_matches_affine():
    """fold_gn is the identity-statistics fold of the group_norm oracle: on
    an input that is already per-group normalized the two agree exactly."""
    from repro.models.common import fold_gn, gn_init, group_norm

    key = jax.random.PRNGKey(7)
    p = gn_init(16)
    p["g"] = jax.random.normal(key, (16,))
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 64, 64, 16))
    # normalize per group first -> statistics are (0, 1) -> fold == oracle
    xg = x.reshape(2, 64, 64, 8, 2)
    xg = (xg - jnp.mean(xg, (1, 2, 4), keepdims=True)) \
        * jax.lax.rsqrt(jnp.var(xg, (1, 2, 4), keepdims=True) + 1e-5)
    xn = xg.reshape(2, 64, 64, 16)
    sc, sh = fold_gn(p)
    assert_allclose(np.asarray(xn * sc + sh),
                    np.asarray(group_norm(p, xn, groups=8)),
                    rtol=1e-4, atol=1e-4)
