"""Shared test fixtures.

The autotune cache is machine-global state (``~/.cache/repro-autotune``);
tests and the benchmark helpers some tests invoke must never write noise
timings there, so every test session gets a throwaway cache directory.

The ``mesh``-marked multi-device tests (DESIGN.md §13: sharded train
parity, mesh serving, failover drills) need a simulated multi-device CPU
client.  That session is OPT-IN:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest -q -m mesh

(what the CI mesh step and the README quickstart run); ``REPRO_FAKE_DEVICES=1``
below merges the flag in for convenience.  It is deliberately NOT forced on
the whole tier-1 session: a long-lived 8-fake-device client segfaults XLA's
CPU compiler a few hundred compilations in (reproducibly, deep in
``backend_compile``), while the short ``-m mesh`` session is fine.  Without
the flag the ``mesh_devices`` fixture skips the mesh tier cleanly.
"""

import os

if os.environ.get("REPRO_FAKE_DEVICES"):
    _flag = "--xla_force_host_platform_device_count=8"
    _prev = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _prev:
        os.environ["XLA_FLAGS"] = f"{_prev} {_flag}".strip()

import pytest  # noqa: E402


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_executables():
    """Unmap each module's compiled executables when the module finishes.

    Every jitted computation XLA compiles stays mmapped for the life of the
    process; across the full one-process suite (~1000 tests, thousands of
    compilations) that walks straight into the kernel's default
    ``vm.max_map_count`` (65530) and XLA's CPU compiler SEGFAULTS mid-
    ``backend_compile``.  Dropping the jit caches at module teardown bounds
    the live map count by the heaviest single module instead of the whole
    suite.  Caches are performance-only state — later modules recompile
    what they share, which costs seconds, not correctness.
    """
    yield
    import sys

    jax = sys.modules.get("jax")
    if jax is not None:
        jax.clear_caches()


@pytest.fixture(scope="session")
def mesh_devices():
    """Device count available to ``mesh``-marked tests; skips the test when
    the session opted out of fake devices and real ones are scarce."""
    import jax

    n = len(jax.devices())
    if n < 2:
        pytest.skip(
            "multi-device mesh tests need >= 2 devices (run with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            "or REPRO_FAKE_DEVICES=1)")
    return n


@pytest.fixture(autouse=True, scope="session")
def _isolated_autotune_cache(tmp_path_factory):
    import os

    from repro.kernels import autotune

    prev = os.environ.get("REPRO_AUTOTUNE_CACHE")
    os.environ["REPRO_AUTOTUNE_CACHE"] = str(
        tmp_path_factory.mktemp("autotune-cache"))
    autotune.clear_memory_cache()
    yield
    if prev is None:
        os.environ.pop("REPRO_AUTOTUNE_CACHE", None)
    else:
        os.environ["REPRO_AUTOTUNE_CACHE"] = prev
    autotune.clear_memory_cache()
