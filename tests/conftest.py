"""Shared test fixtures.

The autotune cache is machine-global state (``~/.cache/repro-autotune``);
tests and the benchmark helpers some tests invoke must never write noise
timings there, so every test session gets a throwaway cache directory.
"""

import pytest


@pytest.fixture(autouse=True, scope="session")
def _isolated_autotune_cache(tmp_path_factory):
    import os

    from repro.kernels import autotune

    prev = os.environ.get("REPRO_AUTOTUNE_CACHE")
    os.environ["REPRO_AUTOTUNE_CACHE"] = str(
        tmp_path_factory.mktemp("autotune-cache"))
    autotune.clear_memory_cache()
    yield
    if prev is None:
        os.environ.pop("REPRO_AUTOTUNE_CACHE", None)
    else:
        os.environ["REPRO_AUTOTUNE_CACHE"] = prev
    autotune.clear_memory_cache()
