"""Mixed-precision (bf16) contract tests (DESIGN.md §12).

Four claims under test:

* **engine parity** — every decomposition engine (dense / dilated / tconv)
  run with ``compute_dtype="bf16"`` returns bf16 outputs within the
  documented tolerance of the fp32 run (forward: 5% of the output range;
  gradients: 10% relative L2), on both backends, and the two backends
  agree with each other *in* bf16.
* **loss scaling** — the dynamic scaler backs off and skips on non-finite
  gradients, grows after the interval, clamps at its bounds, and a skipped
  recipe step leaves params + optimizer state bit-identical.
* **tiling policy** — the analytic score is dtype- and epilogue-aware,
  over-budget candidates never win, and the policy's timed set always
  contains ``DEFAULT_TILES`` — so a tune() under the policy can never do
  worse than the baseline tiling, and agrees with the exhaustive sweep
  whenever the sweep's winner is in the policy set.
* **dtype plumbing** — ``compute_dtype`` aliases resolve in one place
  (``canon_dtype``), model forwards and the DDIM gen step return bf16 for
  bf16 compute, and the generative server serves a bf16 lane end to end.
"""

import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decompose import conv2d
from repro.kernels import autotune as at
from repro.kernels import tiling_policy as tp
from repro.kernels.epilogue import EpilogueSpec
from repro.kernels.util import canon_dtype
from repro.launch import train_recipes
from repro.launch.steps import make_gen_step
from repro.models import dcgan, enet, espnet, unet_decoder
from repro.optim import DynamicLossScale, select_tree

# the benchmarks package lives at the repo root (pytest's pythonpath only
# covers src/); one module-level insert serves the policy-vs-sweep test
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

#: documented bf16-vs-fp32 tolerances (DESIGN.md §12): forward outputs
#: within 5% of the fp32 output range, gradients within 10% relative L2
FWD_RTOL = 0.05
GRAD_RTOL = 0.10

#: (kind, conv2d kwargs) for the three decomposition engines
ENGINES = (
    ("dense", dict()),
    ("dilated", dict(dilation=2)),
    ("tconv", dict(transposed=True, stride=2)),
)


def _xw(cin=4, cout=8, hw=10, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (1, hw, hw, cin), jnp.float32)
    w = jax.random.normal(k2, (3, 3, cin, cout), jnp.float32) * 0.3
    return x, w


def _assert_fwd_close(out16, ref32, rtol=FWD_RTOL):
    assert out16.dtype == jnp.bfloat16
    diff = jnp.max(jnp.abs(out16.astype(jnp.float32) - ref32))
    scale = jnp.max(jnp.abs(ref32))
    assert bool(jnp.isfinite(out16.astype(jnp.float32)).all())
    assert float(diff) <= rtol * float(scale) + 1e-3, \
        f"bf16 drifted {float(diff):.4f} vs range {float(scale):.4f}"


# ------------------------------------------------------------ engines ------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("kind,kw", ENGINES, ids=[k for k, _ in ENGINES])
def test_engine_bf16_forward_parity(kind, kw, backend):
    """bf16 in -> bf16 out, within tolerance of fp32, on both backends."""
    x, w = _xw()
    ref = conv2d(x, w, backend=backend, **kw)
    out = conv2d(x, w, backend=backend, compute_dtype="bf16", **kw)
    assert ref.dtype == jnp.float32          # fp32 path untouched
    _assert_fwd_close(out, ref)


@pytest.mark.parametrize("kind,kw", ENGINES, ids=[k for k, _ in ENGINES])
def test_engine_bf16_grad_parity(kind, kw):
    """Gradients through the bf16 pallas engines track the fp32 gradients
    (fp32 accumulators keep the backward pass from compounding rounding)."""
    x, w = _xw()

    def loss(w_, cd):
        out = conv2d(x, w_, backend="pallas", compute_dtype=cd, **kw)
        return jnp.mean(jnp.square(out.astype(jnp.float32)))

    g32 = jax.grad(lambda w_: loss(w_, None))(w)
    g16 = jax.grad(lambda w_: loss(w_, "bf16"))(w)
    assert g16.dtype == jnp.float32          # grads land on the fp32 master
    assert bool(jnp.isfinite(g16).all())
    rel = jnp.linalg.norm(g16 - g32) / (jnp.linalg.norm(g32) + 1e-9)
    assert float(rel) <= GRAD_RTOL, f"grad drift {float(rel):.4f}"


@pytest.mark.parametrize("kind,kw", ENGINES, ids=[k for k, _ in ENGINES])
def test_engine_bf16_cross_backend_parity(kind, kw):
    """pallas-bf16 and xla-bf16 agree — same decomposition, fp32 accum."""
    x, w = _xw(seed=1)
    a = conv2d(x, w, backend="pallas", compute_dtype="bf16", **kw)
    b = conv2d(x, w, backend="xla", compute_dtype="bf16", **kw)
    assert a.dtype == b.dtype == jnp.bfloat16
    diff = jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))
    scale = jnp.max(jnp.abs(b.astype(jnp.float32)))
    assert float(diff) <= 0.02 * float(scale) + 1e-3


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("kind,kw", (
    ("dense", dict(stride=2)),
    ("dilated", dict(dilation=3)),
    ("tconv", dict(transposed=True, stride=2, output_padding=1)),
    ("tconv", dict(transposed=True, stride=3)),
), ids=["dense-s2", "dilated-d3", "tconv-s2op1", "tconv-s3"])
def test_engine_bf16_parity_full_grid(kind, kw, backend):
    """Wider geometry grid for the same parity claim (slow lane)."""
    x, w = _xw(cin=8, cout=16, hw=24, seed=2)
    ref = conv2d(x, w, backend=backend, **kw)
    out = conv2d(x, w, backend=backend, compute_dtype="bf16", **kw)
    _assert_fwd_close(out, ref)


# ------------------------------------------------------- dtype plumbing ----

def test_canon_dtype_aliases():
    assert canon_dtype(None) is None
    assert canon_dtype("bf16") == jnp.bfloat16
    assert canon_dtype("bfloat16") == jnp.bfloat16
    assert canon_dtype("fp32") == jnp.float32
    assert canon_dtype(jnp.bfloat16) == jnp.bfloat16
    with pytest.raises(ValueError):
        canon_dtype("int7")


def test_model_forwards_return_bf16():
    """compute_dtype="bf16" pins the output dtype of every workload model
    while the fp32 master params are left untouched."""
    key = jax.random.PRNGKey(0)
    img = jax.random.normal(key, (1, 16, 16, 3), jnp.float32)

    p = enet.init_params(key, num_classes=4)
    out = enet.forward(p, img, compute_dtype="bf16")
    assert out.dtype == jnp.bfloat16 and out.shape[-1] == 4
    assert p["initial"].dtype == jnp.float32

    p = espnet.init_params(key, num_classes=4)
    out = espnet.forward(p, img, compute_dtype="bf16")
    assert out.dtype == jnp.bfloat16 and out.shape[-1] == 4

    p = dcgan.init_params(key, size=64, nz=8, ngf=8)
    out = dcgan.forward(p, jax.random.normal(key, (2, 8)),
                        compute_dtype="bf16")
    assert out.dtype == jnp.bfloat16 and out.shape == (2, 64, 64, 3)
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())


def test_gen_step_keeps_lane_dtype():
    """A bf16 diffusion lane stays bf16-resident across DDIM ticks, and the
    inactive-slot freeze is bitwise in bf16 too."""
    params = unet_decoder.init_denoiser_params(jax.random.PRNGKey(0),
                                               widths=(8, 8))
    step = jax.jit(make_gen_step(compute_dtype="bf16"))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 16, 3),
                          jnp.float32).astype(jnp.bfloat16)
    x0 = np.asarray(x.astype(jnp.float32))
    batch = {"t": jnp.array([500, 400], jnp.int32),
             "t_next": jnp.array([250, -1], jnp.int32),
             "active": jnp.array([True, False])}
    y = step(params, x, batch)
    assert y.dtype == jnp.bfloat16
    yf = np.asarray(y.astype(jnp.float32))
    assert np.isfinite(yf).all()
    np.testing.assert_array_equal(yf[1], x0[1])     # frozen slot
    assert not np.array_equal(yf[0], x0[0])          # active slot advanced


def test_gen_server_serves_bf16_lane():
    """End-to-end: a GenServer built with compute_dtype="bf16" drains
    requests to finite images and round-trips the dtype through snapshots."""
    from repro.launch.serve_gen import GenServer

    params = unet_decoder.init_denoiser_params(jax.random.PRNGKey(0),
                                               widths=(8, 8))
    srv = GenServer(batch=2, unet_widths=(8, 8), unet_hw=4,
                    params={"unet_dec": params}, compute_dtype="bf16")
    rids = [srv.submit("unet_dec", steps=2, seed=i) for i in range(2)]
    images = srv.run()
    for rid in rids:
        assert np.isfinite(np.asarray(images[rid], np.float32)).all()
    # admission estimates fall back to the fp32 calibration fit for bf16
    est = srv.admission_estimate("unet_dec", steps=2)
    assert est is None or est > 0
    assert srv._snapshot_config()["compute_dtype"] == "bf16"


# ---------------------------------------------------------- loss scaler ----

def test_loss_scale_backoff_and_growth():
    sc = DynamicLossScale(init_scale=8.0, growth_interval=2)
    st = sc.init()
    assert float(st.scale) == 8.0
    st = sc.update(st, jnp.asarray(False))            # overflow: backoff
    assert float(st.scale) == 4.0 and int(st.good_steps) == 0
    st = sc.update(st, jnp.asarray(True))             # 1 good step: hold
    assert float(st.scale) == 4.0 and int(st.good_steps) == 1
    st = sc.update(st, jnp.asarray(True))             # interval hit: grow
    assert float(st.scale) == 8.0 and int(st.good_steps) == 0


def test_loss_scale_clamps():
    sc = DynamicLossScale(init_scale=1.0, min_scale=1.0, max_scale=2.0,
                          growth_interval=1)
    st = sc.init()
    st = sc.update(st, jnp.asarray(False))
    assert float(st.scale) == 1.0                     # floor holds
    st = sc.update(st, jnp.asarray(True))
    st = sc.update(st, jnp.asarray(True))
    assert float(st.scale) == 2.0                     # ceiling holds


def test_loss_scale_round_trip_and_finiteness():
    sc = DynamicLossScale(init_scale=2.0 ** 10)
    st = sc.init()
    grads = {"a": jnp.array([1e-3, -2.0]), "b": jnp.array([[0.5]])}
    scaled = jax.tree_util.tree_map(lambda g: g * st.scale, grads)
    back = sc.unscale(st, scaled)
    for k in grads:
        np.testing.assert_allclose(back[k], grads[k], rtol=1e-6)
    assert bool(sc.all_finite(grads))
    assert not bool(sc.all_finite({"a": jnp.array([1.0, jnp.nan])}))
    assert not bool(sc.all_finite({"a": jnp.array([jnp.inf])}))
    assert bool(sc.all_finite({}))                    # empty tree is finite


def test_select_tree_is_bitwise():
    a = {"w": jnp.array([1.0, 2.0])}
    b = {"w": jnp.array([3.0, 4.0])}
    np.testing.assert_array_equal(
        select_tree(jnp.asarray(False), a, b)["w"], b["w"])
    np.testing.assert_array_equal(
        select_tree(jnp.asarray(True), a, b)["w"], a["w"])


# -------------------------------------------------------------- recipes ----

def _seg_batch(key, classes=4, hw=16):
    k1, k2 = jax.random.split(key)
    return {"image": jax.random.normal(k1, (1, hw, hw, 3), jnp.float32),
            "label": jax.random.randint(k2, (1, hw, hw), 0, classes)}


def test_recipe_bf16_step_matches_fp32():
    """One ESPNet step in bf16 lands near the fp32 step: same loss (5%) and
    gradient norm (10%), no skip, untouched scale."""
    key = jax.random.PRNGKey(0)
    params = espnet.init_params(key, num_classes=4)
    batch = _seg_batch(jax.random.PRNGKey(1))
    losses, gnorms = {}, {}
    for cd in (None, "bf16"):
        step = train_recipes.make_train_step("espnet", compute_dtype=cd)
        state, metrics = step(train_recipes.init_state(params), batch)
        assert float(metrics["skipped"]) == 0.0
        assert float(metrics["scale"]) == DynamicLossScale().init_scale
        assert bool(jnp.isfinite(metrics["loss"]))
        losses[cd], gnorms[cd] = (float(metrics["loss"]),
                                  float(metrics["grad_norm"]))
        # masters stay fp32 through the update
        assert state.params["stem"].dtype == jnp.float32
    assert abs(losses["bf16"] / losses[None] - 1) <= FWD_RTOL
    assert abs(gnorms["bf16"] / gnorms[None] - 1) <= GRAD_RTOL


def test_recipe_skips_on_nonfinite_batch():
    """A NaN batch must not move params, optimizer state, or the AdamW step
    counter — the scaler backs off and reports the skip."""
    key = jax.random.PRNGKey(0)
    params = espnet.init_params(key, num_classes=4)
    state0 = train_recipes.init_state(params)
    batch = _seg_batch(jax.random.PRNGKey(1))
    batch["image"] = batch["image"].at[0, 0, 0, 0].set(jnp.nan)
    step = train_recipes.make_train_step("espnet", compute_dtype="bf16")
    state1, metrics = step(state0, batch)
    assert float(metrics["skipped"]) == 1.0
    assert float(metrics["grad_norm"]) == 0.0
    assert float(metrics["scale"]) == DynamicLossScale().init_scale / 2
    for p0, p1 in zip(jax.tree_util.tree_leaves(state0.params),
                      jax.tree_util.tree_leaves(state1.params)):
        np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))
    for o0, o1 in zip(jax.tree_util.tree_leaves(state0.opt),
                      jax.tree_util.tree_leaves(state1.opt)):
        np.testing.assert_array_equal(np.asarray(o0), np.asarray(o1))


def test_recipe_dcgan_bf16_smoke():
    key = jax.random.PRNGKey(0)
    params = dcgan.init_params(key, size=64, nz=8, ngf=8)
    batch = {"z": jax.random.normal(key, (2, 8)),
             "target": jnp.zeros((2, 64, 64, 3), jnp.float32)}
    step = train_recipes.make_train_step("dcgan", compute_dtype="bf16")
    state, metrics = step(train_recipes.init_state(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["skipped"]) == 0.0
    with pytest.raises(ValueError):
        train_recipes.make_train_step("vgg")


# -------------------------------------------------------- tiling policy ----

_POLICY_GEOM = dict(x_shape=(1, 64, 64, 16), w_shape=(3, 3, 16, 64))


def test_footprint_is_dtype_and_epilogue_aware():
    fp32 = tp.footprint_bytes("dense", **_POLICY_GEOM, th=8, tc=64)
    bf16 = tp.footprint_bytes("dense", **_POLICY_GEOM, th=8, tc=64,
                              dtype=jnp.bfloat16)
    assert bf16 < fp32                  # halved streams; fp32 acc shared
    fused = tp.footprint_bytes("dense", **_POLICY_GEOM, th=8, tc=64,
                               epilogue=EpilogueSpec(residual="post_act"))
    assert fused > fp32                 # the residual streams a second block
    # occupancy is a fraction, and bf16's deeper sublane packing never helps
    # a tile that fp32 already fills
    occ = tp.mxu_occupancy("dense", **_POLICY_GEOM, th=8, tc=64)
    assert 0 < occ <= 1.0


def test_rank_marks_over_budget_candidates_inf():
    cands = [(4, 64), (8, 64), (8, 128)]
    ranked = tp.rank("dense", **_POLICY_GEOM, cands=cands, vmem_budget=1)
    assert all(math.isinf(s) for s, _ in ranked)
    # and top_candidates degrades to the full sweep rather than guessing
    assert tp.top_candidates("dense", **_POLICY_GEOM, cands=cands,
                             vmem_budget=1) == cands
    with pytest.raises(ValueError):
        tp.rank("conv3d", **_POLICY_GEOM, cands=cands)


def test_top_candidates_keeps_default_and_order():
    cands = at.candidates(h_out=64, cout=512)
    keep = tp.top_candidates("dense", (1, 64, 64, 16), (3, 3, 16, 512),
                             cands, top=at.POLICY_TOP,
                             default_tiles=at.DEFAULT_TILES)
    assert len(keep) <= at.POLICY_TOP + 1
    assert at.DEFAULT_TILES in keep
    assert keep == [c for c in cands if c in keep]    # sweep order preserved
    # forcing the sweep returns the grid unchanged
    os.environ["REPRO_AUTOTUNE_SWEEP"] = "1"
    try:
        assert tp.top_candidates("dense", (1, 64, 64, 16), (3, 3, 16, 512),
                                 cands) == cands
    finally:
        del os.environ["REPRO_AUTOTUNE_SWEEP"]


def test_policy_tune_agrees_with_sweep_on_default_winner(tmp_path,
                                                         monkeypatch):
    """When the true winner is DEFAULT_TILES, the policy tune and the
    exhaustive sweep pick the SAME tiles — the default always rides, so the
    policy can never lose to the baseline tiling."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path))
    at.clear_memory_cache()
    cands = at.candidates(h_out=64, cout=512)
    cost = {c: 5.0 - 4.0 * (c == at.DEFAULT_TILES) for c in cands}
    monkeypatch.setattr(at, "_build_call",
                        lambda kind, x, w, th, tc, *a, **k: (th, tc))
    monkeypatch.setattr(at, "_time_candidate",
                        lambda call, iters: cost[call])
    geom = dict(x_shape=(1, 64, 64, 16), w_shape=(3, 3, 16, 512))
    policy_pick = at.tune("dense", **geom, cands=cands, iters=1)
    monkeypatch.setenv("REPRO_AUTOTUNE_SWEEP", "1")
    sweep_pick = at.tune("dense", **geom, cands=cands, iters=1)
    assert policy_pick == sweep_pick == at.DEFAULT_TILES
    at.clear_memory_cache()


@pytest.mark.slow
def test_policy_vs_sweep_measured():
    """The benchmark-grade comparison on real wall times: the policy's pick
    stays within 50% of the exhaustive winner on the smoke geometries (the
    committed trajectory tracks the tighter 1.05 acceptance bar)."""
    from benchmarks.mixed_precision import policy_vs_sweep

    for kind, r in policy_vs_sweep(iters=2).items():
        assert r["n_timed_policy"] <= at.POLICY_TOP + 1
        # the policy only thins grids bigger than its timed set
        if r["n_candidates"] > at.POLICY_TOP + 1:
            assert r["n_timed_policy"] < r["n_candidates"]
        assert r["agree"] or r["time_ratio"] <= 1.5, (kind, r)
