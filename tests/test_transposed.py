"""Property + unit tests: weight decomposition for transposed convolutions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import transposed as tr


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


@pytest.mark.parametrize("h,w", [(3, 3), (8, 8), (13, 9)])
@pytest.mark.parametrize("output_padding", [0, 1])
def test_decomposed_matches_reference_s2k3(h, w, output_padding):
    key = jax.random.PRNGKey(h * 10 + w)
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (2, h, w, 3))
    wgt = _rand(k2, (3, 3, 3, 4))
    ref = tr.transposed_conv2d_reference(x, wgt, 2, 1, output_padding)
    got = tr.transposed_conv2d_decomposed(x, wgt, 2, 1, output_padding)
    assert got.shape == ref.shape
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_naive_matches_reference():
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (1, 6, 6, 2))
    wgt = _rand(k2, (3, 3, 2, 2))
    ref = tr.transposed_conv2d_reference(x, wgt, 2, 1, 1)
    got = tr.transposed_conv2d_naive(x, wgt, 2, 1, 1)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_paper_fig5_output_size():
    """3x3 input, 3x3 kernel, s=2, p=1 -> 5x5 output (paper Fig. 5)."""
    x = jnp.ones((1, 3, 3, 1))
    w = jnp.ones((3, 3, 1, 1))
    out = tr.transposed_conv2d_decomposed(x, w, 2, 1)
    assert out.shape == (1, 5, 5, 1)


def test_paper_fig6_subkernel_shapes():
    """s=2, k=3, p=1 decomposes into center 1x1, 1x2, 2x1, corners 2x2 (Fig. 6)."""
    w = jnp.arange(9, dtype=jnp.float32).reshape(3, 3, 1, 1)
    subs = tr.decompose_weight(w, 2, 1)
    shapes = {r: (None if e is None else e[0].shape[:2]) for r, e in subs.items()}
    assert shapes[(0, 0)] == (1, 1)   # center tap w[1,1]
    assert shapes[(0, 1)] == (1, 2)   # horizontal endpoints w[1,{0,2}]
    assert shapes[(1, 0)] == (2, 1)   # vertical endpoints
    assert shapes[(1, 1)] == (2, 2)   # four corners
    sub, _, _ = subs[(0, 0)]
    assert float(sub[0, 0, 0, 0]) == 4.0  # w[1,1] is the center element


# parametrized grid over the same (shape, stride, kernel, output_padding)
# space the former hypothesis property test sampled from
@pytest.mark.parametrize("h,w", [(2, 5), (8, 8), (13, 9), (16, 3)])
@pytest.mark.parametrize("s", [2, 3, 4])
@pytest.mark.parametrize("k", [2, 3, 4, 5])
@pytest.mark.parametrize("output_padding", [0, 1])
def test_grid_decomposition_exact(h, w, s, k, output_padding):
    p = (k - 1) // 2
    cin, cout = (h % 3) + 1, (w % 3) + 1
    key = jax.random.PRNGKey(h * 512 + w * 16 + s * 4 + k)
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (1, h, w, cin))
    wgt = _rand(k2, (k, k, cin, cout))
    ref = tr.transposed_conv2d_reference(x, wgt, s, p, output_padding)
    if 0 in ref.shape:
        pytest.skip("degenerate size combination")
    got = tr.transposed_conv2d_decomposed(x, wgt, s, p, output_padding)
    assert got.shape == ref.shape
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_mac_counts_match_parity_sum():
    """Naive does k*k MACs per output; decomposed does only live-tap MACs.

    For s=2,k=3 interiors: avg live taps/output = (1+2+2+4)/4 = 9/4 -> 4x skip.
    """
    h = w = 64
    naive = tr.macs_naive(h, w, 8, 8, 3, 2, 1, 2)
    dec = tr.macs_decomposed_transposed(h, w, 8, 8, 3, 2, 1, 2)
    assert 3.9 < naive / dec < 4.1


def test_grad_flows_through_decomposition():
    """Decomposed op is differentiable (needed to train ENet with it)."""
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (1, 5, 5, 2))
    wgt = _rand(k2, (3, 3, 2, 2))

    def loss(w_):
        return jnp.sum(tr.transposed_conv2d_decomposed(x, w_, 2, 1, 1) ** 2)

    g = jax.grad(loss)(wgt)
    assert g.shape == wgt.shape
    assert bool(jnp.all(jnp.isfinite(g)))

    def loss_ref(w_):
        return jnp.sum(tr.transposed_conv2d_reference(x, w_, 2, 1, 1) ** 2)

    g_ref = jax.grad(loss_ref)(wgt)
    assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-4, atol=1e-4)
