"""Calibration layer + perf gate (DESIGN.md §10).

Covers the closed-form fit (round-trip on synthetic data, degenerate-case
clamping), the prediction-error report schema the bench JSON carries, the
calibrated consumers (tile scoring, serving admission estimates, calibrated
``serve_report`` keys), and the drift gate itself — it must fail on a
doctored baseline and pass on identical data.
"""

import json

import pytest

from benchmarks import perf_gate as pg
from repro.core import calibrate as cal
from repro.core import cycle_model as cm


# ------------------------------------------------------------------ fitting --

def _samples(a=0.5, b=10.0, kind="dense", backend="xla", dev="testdev",
             cycles=(1e3, 5e3, 2e4, 1e5)):
    return [cal.Sample(kind, backend, dev, f"s{i}", c, a * c + b)
            for i, c in enumerate(cycles)]


def test_fit_round_trips_synthetic_affine():
    calib = cal.Calibration.fit(_samples(a=0.5, b=10.0))
    co = calib.coeffs[cal.key_of("dense", "xla", "testdev")]
    assert co.a_us_per_cycle == pytest.approx(0.5)
    assert co.b_us == pytest.approx(10.0)
    assert co.n == 4
    assert calib.predict("dense", 2e4, backend="xla",
                         device_kind="testdev") == pytest.approx(1.001e4)


def test_fit_single_sample_is_origin_slope():
    calib = cal.Calibration.fit(_samples(a=2.0, b=0.0, cycles=(1e4,)))
    co = calib.coeffs[cal.key_of("dense", "xla", "testdev")]
    assert co.a_us_per_cycle == pytest.approx(2.0)
    assert co.b_us == 0.0 and co.n == 1


def test_fit_clamps_negative_intercept():
    # noisy tiny-op data that LS would fit with b < 0: refit through origin
    ss = [cal.Sample("dense", "xla", "d", "a", 10.0, 1.0),
          cal.Sample("dense", "xla", "d", "b", 20.0, 30.0)]
    co = cal.Calibration.fit(ss).coeffs[cal.key_of("dense", "xla", "d")]
    assert co.b_us == 0.0 and co.a_us_per_cycle >= 0.0


def test_fit_zero_samples_raises():
    with pytest.raises(ValueError):
        cal._fit_one([])


def test_key_of_rejects_unknown_kind():
    with pytest.raises(ValueError):
        cal.key_of("conv3d", "xla", "d")


def test_save_load_round_trip(tmp_path):
    calib = cal.Calibration.fit(_samples())
    p = tmp_path / "cal.json"
    calib.save(p)
    loaded = cal.Calibration.load(p)
    assert loaded.to_payload() == calib.to_payload()


def test_fit_key_separates_dtypes():
    """Schema-2 regression pin: fp32 and bf16 measurements of the same
    (kind, backend, device) must fit under DISTINCT keys with their own
    slopes.  Pre-fix the key had no dtype segment, so bf16 samples were
    pooled into the fp32 fit and every prediction was dtype-blind.
    """
    s32 = _samples(a=0.5, b=10.0)
    s16 = [cal.Sample("dense", "xla", "testdev", f"b{i}", c, 0.25 * c + 10.0,
                      dtype="bfloat16")
           for i, c in enumerate((1e3, 5e3, 2e4, 1e5))]
    calib = cal.Calibration.fit(s32 + s16)
    k32 = cal.key_of("dense", "xla", "testdev")
    k16 = cal.key_of("dense", "xla", "testdev", "bfloat16")
    assert k32 != k16
    assert calib.coeffs[k32].a_us_per_cycle == pytest.approx(0.5)
    assert calib.coeffs[k16].a_us_per_cycle == pytest.approx(0.25)
    assert calib.predict("dense", 2e4, backend="xla", device_kind="testdev",
                         dtype="bfloat16") == pytest.approx(0.25 * 2e4 + 10)


def test_schema1_payload_upgrades_to_float32_keys():
    """A pre-dtype (schema-1) cache loads with its 3-segment keys mapped to
    ``.../float32`` — old on-disk calibrations stay usable after the fix."""
    calib = cal.Calibration.fit(_samples(a=0.5, b=10.0))
    payload = calib.to_payload()
    assert payload["schema"] == 2
    legacy = {"schema": 1,
              "coeffs": {"dense/xla/testdev":
                         payload["coeffs"][cal.key_of("dense", "xla",
                                                      "testdev")]}}
    loaded = cal.Calibration.from_payload(legacy)
    assert set(loaded.coeffs) == {cal.key_of("dense", "xla", "testdev")}
    assert loaded.predict("dense", 2e4, backend="xla",
                          device_kind="testdev") == pytest.approx(1.001e4)


def test_unfitted_dtype_falls_back_to_fp32_fit():
    """bf16 predictions fall back to the fp32 fit (a conservative upper
    bound) instead of refusing, when only fp32 was captured."""
    calib = cal.Calibration.fit(_samples(a=0.5, b=10.0))
    assert calib.predict("dense", 2e4, backend="xla", device_kind="testdev",
                         dtype="bfloat16") == pytest.approx(1.001e4)
    assert calib.predict("dense", 2e4, backend="pallas",
                         device_kind="testdev", dtype="bfloat16") is None


# ------------------------------------------------------------ error report --

def test_error_report_schema_and_perfect_fit():
    ss = _samples(a=1e-3, b=2.0)
    rep = cal.Calibration.fit(ss).error_report(ss)
    key = cal.key_of("dense", "xla", "testdev")
    assert set(rep) == {key}
    e = rep[key]
    assert set(e) >= {"a_us_per_cycle", "b_us", "n", "samples",
                      "mape_pct", "max_abs_err_pct"}
    assert len(e["samples"]) == len(ss)
    assert set(e["samples"][0]) == {"name", "cycles", "us", "pred_us",
                                    "err_pct"}
    # exact affine data: the fit reproduces every sample
    assert e["mape_pct"] == pytest.approx(0.0, abs=0.01)


def test_error_report_skips_unfitted_keys():
    calib = cal.Calibration.fit(_samples(kind="dense"))
    rep = calib.error_report(_samples(kind="tconv"))
    assert rep == {}


# --------------------------------------------------------------- consumers --

def _full_calibration(a=1e-3, b=5.0, backend="xla"):
    """Coeffs for every engine kind on THIS host's device key."""
    return cal.Calibration({cal.key_of(k, backend): cal.Coeffs(a, b, 3)
                            for k in cal.KINDS})


def test_predict_layers_sums_and_gates_on_coverage():
    from repro.core.gen_spec import dcgan_layers

    layers = dcgan_layers(64)
    calib = _full_calibration(a=1e-3, b=5.0)
    us = calib.predict_layers(layers, backend="xla")
    expect = sum(1e-3 * cm.cycles_our_decomposed(l) + 5.0 for l in layers)
    assert us == pytest.approx(expect)
    # a partially-fitted calibration must refuse, not undercount
    partial = cal.Calibration(
        {cal.key_of("dense", "xla"): cal.Coeffs(1e-3, 5.0, 3)})
    assert partial.predict_layers(layers, backend="xla") is None


def test_serve_report_calibrated_keys():
    from repro.core.gen_spec import dcgan_layers

    layers = dcgan_layers(64)
    calib = _full_calibration()
    rep = cm.serve_report(layers, steps=4, calibration=calib)
    assert rep["calibrated_us_per_image"] == pytest.approx(
        4 * calib.predict_layers(layers, backend="xla"))
    assert rep["calibrated_images_per_s"] > 0
    assert "calibrated_us_per_image" not in cm.serve_report(layers, steps=4)


def test_gen_server_admission_estimate():
    from repro.launch.serve_gen import GenServer

    srv = GenServer(batch=1, backend="xla", calibration=_full_calibration())
    est = srv.admission_estimate("dcgan64", 1)
    assert est is not None and est > 0
    assert srv.admission_estimate("unet_dec", 5) == pytest.approx(
        5 * srv.admission_estimate("unet_dec", 1))
    # no calibration / partial calibration: no estimate rather than zero cost
    assert GenServer(batch=1).admission_estimate("dcgan64") is None


def test_tile_scores_prefers_coverage_and_weights_overhead():
    cands = [(4, 64), (8, 64), (8, 128)]
    ranked = cal.tile_scores(16, 8, cands)
    # same padded fraction for tc=64 at cout=8; fewer grid cells wins
    assert ranked[0][1] == (8, 64)
    assert [c for _, c in ranked] == [(8, 64), (4, 64), (8, 128)]
    # h_out=20: th=4 covers exactly (5 cells), th=8 pads 20->24 (3 cells).
    # with the default tiny cell weight the exact-cover tile wins ...
    cands = [(4, 64), (8, 64)]
    assert cal.tile_scores(20, 8, cands)[0][1] == (4, 64)
    # ... but on a dispatch-dominated host (huge fitted b_us relative to the
    # modeled compute time) the calibrated score flips to fewest cells
    heavy = cal.Calibration(
        {cal.key_of("dense", "xla"): cal.Coeffs(1e-6, 1e6, 3)})
    ranked = cal.tile_scores(20, 8, cands, kind="dense", backend="xla",
                             base_cycles=1e4, calibration=heavy)
    assert ranked[0][1] == (8, 64)


def test_capture_case_layer_round_trip():
    case = cal.CaptureCase("tconv", (1, 16, 16, 8), (3, 3, 8, 8), stride=2)
    l = cal.layer_of(case)
    assert l.kind == "transposed" and (l.h_out, l.w_out) == (32, 32)
    assert cal.modeled_cycles(case) == cm.cycles_our_decomposed(l)
    dense = cal.CaptureCase("dense", (2, 16, 16, 8), (3, 3, 8, 8), stride=2)
    ld = cal.layer_of(dense)
    assert (ld.h_out, ld.w_out) == (8, 8)
    assert cal.modeled_cycles(dense) == 2 * cm.cycles_our_decomposed(ld)


# ---------------------------------------------------------------- perf gate --

def _bench_payload(model_val=2.5, ratio=0.9, slope=1e-3, mape=5.0):
    return {
        "rev": "abc", "backend": "cpu", "device_kind": "cpu",
        "rows": [
            {"name": "fig12.L128.speedup_x", "us_per_call": 1.0,
             "derived": f"{model_val}"},
            {"name": "kern.dilated_D3.naive", "us_per_call": 10.0,
             "derived": ""},   # wall row without a derived number: untracked
        ],
        "ratios": {"fused_unfused": {"kern.epilogue_dense.fused": ratio}},
        "calibration": {
            "fit": {"schema": 1, "coeffs": {
                "dense/xla/cpu": {"a_us_per_cycle": slope, "b_us": 1.0,
                                  "n": 3}}},
            "errors": {"dense/xla/cpu": {"mape_pct": mape}},
        },
    }


def test_gate_passes_on_identical_payloads():
    p = _bench_payload()
    violations, _ = pg.compare(p, _bench_payload())
    assert violations == []


def test_gate_fails_on_model_drift():
    violations, _ = pg.compare(_bench_payload(model_val=2.6),
                               _bench_payload(model_val=2.5))
    assert any("fig12.L128.speedup_x" in v for v in violations)
    # within the 1% band: no violation
    violations, _ = pg.compare(_bench_payload(model_val=2.51),
                               _bench_payload(model_val=2.5))
    assert violations == []


def test_gate_fails_on_vanished_entry():
    cur = _bench_payload()
    cur["rows"] = []
    violations, _ = pg.compare(cur, _bench_payload())
    assert any("missing from current" in v for v in violations)


def test_gate_ratio_tolerance_is_loose():
    violations, _ = pg.compare(_bench_payload(ratio=1.4),
                               _bench_payload(ratio=0.9))
    assert violations == []     # 56% drift < 75% tol: wall noise tolerated
    violations, _ = pg.compare(_bench_payload(ratio=9.0),
                               _bench_payload(ratio=0.9))
    assert any("[ratio]" in v for v in violations)


def test_gate_mape_growth_is_one_sided():
    violations, _ = pg.compare(_bench_payload(mape=25.0),
                               _bench_payload(mape=5.0))
    assert any("[calib_mape]" in v for v in violations)
    # improvement never fails
    violations, _ = pg.compare(_bench_payload(mape=0.5),
                               _bench_payload(mape=5.0))
    assert violations == []


def test_gate_skips_wall_families_across_hosts():
    cur = _bench_payload(ratio=9.0, mape=90.0, slope=1.0)
    cur["device_kind"] = "TPU v4"
    violations, notes = pg.compare(cur, _bench_payload())
    assert violations == []     # model family alone applies cross-host
    assert any("skipped" in n for n in notes)


def test_gate_main_exit_codes(tmp_path, monkeypatch, capsys):
    cur = tmp_path / "BENCH_cur.json"
    base = tmp_path / "base.json"
    cur.write_text(json.dumps(_bench_payload()))
    base.write_text(json.dumps(_bench_payload()))
    args = ["--current", str(cur), "--baseline", str(base)]
    assert pg.main(args) == 0
    # doctored baseline: the gate must catch it
    base.write_text(json.dumps(_bench_payload(model_val=99.0)))
    assert pg.main(args) == 1
    # no baseline committed yet: bootstrap pass
    assert pg.main(["--current", str(cur),
                    "--baseline", str(tmp_path / "nope.json")]) == 0
    # no current bench anywhere: distinct error code
    monkeypatch.chdir(tmp_path / "..")
    empty = tmp_path / "empty"
    empty.mkdir()
    monkeypatch.chdir(empty)
    assert pg.main(["--baseline", str(base)]) == 2
    capsys.readouterr()


def test_gate_extract_covers_all_families():
    e = pg.extract(_bench_payload())
    assert e["model"] == {"fig12.L128.speedup_x": 2.5}
    assert e["ratio"] == {"fused_unfused/kern.epilogue_dense.fused": 0.9}
    assert e["calib_slope"] == {"dense/xla/cpu": 1e-3}
    assert e["calib_mape"] == {"dense/xla/cpu": 5.0}
