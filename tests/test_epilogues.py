"""Fused-epilogue parity (DESIGN.md §7): for every engine and spec shape,
the fused kernel computes exactly ``unfused kernel + apply_reference`` —
forward and gradients, pallas and xla.

Three pins per (engine, spec) cell:

* **fused == unfused + reference** on the pallas engine (the kernel applies
  the epilogue on the fp32 accumulator in VMEM; the reference applies it as
  separate jnp passes);
* **pallas == xla** through the dispatcher (the xla backend applies the
  identical reference oracle post-conv);
* **gradient parity** across backends for all operands, including the
  epilogue's own (``scale``/``shift``/``alpha``/``residual``) — the fused
  VJP differentiates by adjoint re-entry (``adjoints.fused_epilogue_bwd``).

The fast subset runs in tier-1; the full cross grid is ``slow``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.decompose import conv2d
from repro.kernels import ops
from repro.kernels.epilogue import EpilogueSpec, apply_reference, pack_args

SPECS = {
    "bn": EpilogueSpec(bn=True),
    "prelu": EpilogueSpec(prelu=True),
    "bn_act": EpilogueSpec(bn=True, prelu=True),
    "bn_res_act": EpilogueSpec(bn=True, prelu=True, residual="pre_act"),
    "act_res": EpilogueSpec(prelu=True, residual="post_act"),
    "res": EpilogueSpec(residual="post_act"),
}

# (name, conv kwargs, x shape, w shape) — engine geometries
FAST_GEOMS = [
    ("dense_s1", dict(), (1, 9, 8, 3), (3, 3, 3, 5)),
    ("dilated_d2", dict(dilation=2), (1, 10, 9, 3), (3, 3, 3, 4)),
    ("tconv_s2", dict(stride=2, transposed=True, output_padding=1),
     (1, 5, 6, 3), (3, 3, 3, 4)),
]
SLOW_GEOMS = [
    ("dense_s2", dict(stride=2), (1, 9, 8, 3), (3, 3, 3, 4)),
    ("dilated_d3", dict(dilation=3), (1, 12, 11, 3), (3, 3, 3, 4)),
    ("dilated_d2_s2", dict(dilation=2, stride=2), (1, 12, 10, 3), (3, 3, 3, 4)),
    ("tconv_s2_k2", dict(stride=2, transposed=True, output_padding=0),
     (1, 6, 5, 3), (2, 2, 3, 4)),
    ("tconv_s3_k5", dict(stride=3, transposed=True, output_padding=1),
     (1, 5, 5, 2), (5, 5, 2, 3)),
    ("tconv_s4_k2", dict(stride=4, transposed=True, output_padding=1),
     (1, 4, 5, 2), (2, 2, 2, 3)),   # k < s: zero conv planes, live epilogue
]
FAST_SPECS = ["bn_act", "bn_res_act"]


def _operands(spec: EpilogueSpec, kw, xs, ws):
    """Deterministic epilogue operands for one (spec, geometry) cell."""
    keys = jax.random.split(jax.random.PRNGKey(sum(xs) + sum(ws)), 6)
    x = jax.random.normal(keys[0], xs, jnp.float32)
    w = jax.random.normal(keys[1], ws, jnp.float32)
    cout = ws[-1]
    out_shape = jax.eval_shape(
        lambda x, w: conv2d(x, w, **kw), x, w).shape
    full = {
        "scale": jax.random.normal(keys[2], (cout,)) * 0.3 + 1.0,
        "shift": jnp.linspace(-0.7, 0.7, cout),
        "alpha": jnp.full((1,), 0.25),
        "residual": jax.random.normal(keys[3], out_shape),
    }
    return x, w, {k: full[k] for k in spec.slots}


def _fused_vs_reference(geom, spec_name):
    _, kw, xs, ws = geom
    spec = SPECS[spec_name]
    x, w, eops = _operands(spec, kw, xs, ws)
    fused = conv2d(x, w, backend="pallas", epilogue=spec, **eops, **kw)
    z = conv2d(x, w, backend="pallas", **kw)
    want = apply_reference(spec, z, pack_args(spec, **eops))
    assert fused.shape == want.shape
    assert_allclose(np.asarray(fused), np.asarray(want), rtol=2e-5, atol=2e-5)
    via_xla = conv2d(x, w, backend="xla", epilogue=spec, **eops, **kw)
    assert_allclose(np.asarray(fused), np.asarray(via_xla),
                    rtol=5e-5, atol=5e-5)


def _grad_parity(geom, spec_name):
    _, kw, xs, ws = geom
    spec = SPECS[spec_name]
    x, w, eops = _operands(spec, kw, xs, ws)
    names = list(eops)

    def loss(backend):
        def f(x, w, *ev):
            y = conv2d(x, w, backend=backend, epilogue=spec,
                       **dict(zip(names, ev)), **kw)
            return jnp.sum(jnp.sin(y))
        return f

    argnums = tuple(range(2 + len(names)))
    gs_x = jax.grad(loss("xla"), argnums)(x, w, *eops.values())
    gs_p = jax.grad(loss("pallas"), argnums)(x, w, *eops.values())
    for name, a, b in zip(["x", "w", *names], gs_p, gs_x):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4,
                        err_msg=f"{geom[0]}/{spec_name}/d{name}")


@pytest.mark.parametrize("spec_name", FAST_SPECS)
@pytest.mark.parametrize("geom", FAST_GEOMS, ids=lambda g: g[0])
def test_fused_equals_reference_fast(geom, spec_name):
    _fused_vs_reference(geom, spec_name)


@pytest.mark.slow
@pytest.mark.parametrize("spec_name", sorted(SPECS))
@pytest.mark.parametrize("geom", FAST_GEOMS + SLOW_GEOMS, ids=lambda g: g[0])
def test_fused_equals_reference_grid(geom, spec_name):
    _fused_vs_reference(geom, spec_name)


@pytest.mark.parametrize("spec_name", FAST_SPECS)
@pytest.mark.parametrize("geom", FAST_GEOMS, ids=lambda g: g[0])
def test_gradient_parity_fast(geom, spec_name):
    _grad_parity(geom, spec_name)


@pytest.mark.slow
@pytest.mark.parametrize("spec_name", sorted(SPECS))
@pytest.mark.parametrize("geom", FAST_GEOMS + SLOW_GEOMS, ids=lambda g: g[0])
def test_gradient_parity_grid(geom, spec_name):
    _grad_parity(geom, spec_name)


def test_epilogue_zero_planes_not_skipped():
    """k < s transposed parities have zero conv output but a LIVE epilogue
    (BN shift / residual must land there too)."""
    spec = SPECS["bn_res_act"]
    kw = dict(stride=4, transposed=True, output_padding=1)
    x, w, eops = _operands(spec, kw, (1, 4, 4, 2), (2, 2, 2, 3))
    fused = conv2d(x, w, backend="pallas", epilogue=spec, **eops, **kw)
    want = conv2d(x, w, backend="xla", epilogue=spec, **eops, **kw)
    assert_allclose(np.asarray(fused), np.asarray(want), rtol=2e-5, atol=2e-5)
    # the k=2, s=4 schedule leaves parities 1 and 2 with no live tap: their
    # conv output is identically zero, but the fused output must still carry
    # the epilogue there (residual + shift) — pin that it is not zero
    z = conv2d(x, w, backend="pallas", **kw)
    zero_plane = np.asarray(z)[:, 1::4, 1::4, :]
    assert np.abs(zero_plane).max() == 0.0
    assert np.abs(np.asarray(fused)[:, 1::4, 1::4, :]).max() > 0.0


def test_bf16_fused_epilogue():
    """bf16 in/out with the epilogue applied on the fp32 accumulator."""
    spec = SPECS["bn_act"]
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 8, 4), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 8), jnp.bfloat16)
    sc = jnp.ones((8,)); sh = jnp.zeros((8,)); al = jnp.full((1,), 0.25)
    got = ops.conv2d(x, w, epilogue=spec, scale=sc, shift=sh, alpha=al)
    z = ops.conv2d(x, w)
    want = apply_reference(spec, z, (sc, sh, al))
    assert got.dtype == jnp.bfloat16
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    rtol=3e-2, atol=3e-2)


def test_per_channel_alpha():
    """PReLU slope may be scalar or per-channel."""
    spec = SPECS["prelu"]
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 8, 4))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 6))
    al = jnp.linspace(0.1, 0.9, 6)
    got = ops.conv2d(x, w, epilogue=spec, alpha=al)
    want = apply_reference(spec, ops.conv2d(x, w), (al,))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_pack_args_validation():
    spec = EpilogueSpec(bn=True)
    with pytest.raises(ValueError, match="requires operand"):
        pack_args(spec, scale=jnp.ones((4,)))          # shift missing
    with pytest.raises(ValueError, match="does not take"):
        pack_args(spec, scale=jnp.ones((4,)), shift=jnp.zeros((4,)),
                  alpha=jnp.ones((1,)))
    with pytest.raises(ValueError, match="residual"):
        EpilogueSpec(residual="sideways")


def test_residual_shape_mismatch_raises():
    spec = EpilogueSpec(residual="post_act")
    x = jnp.zeros((1, 8, 8, 4))
    w = jnp.zeros((3, 3, 4, 4))
    with pytest.raises((ValueError, TypeError)):
        jax.block_until_ready(ops.conv2d(
            x, w, epilogue=spec, residual=jnp.zeros((1, 3, 3, 4))))


# ------------------------------------------------- rectangular kernels ---

@pytest.mark.parametrize("ks", [(5, 1), (1, 5), (3, 2)])
def test_rectangular_dense_kernel(ks):
    """ENet's asymmetric pair no longer falls back to lax under pallas."""
    kh, kw = ks
    x = jax.random.normal(jax.random.PRNGKey(kh), (1, 10, 11, 3))
    w = jax.random.normal(jax.random.PRNGKey(kw), (kh, kw, 3, 5))
    got = conv2d(x, w, backend="pallas")
    want = conv2d(x, w, backend="xla")
    assert got.shape == want.shape == (1, 10, 11, 5)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_rectangular_dense_gradients():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 9, 9, 2))
    w = jax.random.normal(jax.random.PRNGKey(1), (5, 1, 2, 3))

    def loss(backend):
        return lambda x, w: jnp.sum(jnp.sin(conv2d(x, w, backend=backend)))

    gx_x, gw_x = jax.grad(loss("xla"), (0, 1))(x, w)
    gx_p, gw_p = jax.grad(loss("pallas"), (0, 1))(x, w)
    assert_allclose(np.asarray(gx_p), np.asarray(gx_x), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(gw_p), np.asarray(gw_x), rtol=1e-4, atol=1e-4)


def test_rectangular_fused_epilogue():
    spec = SPECS["bn_act"]
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (1, 5, 3, 4))
    sc = jnp.ones((4,)) * 1.5; sh = jnp.full((4,), -0.2); al = jnp.full((1,), 0.1)
    got = conv2d(x, w, backend="pallas", epilogue=spec, scale=sc, shift=sh,
                 alpha=al)
    want = conv2d(x, w, backend="xla", epilogue=spec, scale=sc, shift=sh,
                  alpha=al)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
