"""AdamW correctness vs a NumPy reference + schedule/memory-mode behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.optim.schedules import linear_warmup


def _np_adamw(w, g, m, v, step, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1 ** step)
    vhat = v / (1 - b2 ** step)
    w = w - lr * (mhat / (np.sqrt(vhat) + eps) + wd * w)
    return w, m, v


def test_adamw_matches_numpy_reference():
    params = {"a": jnp.array([1.0, -2.0, 3.0], jnp.float32),
              "b": jnp.array([[0.5, 0.5]], jnp.float32)}
    grads = {"a": jnp.array([0.1, 0.2, -0.3], jnp.float32),
             "b": jnp.array([[0.01, -0.02]], jnp.float32)}
    state = adamw_init(params)
    # grads norm < 1 -> no clipping
    new_params, new_state, gnorm = adamw_update(
        grads, state, params, lr=jnp.float32(1e-2))
    for k in params:
        w, m, v = _np_adamw(np.asarray(params[k]), np.asarray(grads[k]),
                            np.zeros_like(params[k]),
                            np.zeros_like(params[k]), 1, 1e-2)
        assert_allclose(np.asarray(new_params[k]), w, rtol=1e-6)
        assert_allclose(np.asarray(new_state.mu[k]), m, rtol=1e-6)
        assert_allclose(np.asarray(new_state.nu[k]), v, rtol=1e-6)


def test_gradient_clipping():
    params = {"a": jnp.zeros((4,), jnp.float32)}
    grads = {"a": jnp.full((4,), 100.0, jnp.float32)}  # norm 200 >> 1
    state = adamw_init(params)
    _, _, gnorm = adamw_update(grads, state, params, lr=jnp.float32(0.1),
                               clip_norm=1.0)
    assert float(gnorm) == pytest.approx(200.0)


def test_bf16_memory_mode():
    params = {"a": jnp.ones((8,), jnp.bfloat16)}
    state = adamw_init(params, memory_mode="bf16")
    assert state.master is None
    assert state.mu["a"].dtype == jnp.bfloat16
    grads = {"a": jnp.full((8,), 0.01, jnp.bfloat16)}
    new_params, new_state, _ = adamw_update(grads, state, params,
                                            lr=jnp.float32(1e-2))
    assert new_params["a"].dtype == jnp.bfloat16
    assert new_state.master is None
    assert bool(jnp.all(new_params["a"] != params["a"]))


def test_steps_converge_quadratic():
    """AdamW should minimise a simple quadratic."""
    params = {"w": jnp.array([5.0, -3.0], jnp.float32)}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params,
                                        lr=jnp.float32(0.1),
                                        weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_schedules():
    assert float(linear_warmup(0, 100, 1.0)) == pytest.approx(0.01)
    assert float(linear_warmup(99, 100, 1.0)) == pytest.approx(1.0)
    peak = float(cosine_schedule(100, 100, 1000, 3e-4))
    end = float(cosine_schedule(1000, 100, 1000, 3e-4))
    assert peak == pytest.approx(3e-4, rel=0.02)
    assert end == pytest.approx(0.1 * 3e-4, rel=0.02)  # floor
