"""Golden tests pinning the cycle model to the paper's published figures.

The abstract claims the decomposition "can cut down 87.8% of the cycle
counts to achieve 8.2X speedup over a naive execution for the ENet case".
These tests freeze that reproduction so cycle-model refactors cannot
silently drift off the paper:

* **headline** — per-group cycle ratios normalized by the paper's own
  Fig. 10 workload mix must recover 8.2x (±5%) and ≥87% reduction
  (see ``cycle_model.headline`` for why the mix normalization is the
  honest pinning);
* **Fig. 11** — per-dilation-rate efficiency vs ideal sparse must sit in
  the published 83–98% band and fall monotonically with D;
* **Fig. 12** — per-output-size transposed efficiency must reach 99% at
  512 and degrade only marginally with tiling;
* the ESPNet workload and the training-cost extension ride on the same
  harness so they are pinned from birth.
"""

import pytest

from repro.core import cycle_model as cm
from repro.core.enet_spec import (
    dilated_layer_sets, enet_512_layers, transposed_layer_sets,
)
from repro.core.espnet_spec import espnet_512_layers
from repro.core.gen_spec import dcgan_layers, unet_decoder_layers

# the benchmarks package lives at the repo root (pytest's pythonpath only
# covers src/); one module-level insert serves every benchmark-harness test
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

PAPER_SPEEDUP = 8.2
PAPER_REDUCTION_PCT = 87.8


@pytest.fixture(scope="module")
def enet():
    return enet_512_layers()


@pytest.fixture(scope="module")
def espnet():
    return espnet_512_layers()


# ------------------------------------------------------------- headline ---

def test_headline_speedup_within_5pct(enet):
    hl = cm.headline(enet)
    assert PAPER_SPEEDUP * 0.95 <= hl["speedup"] <= PAPER_SPEEDUP * 1.05, hl


def test_headline_cycle_reduction(enet):
    hl = cm.headline(enet)
    assert hl["cycle_reduction_pct"] >= 87.0
    assert abs(hl["cycle_reduction_pct"] - PAPER_REDUCTION_PCT) <= 2.0


def test_headline_group_ratios(enet):
    """The per-group ratios behind the headline (Fig. 10's 2/2/9 vs 85/7/8)."""
    r = cm.headline(enet)["group_ratios"]
    assert r["dilated"] == pytest.approx(2 / 85, rel=0.20)     # 85% -> ~2%
    assert r["transposed"] == pytest.approx(2 / 7, rel=0.15)   # 7%  -> ~2%
    assert 1.05 <= r["general"] <= 1.20                        # 8%  -> ~9%


def test_naive_array_baseline(enet):
    """The zero-laden schedule on the same array costs MORE than ideal dense
    (utilization losses), and the decomposition still wins >7x against it."""
    rep = cm.report(enet)
    assert rep["naive_cycles"] >= rep["ideal_dense_cycles"]
    assert 7.0 <= rep["speedup_vs_naive"] <= 9.0
    assert 85.0 <= rep["cycle_reduction_vs_naive_pct"] <= 90.0


def test_honest_inventory_bands(enet):
    """The full honest ENet inventory (no mix normalization) stays in the
    band the seed established — a drift alarm, not a paper claim."""
    rep = cm.report(enet)
    assert 6.0 <= rep["overall_speedup"] <= 9.0
    assert 82.0 <= rep["cycle_reduction_pct"] <= 90.0


# ------------------------------------------------------ Fig. 11 (dilated) ---

FIG11_BANDS = {1: (0.95, 0.99), 3: (0.93, 0.98), 7: (0.88, 0.95),
               15: (0.83, 0.88)}


def test_fig11_efficiency_bands(enet):
    effs = {}
    for D, ls in dilated_layer_sets(enet).items():
        effs[D] = (sum(cm.cycles_ideal_sparse(l) for l in ls)
                   / sum(cm.cycles_our_decomposed(l) for l in ls))
    assert set(effs) == set(FIG11_BANDS)
    for D, (lo, hi) in FIG11_BANDS.items():
        assert lo <= effs[D] <= hi, (D, effs[D])
    assert effs[1] > effs[3] > effs[7] > effs[15]   # paper: falls with D


def test_fig11_speedup_rises_with_D(enet):
    sps = {D: (sum(cm.cycles_ideal_dense(l) for l in ls)
               / sum(cm.cycles_our_decomposed(l) for l in ls))
           for D, ls in dilated_layer_sets(enet).items()}
    assert sps[1] < sps[3] < sps[7] < sps[15]
    # ~ (2D+3)^2/9 x efficiency: pin the endpoints
    assert sps[1] == pytest.approx(2.8, rel=0.10)
    assert sps[15] == pytest.approx(121 * 0.833 / 0.69, rel=0.15)


# --------------------------------------------------- Fig. 12 (transposed) ---

def test_fig12_transposed_bands(enet):
    effs = {sz: (sum(cm.cycles_ideal_sparse(l) for l in ls)
                 / sum(cm.cycles_our_decomposed(l) for l in ls))
            for sz, ls in transposed_layer_sets(enet).items()}
    assert set(effs) == {128, 256, 512}
    assert effs[512] >= 0.97                        # paper: "up to 99%"
    assert all(e >= 0.88 for e in effs.values())
    assert effs[128] < effs[256] < effs[512]        # tiling loss shrinks


# -------------------------------------------------------- ESPNet workload ---

def test_espnet_is_dilated_dominated(espnet):
    """The spatial pyramid makes ESPNet even more dilated-heavy than ENet."""
    rep = cm.report(espnet)
    assert rep["share_dilated_pct"] >= 80.0
    assert rep["share_transposed_pct"] >= 3.0


def test_espnet_overall_speedup(espnet):
    rep = cm.report(espnet)
    assert 7.5 <= rep["overall_speedup"] <= 10.0
    assert 8.0 <= rep["speedup_vs_naive"] <= 11.0


def test_espnet_dilated_bands(espnet):
    """Small mixed rates (2/4/8) sample the top of the Fig. 11 band, and the
    strided down-ESP branches go through the output-class schedule."""
    effs = {}
    for D, ls in dilated_layer_sets(espnet).items():
        assert any(l.stride == 2 for l in ls)       # strided branch present
        effs[D] = (sum(cm.cycles_ideal_sparse(l) for l in ls)
                   / sum(cm.cycles_our_decomposed(l) for l in ls))
    assert set(effs) == {1, 3, 7}
    assert all(0.90 <= e <= 0.99 for e in effs.values())
    assert effs[1] > effs[3] > effs[7]


# ------------------------------------------- generative decoder workloads ---
#
# EcoFlow's argument, pinned: the weight decomposition matters most where
# transposed convolutions dominate — GAN generators and diffusion decoders,
# not segmentation decoder tails.  Bands computed from the gen_spec tables
# (mirroring the fig11 pattern: cycle bands + an executable MAC-skip
# cross-check from each layer set's own geometry).

@pytest.fixture(scope="module")
def dcgan64():
    return dcgan_layers(64)


@pytest.fixture(scope="module")
def dcgan128():
    return dcgan_layers(128)


@pytest.fixture(scope="module")
def unet_dec():
    return unet_decoder_layers()


def _tconv_mac_skip(layers):
    """naive/decomposed MAC ratio from each layer's own geometry — the SAME
    helper the fig12 benchmark emits, so the golden pin and the benchmark
    row cannot drift apart."""
    from benchmarks.fig12_transposed_layers import _tconv_mac_skip as skip

    return skip(layers)


def test_dcgan_is_transposed_dominated(dcgan64, dcgan128, enet):
    """>99% of generator cycles are transposed conv — the whole net runs on
    the weight decomposition, vs ENet's ~5% decoder tail."""
    for layers in (dcgan64, dcgan128):
        rep = cm.report(layers)
        assert rep["share_transposed_pct"] >= 99.0
        assert rep["share_dilated_pct"] == 0.0
    assert cm.report(enet)["share_transposed_pct"] <= 10.0


def test_dcgan_reduction_bands(dcgan64, dcgan128):
    """Pinned bands: the k=4/s=2 chains cut ~72% of the naive-array cycles
    (s**2 = 4x MAC skip, minus the input-tiling and boundary losses that
    dominate at the 4x4/8x8 ends of the chain)."""
    for layers, lo_sp in ((dcgan64, 3.4), (dcgan128, 3.4)):
        rep = cm.report(layers)
        assert lo_sp <= rep["speedup_vs_naive"] <= 3.9, rep
        assert 70.0 <= rep["cycle_reduction_vs_naive_pct"] <= 75.0, rep
        assert 2.3 <= rep["transposed_speedup"] <= 2.9, rep


def test_dcgan_mac_skip_is_exactly_s_squared(dcgan64, dcgan128, unet_dec):
    """Exact-2x even-kernel geometry gives every parity (k/s)**2 live taps,
    so the executable MAC skip is exactly s**2 = 4 for all three workloads —
    the cross-check that the spec tables record the true geometry."""
    for layers in (dcgan64, dcgan128, unet_dec):
        assert _tconv_mac_skip(layers) == pytest.approx(4.0, rel=1e-9)


def test_dcgan_boundary_loss_shrinks_with_size(dcgan64, dcgan128):
    """Transposed efficiency vs ideal sparse improves with extent (the
    Fig. 12 trend, sampled at generative 4..128 extents where the boundary
    taps of p_lo=2 actually bite)."""

    def eff(layers):
        g = cm.summarize(layers)
        return g["transposed"].cycles_sparse / g["transposed"].cycles_ours

    assert 0.50 <= eff(dcgan64) <= 0.60
    assert 0.55 <= eff(dcgan128) <= 0.66
    assert eff(dcgan64) < eff(dcgan128)


def test_unet_decoder_bands(unet_dec):
    """The mixed conv/tconv decoder: transposed is ~half the cycle share and
    the decomposition still removes ~30% of the naive-array cycles."""
    rep = cm.report(unet_dec)
    assert 40.0 <= rep["share_transposed_pct"] <= 55.0
    assert 1.3 <= rep["speedup_vs_naive"] <= 1.6
    assert 26.0 <= rep["cycle_reduction_vs_naive_pct"] <= 34.0
    assert 2.6 <= rep["transposed_speedup"] <= 3.0


def test_generative_training_report(dcgan64, unet_dec):
    """The fwd+bwd extension holds for the generative workloads too: the
    adjoint of a k=4/s=2 upsample is a strided dense conv at the input
    extent, so training keeps a transposed-class win."""
    for layers in (dcgan64, unet_dec):
        t = cm.training_report(layers)
        assert t["train_speedup_vs_naive"] >= 1.2
        assert t["train_cycles"] > t["fwd_cycles"] > 0


def test_ecoflow_share_ordering(dcgan64, unet_dec, enet, espnet):
    """The weight decomposition's leverage orders exactly as EcoFlow argues:
    generator >> diffusion decoder >> segmentation nets."""
    share = {id(l): cm.report(l)["share_transposed_pct"]
             for l in (dcgan64, unet_dec, enet, espnet)}
    assert share[id(dcgan64)] > share[id(unet_dec)] > share[id(enet)]
    assert share[id(dcgan64)] > share[id(unet_dec)] > share[id(espnet)]


# --------------------------------------------- training-cost extension ---

def test_training_speedup_carries_to_backward(enet, espnet):
    """EcoFlow's observation: the backward pass is itself dilated/transposed
    convolutions, so the decomposition accelerates training, not just
    inference — the fwd+bwd speedup stays within ~15% of forward-only."""
    for layers in (enet, espnet):
        tr = cm.training_report(layers)
        assert tr["bwd_speedup_vs_naive"] >= 5.0
        assert tr["train_speedup_vs_naive"] >= 0.85 * tr["fwd_speedup_vs_naive"]
        assert tr["train_cycles"] > tr["fwd_cycles"] > 0


def test_adjoint_layer_classes(enet):
    """The adjoint symmetry at the spec level: transposed -> strided dense at
    the input extent; dilated -> dilated; channels always swap."""
    for l in enet:
        a = cm.adjoint_layer(l)
        assert (a.cin, a.cout) == (l.cout, l.cin)
        if l.kind == "transposed":
            assert a.kind == "conv"
            assert (a.h_out, a.w_out) == cm.tconv_input_size(l)
        elif l.kind == "dilated":
            assert a.kind == "dilated" and a.D == l.D


# ----------------------------------------------------- benchmark harness ---

def test_fig10_and_fig11_benchmarks_run():
    """The figure benchmarks stay executable and emit the golden rows."""
    from benchmarks import fig10_enet_speedup, fig11_dilated_layers

    rows10 = {name: val for name, _, val in fig10_enet_speedup.run(csv=True)}
    assert "fig10.headline_speedup_x" in rows10
    assert float(rows10["fig10.headline_speedup_x"].split()[0]) >= 7.7
    rows11 = [name for name, _, _ in fig11_dilated_layers.run(csv=True)]
    assert any(n.startswith("fig11.enet.D15") for n in rows11)
    assert any(n.startswith("fig11.espnet.D7") for n in rows11)


def test_fig12_benchmark_emits_generative_rows():
    """fig12 carries the generative workload rows (they ride into the
    BENCH_<rev>.json artifact through benchmarks/run.py)."""
    from benchmarks import fig12_transposed_layers

    rows = {name: val for name, _, val in fig12_transposed_layers.run(csv=True)}
    for wl in ("dcgan64", "dcgan128", "unet_dec"):
        assert f"fig12.{wl}.speedup_vs_naive_x" in rows
        assert float(rows[f"fig12.{wl}.mac_skip_ratio"]) == pytest.approx(4.0)
    assert float(rows["fig12.dcgan64.share_transposed_pct"]) >= 99.0
    assert float(rows["fig12.L512.eff_vs_sparse_pct"]) >= 97.0  # paper band
