"""Launch layer: shape cells, input specs, skip logic, mesh construction."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.shapes import SHAPES, cell_supported, input_specs


def test_shape_cells_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_skips_documented():
    """long_500k runs exactly for the sub-quadratic archs."""
    runs = {a for a in ARCH_IDS
            if cell_supported(get_config(a), "long_500k")[0]}
    assert runs == {"jamba-1.5-large-398b", "gemma3-12b", "xlstm-1.3b"}
    ok, reason = cell_supported(get_config("qwen3-32b"), "long_500k")
    assert not ok and "full-attention" in reason


def test_input_specs_are_abstract():
    for arch in ("qwen3-32b", "whisper-small", "jamba-1.5-large-398b"):
        cfg = get_config(arch)
        for shape in SHAPES:
            specs = input_specs(cfg, shape)
            for v in jax.tree.leaves(specs):
                assert isinstance(v, jax.ShapeDtypeStruct)  # no allocation
        t = input_specs(cfg, "train_4k")
        assert t["tokens"].shape == (256, 4096)
        if cfg.encoder_layers:
            assert t["frames"].shape == (256, cfg.encoder_ctx, cfg.d_model)


def test_decode_specs():
    cfg = get_config("gemma3-12b")
    d = input_specs(cfg, "decode_32k")
    assert d["token"].shape == (128, 1)
    assert d["cache_pos"].shape == ()


def test_mesh_factories_are_lazy():
    """Importing mesh.py must not touch jax device state (spec requirement)."""
    import importlib

    import repro.launch.mesh as m
    importlib.reload(m)  # would raise if module-level device access existed
    assert callable(m.make_production_mesh)


def test_default_microbatches_scale():
    from repro.launch.steps import default_microbatches

    assert default_microbatches(get_config("stablelm-1.6b")) == 2
    assert default_microbatches(get_config("qwen3-32b")) == 4
    assert default_microbatches(get_config("jamba-1.5-large-398b")) == 8
