"""Launch layer: shape cells, input specs, skip logic, mesh construction,
and the LM server's parallel-vs-sequential prefill parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.launch.shapes import SHAPES, cell_supported, input_specs


def test_shape_cells_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_skips_documented():
    """long_500k runs exactly for the sub-quadratic archs."""
    runs = {a for a in ARCH_IDS
            if cell_supported(get_config(a), "long_500k")[0]}
    assert runs == {"jamba-1.5-large-398b", "gemma3-12b", "xlstm-1.3b"}
    ok, reason = cell_supported(get_config("qwen3-32b"), "long_500k")
    assert not ok and "full-attention" in reason


def test_input_specs_are_abstract():
    for arch in ("qwen3-32b", "whisper-small", "jamba-1.5-large-398b"):
        cfg = get_config(arch)
        for shape in SHAPES:
            specs = input_specs(cfg, shape)
            for v in jax.tree.leaves(specs):
                assert isinstance(v, jax.ShapeDtypeStruct)  # no allocation
        t = input_specs(cfg, "train_4k")
        assert t["tokens"].shape == (256, 4096)
        if cfg.encoder_layers:
            assert t["frames"].shape == (256, cfg.encoder_ctx, cfg.d_model)


def test_decode_specs():
    cfg = get_config("gemma3-12b")
    d = input_specs(cfg, "decode_32k")
    assert d["token"].shape == (128, 1)
    assert d["cache_pos"].shape == ()


def test_mesh_factories_are_lazy():
    """Importing mesh.py must not touch jax device state (spec requirement)."""
    import importlib

    import repro.launch.mesh as m
    importlib.reload(m)  # would raise if module-level device access existed
    assert callable(m.make_production_mesh)


def test_default_microbatches_scale():
    from repro.launch.steps import default_microbatches

    assert default_microbatches(get_config("stablelm-1.6b")) == 2
    assert default_microbatches(get_config("qwen3-32b")) == 4
    assert default_microbatches(get_config("jamba-1.5-large-398b")) == 8


def test_parallel_prefill_matches_sequential_loop():
    """The prefill fix: ONE multi-token serve_step call produces the same
    caches and next token as the token-by-token decode loop."""
    from repro.launch.serve import Server

    srv = Server(get_reduced("stablelm-1.6b"), max_len=16)
    assert srv.parallel_prefill_ok()
    toks = np.random.default_rng(0).integers(0, 256, (2, 6), dtype=np.int32)
    tok_par, caches_par, pos_par = srv.prefill(toks)
    tok_seq, caches_seq, pos_seq = srv.prefill(toks, slow=True)
    assert pos_par == pos_seq == 6
    assert np.array_equal(np.asarray(tok_par), np.asarray(tok_seq))
    for a, b in zip(jax.tree.leaves(caches_par), jax.tree.leaves(caches_seq)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)   # bf16 caches


def test_parallel_prefill_gating():
    """Sliding-window / recurrent-mixer configs keep the sequential loop."""
    from repro.launch.serve import parallel_prefill_ok

    assert parallel_prefill_ok(get_reduced("stablelm-1.6b"))
    assert not parallel_prefill_ok(get_reduced("gemma3-12b"))   # attn_local
    assert not parallel_prefill_ok(get_reduced("xlstm-1.3b"))   # recurrent
    assert not parallel_prefill_ok(get_reduced("whisper-small"))  # enc-dec
    assert not parallel_prefill_ok(get_reduced("jamba-1.5-large-398b"))


def test_forced_parallel_prefill_rejected_on_gated_config():
    """slow=False must not silently corrupt one-token-at-a-time caches."""
    from repro.launch.serve import Server

    srv = Server(get_reduced("gemma3-12b"), max_len=8)
    toks = np.zeros((1, 4), dtype=np.int32)
    with pytest.raises(ValueError, match="parallel prefill unsupported"):
        srv.prefill(toks, slow=False)
