"""Validate the cycle model against the paper's reported claims (§III).

Tolerances: the paper's technique-specific numbers (dilated/transposed
speedups, efficiency bands) reproduce tightly; the overall ENet aggregate
depends on layer-inventory bookkeeping the paper does not fully specify
(skip-projection convs, decoder internal widths), so it carries a wider band
plus a paper-mix consistency check (see EXPERIMENTS.md §Fig10).
"""

import pytest

from repro.core import cycle_model as cm
from repro.core.enet_spec import (
    enet_512_layers, dilated_layer_sets, transposed_layer_sets,
)


@pytest.fixture(scope="module")
def layers():
    return enet_512_layers()


@pytest.fixture(scope="module")
def rep(layers):
    return cm.report(layers)


def test_array_is_168_macs():
    assert cm.N_ROWS * 3 * cm.N_BLOCKS == cm.MACS_PER_CYCLE == 168
    assert cm.MACS_PER_CYCLE * 2 * cm.FREQ_HZ / 1e9 == 168.0 * 2 * 500e6 / 1e9


def test_peak_throughput_matches_table1(rep):
    assert rep["peak_gops"] == pytest.approx(168.0)  # Table I peak


def test_effective_throughput_matches_table1(rep):
    # Table I: 1377 GOPS logical throughput with zero skipping on ENet.
    assert 1000 < rep["effective_gops"] < 1600


def test_dilated_share_of_cycles(rep):
    # paper: dilated convolutions are 85% of the ideal-dense cycle count
    assert 82 <= rep["share_dilated_pct"] <= 88


def test_dilated_aggregate_speedup(rep):
    # paper: 85% -> 2%, about 42.5x
    assert 38 <= rep["dilated_speedup"] <= 48


def test_transposed_aggregate_speedup(rep):
    # paper: 7% -> 2%, 3.5x
    assert 3.0 <= rep["transposed_speedup"] <= 4.2


def test_overall_speedup_and_reduction(rep):
    # paper: 8.2x, 87.8% reduction. Honest ENet inventory gives 6.6x / 85%;
    # the per-group ratios applied to the paper's own 85/7/8 mix give 7.9x
    # (tested below) — band covers both.
    assert 6.0 <= rep["overall_speedup"] <= 9.0
    assert 82 <= rep["cycle_reduction_pct"] <= 90


def test_paper_mix_consistency(layers):
    """Per-group ratios x paper's reported 85/7/8 mix must recover ~8.2x."""
    g = cm.summarize(layers)
    ratios = {k: g[k].cycles_ours / g[k].cycles_dense
              for k in ("dilated", "transposed", "general")}
    mix = {"dilated": 85.0, "transposed": 7.0, "general": 8.0}
    ours_total = sum(mix[k] * ratios[k] for k in mix)
    assert 7.3 <= 100.0 / ours_total <= 9.0


def test_dilated_efficiency_band(layers):
    """Paper Fig. 11: 83%-98% of ideal sparse, decreasing with D."""
    effs = {}
    for D, ls in dilated_layer_sets(layers).items():
        effs[D] = (sum(cm.cycles_ideal_sparse(l) for l in ls)
                   / sum(cm.cycles_our_decomposed(l) for l in ls))
    assert set(effs) == {1, 3, 7, 15}   # ENet dilation rates 2,4,8,16
    assert 0.95 <= effs[1] <= 0.99      # ~98% at D=1
    assert 0.80 <= effs[15] <= 0.88     # ~83% at D=15
    # monotone: more padding loss for larger D
    assert effs[1] > effs[3] > effs[7] > effs[15]


def test_dilated_speedup_increases_with_D(layers):
    """Paper Fig. 11: higher speedup for larger dilation rate."""
    sps = {}
    for D, ls in dilated_layer_sets(layers).items():
        sps[D] = (sum(cm.cycles_ideal_dense(l) for l in ls)
                  / sum(cm.cycles_our_decomposed(l) for l in ls))
    assert sps[1] < sps[3] < sps[7] < sps[15]
    # naive/dec MAC ratio is (2D+3)^2/9: 2.8x, 9x, 32x, 121x
    assert 2.2 <= sps[1] <= 3.5
    assert 100 <= sps[15] <= 160


def test_transposed_efficiency_close_to_sparse(layers):
    """Paper Fig. 12: up to 99%, marginal loss due to tiled input."""
    effs = {}
    for sz, ls in transposed_layer_sets(layers).items():
        effs[sz] = (sum(cm.cycles_ideal_sparse(l) for l in ls)
                    / sum(cm.cycles_our_decomposed(l) for l in ls))
    assert set(effs) == {128, 256, 512}
    assert all(e >= 0.88 for e in effs.values())
    assert effs[512] >= 0.97            # "up to 99%" at the largest layer
    assert effs[128] < effs[512]        # tiling loss shrinks with size


def test_general_conv_overhead_matches_9_vs_8(layers):
    """Paper Fig. 10: general convs 9% on our work vs 8% ideal -> ~1.13x."""
    g = cm.summarize(layers)
    ratio = g["general"].cycles_ours / g["general"].cycles_dense
    assert 1.05 <= ratio <= 1.20


def test_mac_counts_are_exact_for_dilated():
    """Cycle model MACs agree with the executable decomposition's counts."""
    from repro.core.enet_spec import ConvLayer
    from repro.core import dilated as dil

    l = ConvLayer("x", "dilated", 64, 64, 32, 32, 3, 3, D=7, group="dilated")
    assert cm.ideal_dense_macs(l) == dil.macs_dense(64, 64, 32, 32, 3, 8)
    # decomposition issues <= compact-kernel MACs (boundary in-bounds only)
    assert cm.ideal_sparse_macs(l) <= dil.macs_decomposed(64, 64, 32, 32, 3, 8)


def test_cycles_scale_linearly_with_channels():
    from repro.core.enet_spec import ConvLayer

    a = ConvLayer("a", "dilated", 64, 64, 16, 16, 3, 3, D=3, group="dilated")
    b = ConvLayer("b", "dilated", 64, 64, 32, 32, 3, 3, D=3, group="dilated")
    ca, cb = cm.cycles_our_decomposed(a), cm.cycles_our_decomposed(b)
    assert cb == pytest.approx(4 * ca, rel=0.01)


# ------------------------------------- explicit-padding transposed costing ---
# Regression: tconv_input_size/ideal_sparse_macs used to hard-code the
# framework-default p_lo=(k-1)//2, which mis-inverts the input extent for the
# generative geometries (DCGAN k=4/s=2/p_lo=2/op=0, U-Net k=2/s=2/p_lo=1).

def _tlayer(h_out, k, s, padding, op, cin=16, cout=8):
    from repro.core.enet_spec import ConvLayer

    return ConvLayer("t", "transposed", h_out, h_out, cin, cout, k, k,
                     stride=s, group="transposed", output_padding=op,
                     padding=padding)


@pytest.mark.parametrize("h_out,k,s,padding,op,h_in", [
    (8, 4, 2, 2, 0, 4),      # DCGAN exact-2x stage
    (16, 2, 2, 1, 0, 8),     # U-Net k=2 exact-2x upsample
    (128, 3, 2, None, 1, 64),  # ENet default geometry unchanged
    (8, 5, 3, 2, 1, 3),      # odd general case
])
def test_tconv_input_size_honors_padding(h_out, k, s, padding, op, h_in):
    l = _tlayer(h_out, k, s, padding, op)
    assert cm.tconv_input_size(l) == (h_in, h_in)
    # round-trip through the executable engine's size relation
    from repro.core import transposed as tr

    p_lo, p_hi = cm.tconv_pads(l)
    assert tr.out_size(h_in, s, k, p_lo, p_hi) == h_out


def test_tconv_sparse_macs_bounded_by_decomposition():
    """ideal sparse (in-bounds live taps) <= MACs the decomposition issues
    (which include boundary taps over pad) <= dense-over-zero-inserted."""
    from repro.core import transposed as tr

    for l in (_tlayer(8, 4, 2, 2, 0), _tlayer(16, 2, 2, 1, 0),
              _tlayer(11, 2, 3, 1, 0), _tlayer(128, 3, 2, None, 1)):
        h_in, w_in = cm.tconv_input_size(l)
        p_lo, p_hi = cm.tconv_pads(l)
        issued = tr.macs_decomposed_transposed(h_in, w_in, l.cin, l.cout,
                                               l.kh, l.stride, p_lo, p_hi)
        assert cm.ideal_sparse_macs(l) <= issued <= cm.ideal_dense_macs(l)


def test_k_lt_s_zero_planes_cost_nothing():
    """k < s leaves dead output parities (zero conv planes): the sparse MAC
    count must skip them entirely, and the decomposed schedule still packs
    only the k*k live taps (every tap maps to exactly one parity)."""
    l = _tlayer(11, 2, 3, 1, 0)
    h_in, _ = cm.tconv_input_size(l)
    # one of the 3 parities has no live tap per dim: the 3 dead rows/cols of
    # the 11-wide output contribute no MACs, so the sparse count collapses to
    # the k*k in-bounds taps over the INPUT extent — nothing charged to the
    # zero conv planes
    assert cm.ideal_sparse_macs(l) == l.kh * l.kw * h_in * h_in * l.cin * l.cout
    # while the naive schedule pays k*k taps for every one of the 11x11
    # outputs, dead planes included
    naive = l.kh * l.kw * l.h_out * l.w_out * l.cin * l.cout
    assert cm.ideal_sparse_macs(l) < naive / (l.stride ** 2 / 2)
    # port packing charges exactly k*k taps x cin x cout per input column
    expected = (cm._ceil(h_in, cm.N_ROWS) * h_in
                * cm._ceil(l.kh * l.kw * l.cin * l.cout, 3 * cm.N_BLOCKS))
    assert cm.cycles_our_decomposed(l) == expected


def test_adjoint_layer_uses_padded_input_extent():
    """The adjoint of a DCGAN upsample is a strided dense conv at the TRUE
    input extent (4 for an 8-out stage), not the (k-1)//2 mis-inversion."""
    l = _tlayer(8, 4, 2, 2, 0)
    a = cm.adjoint_layer(l)
    assert a.kind == "conv"
    assert (a.h_out, a.w_out) == (4, 4)
    assert (a.cin, a.cout) == (l.cout, l.cin)


def test_report_handles_missing_groups():
    """Generative workloads are not full-mix: a dilated-free layer set must
    not divide by the empty group's zero cycles."""
    from repro.core.gen_spec import dcgan_layers

    rep = cm.report(dcgan_layers(64))
    assert rep["dilated_speedup"] == 1.0          # absent group: neutral
    assert rep["share_dilated_pct"] == 0.0
    assert rep["transposed_speedup"] > 2.0


# ------------------------------------------- empty-workload report guards ---
# Regression: report/training_report/serve_report on an empty (or otherwise
# zero-cycle) layer list raised ZeroDivisionError instead of returning the
# neutral report — callers costing a filtered layer subset hit this.

def test_report_empty_layers_is_neutral():
    rep = cm.report([])
    assert rep["overall_speedup"] == 1.0
    assert rep["dilated_speedup"] == 1.0
    assert rep["share_dilated_pct"] == 0.0
    assert rep["peak_gops"] == pytest.approx(168.0)   # array property survives


def test_training_report_empty_layers_is_neutral():
    trn = cm.training_report([])
    assert trn["train_speedup_vs_naive"] == 1.0
    assert trn["fwd_cycles"] == 0.0


def test_serve_report_empty_layers_is_neutral():
    rep = cm.serve_report([], steps=8)
    assert rep["serve_speedup_vs_naive"] == 1.0
    assert rep["cycles_per_image_ours"] == 0.0
    assert rep["images_per_s_ours"] == 0.0


# --------------------------------------- wgrad tap-gather port contention ---
# The backward weight pass gathers taps along the contraction (spatial)
# axis, so dL/dw packs kernel-tap columns instead of output rows: the
# cycle model charges the pack-quantization of those columns rather than
# assuming the forward pass's full-rate port utilization.

def test_wgrad_contention_bounds_and_exact_values():
    from repro.core.enet_spec import ConvLayer

    # k=3 transposed: 9 taps pack 3-per-port exactly; cout=16 tiles 8-wide
    t3 = ConvLayer("t", "transposed", 128, 128, 16, 16, 3, 3, stride=2,
                   group="transposed")
    assert cm.wgrad_contention(t3) == pytest.approx(1.0)
    # k=4 (DCGAN): 16 taps -> ceil to 18 slots = 1.125x
    t4 = ConvLayer("t", "transposed", 8, 8, 16, 16, 4, 4, stride=2,
                   group="transposed", output_padding=0, padding=2)
    assert cm.wgrad_contention(t4) == pytest.approx(18 / 16)
    # k=2 (U-Net upsample): 4 taps -> 6 slots = 1.5x
    t2 = ConvLayer("t", "transposed", 16, 16, 16, 16, 2, 2, stride=2,
                   group="transposed", output_padding=0, padding=1)
    assert cm.wgrad_contention(t2) == pytest.approx(1.5)
    # dense k=3 cin=16: column 48 packs exactly; cout=16 tiles exactly
    d = ConvLayer("d", "conv", 64, 64, 16, 16, 3, 3)
    assert cm.wgrad_contention(d) == pytest.approx(1.0)
    # never below full rate, and cycles_wgrad carries the term
    for l in (t3, t4, t2, d):
        assert cm.wgrad_contention(l) >= 1.0
        assert cm.cycles_wgrad(l) == pytest.approx(
            cm.ideal_sparse_macs(l) / cm.MACS_PER_CYCLE
            * cm.wgrad_contention(l))


def test_wgrad_contention_ragged_cout_tiling():
    from repro.core.enet_spec import ConvLayer

    # cout=12 on an 8-wide block row: 16/12 tiling waste enters wgrad
    l = ConvLayer("d", "conv", 32, 32, 16, 12, 3, 3)
    assert cm.wgrad_contention(l) == pytest.approx(16 / 12)
