"""Validate the cycle model against the paper's reported claims (§III).

Tolerances: the paper's technique-specific numbers (dilated/transposed
speedups, efficiency bands) reproduce tightly; the overall ENet aggregate
depends on layer-inventory bookkeeping the paper does not fully specify
(skip-projection convs, decoder internal widths), so it carries a wider band
plus a paper-mix consistency check (see EXPERIMENTS.md §Fig10).
"""

import pytest

from repro.core import cycle_model as cm
from repro.core.enet_spec import (
    enet_512_layers, dilated_layer_sets, transposed_layer_sets,
)


@pytest.fixture(scope="module")
def layers():
    return enet_512_layers()


@pytest.fixture(scope="module")
def rep(layers):
    return cm.report(layers)


def test_array_is_168_macs():
    assert cm.N_ROWS * 3 * cm.N_BLOCKS == cm.MACS_PER_CYCLE == 168
    assert cm.MACS_PER_CYCLE * 2 * cm.FREQ_HZ / 1e9 == 168.0 * 2 * 500e6 / 1e9


def test_peak_throughput_matches_table1(rep):
    assert rep["peak_gops"] == pytest.approx(168.0)  # Table I peak


def test_effective_throughput_matches_table1(rep):
    # Table I: 1377 GOPS logical throughput with zero skipping on ENet.
    assert 1000 < rep["effective_gops"] < 1600


def test_dilated_share_of_cycles(rep):
    # paper: dilated convolutions are 85% of the ideal-dense cycle count
    assert 82 <= rep["share_dilated_pct"] <= 88


def test_dilated_aggregate_speedup(rep):
    # paper: 85% -> 2%, about 42.5x
    assert 38 <= rep["dilated_speedup"] <= 48


def test_transposed_aggregate_speedup(rep):
    # paper: 7% -> 2%, 3.5x
    assert 3.0 <= rep["transposed_speedup"] <= 4.2


def test_overall_speedup_and_reduction(rep):
    # paper: 8.2x, 87.8% reduction. Honest ENet inventory gives 6.6x / 85%;
    # the per-group ratios applied to the paper's own 85/7/8 mix give 7.9x
    # (tested below) — band covers both.
    assert 6.0 <= rep["overall_speedup"] <= 9.0
    assert 82 <= rep["cycle_reduction_pct"] <= 90


def test_paper_mix_consistency(layers):
    """Per-group ratios x paper's reported 85/7/8 mix must recover ~8.2x."""
    g = cm.summarize(layers)
    ratios = {k: g[k].cycles_ours / g[k].cycles_dense
              for k in ("dilated", "transposed", "general")}
    mix = {"dilated": 85.0, "transposed": 7.0, "general": 8.0}
    ours_total = sum(mix[k] * ratios[k] for k in mix)
    assert 7.3 <= 100.0 / ours_total <= 9.0


def test_dilated_efficiency_band(layers):
    """Paper Fig. 11: 83%-98% of ideal sparse, decreasing with D."""
    effs = {}
    for D, ls in dilated_layer_sets(layers).items():
        effs[D] = (sum(cm.cycles_ideal_sparse(l) for l in ls)
                   / sum(cm.cycles_our_decomposed(l) for l in ls))
    assert set(effs) == {1, 3, 7, 15}   # ENet dilation rates 2,4,8,16
    assert 0.95 <= effs[1] <= 0.99      # ~98% at D=1
    assert 0.80 <= effs[15] <= 0.88     # ~83% at D=15
    # monotone: more padding loss for larger D
    assert effs[1] > effs[3] > effs[7] > effs[15]


def test_dilated_speedup_increases_with_D(layers):
    """Paper Fig. 11: higher speedup for larger dilation rate."""
    sps = {}
    for D, ls in dilated_layer_sets(layers).items():
        sps[D] = (sum(cm.cycles_ideal_dense(l) for l in ls)
                  / sum(cm.cycles_our_decomposed(l) for l in ls))
    assert sps[1] < sps[3] < sps[7] < sps[15]
    # naive/dec MAC ratio is (2D+3)^2/9: 2.8x, 9x, 32x, 121x
    assert 2.2 <= sps[1] <= 3.5
    assert 100 <= sps[15] <= 160


def test_transposed_efficiency_close_to_sparse(layers):
    """Paper Fig. 12: up to 99%, marginal loss due to tiled input."""
    effs = {}
    for sz, ls in transposed_layer_sets(layers).items():
        effs[sz] = (sum(cm.cycles_ideal_sparse(l) for l in ls)
                    / sum(cm.cycles_our_decomposed(l) for l in ls))
    assert set(effs) == {128, 256, 512}
    assert all(e >= 0.88 for e in effs.values())
    assert effs[512] >= 0.97            # "up to 99%" at the largest layer
    assert effs[128] < effs[512]        # tiling loss shrinks with size


def test_general_conv_overhead_matches_9_vs_8(layers):
    """Paper Fig. 10: general convs 9% on our work vs 8% ideal -> ~1.13x."""
    g = cm.summarize(layers)
    ratio = g["general"].cycles_ours / g["general"].cycles_dense
    assert 1.05 <= ratio <= 1.20


def test_mac_counts_are_exact_for_dilated():
    """Cycle model MACs agree with the executable decomposition's counts."""
    from repro.core.enet_spec import ConvLayer
    from repro.core import dilated as dil

    l = ConvLayer("x", "dilated", 64, 64, 32, 32, 3, 3, D=7, group="dilated")
    assert cm.ideal_dense_macs(l) == dil.macs_dense(64, 64, 32, 32, 3, 8)
    # decomposition issues <= compact-kernel MACs (boundary in-bounds only)
    assert cm.ideal_sparse_macs(l) <= dil.macs_decomposed(64, 64, 32, 32, 3, 8)


def test_cycles_scale_linearly_with_channels():
    from repro.core.enet_spec import ConvLayer

    a = ConvLayer("a", "dilated", 64, 64, 16, 16, 3, 3, D=3, group="dilated")
    b = ConvLayer("b", "dilated", 64, 64, 32, 32, 3, 3, D=3, group="dilated")
    ca, cb = cm.cycles_our_decomposed(a), cm.cycles_our_decomposed(b)
    assert cb == pytest.approx(4 * ca, rel=0.01)
