"""Whisper conv frontend: shape + parity vs ``lax.conv_general_dilated``.

The frontend (``repro.models.whisper``) expresses Whisper's two temporal
convs as (H=1) 2-D convolutions through the repo's conv engine; these tests
pin its output geometry (``T -> ceil(T/2)``) and numerical parity with a
reference path that never touches engine code — un-stranding the demo that
previously lived outside CI.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.models import whisper


def _setup(b=1, t=64, mel=16, d=32, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    params = whisper.init_frontend_params(k1, n_mels=mel, d_model=d)
    x = jax.random.normal(k2, (b, t, mel))
    return params, x


@pytest.mark.parametrize("t", [64, 63])
def test_frontend_shape(t):
    params, x = _setup(b=2, t=t)
    frames = whisper.frontend(params, x)
    # SAME stride-2: ceil(T/2) — covers the odd-T branch too
    assert frames.shape == (2, (t + 1) // 2, 32)
    assert bool(jnp.all(jnp.isfinite(frames)))


def test_frontend_matches_lax_reference():
    params, x = _setup()
    got = whisper.frontend(params, x)
    want = whisper.frontend_reference(params, x)
    assert jnp.max(jnp.abs(got - want)) < 1e-5


def test_frontend_param_shapes():
    params = whisper.init_frontend_params(jax.random.PRNGKey(0))
    assert params["conv1"].shape == (1, 3, whisper.N_MELS, whisper.D_MODEL)
    assert params["conv2"].shape == (1, 3, whisper.D_MODEL, whisper.D_MODEL)
