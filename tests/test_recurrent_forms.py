"""Equivalence of the parallel / chunkwise / sequential forms of the
recurrent sequence mixers (Mamba selective scan, mLSTM) — the chunkwise
forms are what make the 32k/500k cells feasible, so they must be exact."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from repro.models import mamba as M
from repro.models import xlstm as X
from repro.models.config import MambaConfig, ModelConfig, XLSTMConfig


def _mamba_cfg():
    return ModelConfig(
        name="t", family="hybrid", num_layers=1, d_model=16, num_heads=2,
        kv_heads=2, head_dim=8, d_ff=32, vocab=64,
        block_pattern=("mamba",), mamba=MambaConfig(d_state=4), remat=False)


def _xlstm_cfg():
    return ModelConfig(
        name="t", family="ssm", num_layers=2, d_model=16, num_heads=2,
        kv_heads=2, head_dim=8, d_ff=0, vocab=64,
        block_pattern=("mlstm", "slstm"), xlstm=XLSTMConfig(), remat=False)


def test_mamba_chunked_equals_full_scan():
    cfg = _mamba_cfg()
    p = M.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1024, 16))
    old = M.SCAN_CHUNK
    try:
        M.SCAN_CHUNK = 128
        y_chunk, _ = M.mamba_block(p, x, cfg)
        M.SCAN_CHUNK = 1 << 30
        y_full, _ = M.mamba_block(p, x, cfg)
    finally:
        M.SCAN_CHUNK = old
    assert_allclose(np.asarray(y_chunk), np.asarray(y_full), rtol=1e-4,
                    atol=1e-5)


def test_mamba_sequential_decode_equals_parallel():
    cfg = _mamba_cfg()
    p = M.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 16))
    y_par, _ = M.mamba_block(p, x, cfg)
    cache = M.init_mamba_cache(cfg, 1, jnp.float32)
    ys = []
    for t in range(6):
        y, cache = M.mamba_block(p, x[:, t:t + 1], cfg, cache=cache)
        ys.append(y[:, 0])
    assert_allclose(np.asarray(jnp.stack(ys, 1)), np.asarray(y_par),
                    rtol=1e-4, atol=1e-5)


def test_mlstm_chunkwise_equals_parallel():
    cfg = _xlstm_cfg()
    p = X.mlstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1024, 16))
    old = X.M_CHUNK
    try:
        X.M_CHUNK = 128   # chunked path (1024 > 128)
        y_chunk, _ = X.mlstm_block(p, x, cfg)
        X.M_CHUNK = 1 << 30  # parallel path
        y_par, _ = X.mlstm_block(p, x, cfg)
    finally:
        X.M_CHUNK = old
    assert_allclose(np.asarray(y_chunk), np.asarray(y_par), rtol=2e-4,
                    atol=2e-4)


def test_mlstm_sequential_decode_equals_parallel():
    cfg = _xlstm_cfg()
    p = X.mlstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 5, 16))
    y_par, _ = X.mlstm_block(p, x, cfg)
    cache = X.init_mlstm_cache(cfg, 1)
    ys = []
    for t in range(5):
        y, cache = X.mlstm_block(p, x[:, t:t + 1], cfg, cache=cache)
        ys.append(y[:, 0])
    assert_allclose(np.asarray(jnp.stack(ys, 1)), np.asarray(y_par),
                    rtol=2e-4, atol=2e-4)


def test_slstm_decode_equals_scan():
    cfg = _xlstm_cfg()
    p = X.slstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 5, 16))
    y_scan, _ = X.slstm_block(p, x, cfg)
    cache = X.init_slstm_cache(cfg, 1)
    ys = []
    for t in range(5):
        y, cache = X.slstm_block(p, x[:, t:t + 1], cfg, cache=cache)
        ys.append(y[:, 0])
    assert_allclose(np.asarray(jnp.stack(ys, 1)), np.asarray(y_scan),
                    rtol=1e-4, atol=1e-5)
