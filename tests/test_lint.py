"""Repo lint: the silent-except rule that guards the degradation paths
(DESIGN.md §11) — CI runs ``tools/lint_silent_except.py src`` blocking."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))

import lint_silent_except as lint  # noqa: E402

_SRC = Path(__file__).resolve().parents[1] / "src"


def test_src_tree_is_clean():
    problems = []
    for f in sorted(_SRC.rglob("*.py")):
        problems.extend(lint.check_file(f))
    assert problems == []


def test_flags_bare_except(tmp_path):
    f = tmp_path / "bad.py"
    f.write_text("try:\n    x = 1\nexcept:\n    x = 2\n")
    assert any("bare 'except:'" in p for p in lint.check_file(f))


def test_flags_silent_broad_except(tmp_path):
    f = tmp_path / "bad.py"
    f.write_text("try:\n    x = 1\nexcept Exception:\n    pass\n")
    problems = lint.check_file(f)
    assert any("silently eats errors" in p for p in problems)
    # ellipsis body and tuple forms are just as silent
    f.write_text("try:\n    x = 1\n"
                 "except (ValueError, BaseException):\n    ...\n")
    assert lint.check_file(f)


def test_allows_handled_broad_except(tmp_path):
    """Broad catches with a real handler body are the supported fallback
    idiom (autotune/calibration use them) — not flagged."""
    f = tmp_path / "ok.py"
    f.write_text("try:\n    x = 1\n"
                 "except Exception as e:\n    x = fallback(e)\n")
    assert lint.check_file(f) == []
    # narrow silent catches are a judgement call, left alone too
    f.write_text("try:\n    x = 1\nexcept KeyError:\n    pass\n")
    assert lint.check_file(f) == []


def test_cli_exit_status(tmp_path, capsys):
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert lint.main([str(good)]) == 0
    bad = tmp_path / "bad.py"
    bad.write_text("try:\n    x = 1\nexcept:\n    pass\n")
    assert lint.main([str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "bad.py:3" in out
