"""Gradient correctness for the decomposition engine (DESIGN.md §6).

Two independent guarantees, so the custom VJPs are pinned numerically:

* **finite differences** — the directional derivative of a scalar loss
  matches a central-difference estimate (is the VJP *a* derivative at all);
* **backend parity** — ``jax.grad`` through ``backend='pallas'`` (custom
  VJPs over the fused kernels) matches ``jax.grad`` through
  ``backend='xla'`` (lax autodiff) to fp32 tolerance (is it the *same*
  derivative).

The fast subset runs in tier-1; the exhaustive grids are marked ``slow``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.decompose import conv2d


def _case_fn(kind: str, **kw):
    """A conv2d closure for one operator geometry."""
    def f(x, w, backend):
        return conv2d(x, w, backend=backend, **kw)
    f.kind = kind
    return f


# (name, conv kwargs, x shape, w shape) — geometry grid for the gradchecks
FAST_CASES = [
    ("dense_s1", dict(), (1, 8, 9, 3), (3, 3, 3, 4)),
    ("dense_s2", dict(stride=2), (1, 9, 8, 3), (3, 3, 3, 4)),
    ("dilated_d2", dict(dilation=2), (1, 10, 9, 3), (3, 3, 3, 4)),
    ("tconv_s2", dict(stride=2, transposed=True, output_padding=1),
     (1, 5, 6, 3), (3, 3, 3, 4)),
]
SLOW_CASES = [
    ("dilated_d3", dict(dilation=3), (2, 12, 11, 3), (3, 3, 3, 4)),
    ("dilated_d4", dict(dilation=4), (1, 13, 13, 2), (3, 3, 2, 3)),
    ("dilated_d2_s2", dict(dilation=2, stride=2), (1, 12, 10, 3), (3, 3, 3, 4)),
    ("dilated_d3_s2", dict(dilation=3, stride=2), (1, 12, 12, 2), (3, 3, 2, 2)),
    ("tconv_s2_k2", dict(stride=2, transposed=True, output_padding=0),
     (1, 6, 5, 3), (2, 2, 3, 4)),
    ("tconv_s3_k5", dict(stride=3, transposed=True, output_padding=1),
     (1, 5, 5, 2), (5, 5, 2, 3)),
    ("tconv_s2_k4", dict(stride=2, transposed=True, output_padding=1),
     (1, 6, 6, 2), (4, 4, 2, 3)),
    ("dense_s2_k2_p0", dict(stride=2, padding=0), (1, 8, 8, 3), (2, 2, 3, 4)),
]


def _data(case):
    _, kw, xs, ws = case
    k1, k2, k3, k4, k5 = jax.random.split(jax.random.PRNGKey(sum(xs) + sum(ws)), 5)
    x = jax.random.normal(k1, xs, jnp.float32)
    w = jax.random.normal(k2, ws, jnp.float32)
    vx = jax.random.normal(k3, xs, jnp.float32)
    vw = jax.random.normal(k4, ws, jnp.float32)
    return x, w, vx, vw, k5


def _loss(kw, backend):
    def loss(x, w):
        y = conv2d(x, w, backend=backend, **kw)
        return jnp.sum(jnp.sin(y))          # nonlinear, so dL/dy varies
    return loss


def _fd_check(case, backend):
    """Directional finite-difference check of dL/dx and dL/dw."""
    _, kw, _, _ = case
    x, w, vx, vw, _ = _data(case)
    loss = _loss(kw, backend)
    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    eps = 1.5e-2
    for g, v, lo in ((gx, vx, lambda t: loss(x + t * vx, w)),
                     (gw, vw, lambda t: loss(x, w + t * vw))):
        def central(e):
            return (float(lo(e)) - float(lo(-e))) / (2 * e)
        # Richardson-extrapolated central difference: O(eps^4) truncation
        fd = (4 * central(eps) - central(2 * eps)) / 3
        an = float(jnp.vdot(g, v))
        assert np.isfinite(fd) and np.isfinite(an)
        assert abs(fd - an) <= 1e-2 * max(1.0, abs(an)), (case[0], backend, fd, an)


def _parity_check(case):
    """jax.grad via pallas custom VJPs == jax.grad via XLA autodiff."""
    _, kw, _, _ = case
    x, w, _, _, _ = _data(case)
    gx_x, gw_x = jax.grad(_loss(kw, "xla"), argnums=(0, 1))(x, w)
    gx_p, gw_p = jax.grad(_loss(kw, "pallas"), argnums=(0, 1))(x, w)
    assert_allclose(np.asarray(gx_p), np.asarray(gx_x), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(gw_p), np.asarray(gw_x), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("case", FAST_CASES, ids=lambda c: c[0])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_gradcheck_fast(case, backend):
    _fd_check(case, backend)


@pytest.mark.slow
@pytest.mark.parametrize("case", SLOW_CASES, ids=lambda c: c[0])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_gradcheck_grid(case, backend):
    _fd_check(case, backend)


@pytest.mark.parametrize("case", FAST_CASES, ids=lambda c: c[0])
def test_backend_gradient_parity(case):
    _parity_check(case)


@pytest.mark.slow
@pytest.mark.parametrize("case", SLOW_CASES, ids=lambda c: c[0])
def test_backend_gradient_parity_grid(case):
    _parity_check(case)


def test_gradcheck_dilated_even_kernel_pallas():
    """Even-k dilated kernels skip the symmetry VJP (asymmetric SAME pads)
    and differentiate compositionally — FD-checked against the pallas
    forward itself (the XLA engine rejects even-k dilated SAME)."""
    from repro.kernels.dilated_conv import dilated_conv2d

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    x = jax.random.normal(k1, (1, 9, 8, 2), jnp.float32)
    w = jax.random.normal(k2, (2, 2, 2, 3), jnp.float32)
    v = jax.random.normal(k3, (2, 2, 2, 3), jnp.float32)

    def loss(w):
        return jnp.sum(jnp.sin(dilated_conv2d(x, w, 2)))

    g = jax.grad(loss)(w)
    eps = 1.5e-2

    def central(e):
        return (float(loss(w + e * v)) - float(loss(w - e * v))) / (2 * e)

    fd = (4 * central(eps) - central(2 * eps)) / 3
    an = float(jnp.vdot(g, v))
    assert abs(fd - an) <= 1e-2 * max(1.0, abs(an)), (fd, an)


def test_naive_and_decomposed_gradients_agree():
    """d(decomposed)/dx == d(naive zero-laden)/dx — same function, XLA side."""
    case = ("dil", dict(dilation=2), (1, 9, 9, 3), (3, 3, 3, 4))
    x, w, _, _, _ = _data(case)

    def loss(dec):
        return lambda x, w: jnp.sum(jnp.sin(
            conv2d(x, w, dilation=2, decomposed=dec)))

    gd = jax.grad(loss(True), argnums=(0, 1))(x, w)
    gn = jax.grad(loss(False), argnums=(0, 1))(x, w)
    for a, b in zip(gd, gn):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_ragged_strategy_gradients():
    """The paper-faithful ragged schedule is differentiable too (lax path)."""
    case = ("rag", dict(dilation=3, strategy="ragged"), (1, 9, 8, 2), (3, 3, 2, 3))
    x, w, _, _, _ = _data(case)
    g = jax.grad(lambda x, w: jnp.sum(jnp.sin(
        conv2d(x, w, dilation=3, strategy="ragged"))), argnums=(0, 1))(x, w)
    gb = jax.grad(lambda x, w: jnp.sum(jnp.sin(
        conv2d(x, w, dilation=3, strategy="batched"))), argnums=(0, 1))(x, w)
    for a, b in zip(g, gb):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_grad_dtype_and_shape_match_primals():
    """VJP outputs carry the primal shapes/dtypes (bf16 params train)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 6, 2), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 2, 2), jnp.bfloat16)
    gx, gw = jax.grad(
        lambda x, w: jnp.sum(conv2d(x, w, dilation=2, backend="pallas")
                             .astype(jnp.float32)),
        argnums=(0, 1))(x, w)
    assert gx.shape == x.shape and gx.dtype == x.dtype
    assert gw.shape == w.shape and gw.dtype == w.dtype


def test_second_order_grad_xla_backend():
    """Higher-order autodiff works on the XLA backend (pure lax composition).

    The pallas backend is first-order only — ``jax.custom_vjp`` functions are
    not forward-differentiable (a JAX restriction, recorded in DESIGN.md §6).
    """
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 6, 2))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 2, 2))

    def f(w):
        return jnp.sum(jnp.sin(conv2d(x, w, stride=2, backend="xla")))

    g2 = jax.grad(lambda w: jnp.sum(jnp.cos(jax.grad(f)(w))))(w)
    assert g2.shape == w.shape and bool(jnp.all(jnp.isfinite(g2)))
