"""Autotune cache behaviour (DESIGN.md §7): round-trip, determinism,
invalidation-by-filename, and the no-sweep-on-cold-miss contract.
"""

import json

import jax.numpy as jnp
import pytest

from repro.kernels import autotune as at

GEOM = dict(x_shape=(1, 12, 12, 4), w_shape=(3, 3, 4, 8))


@pytest.fixture()
def cache_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    at.clear_memory_cache()
    yield tmp_path
    at.clear_memory_cache()


def test_make_key_is_geometry_exact():
    k1 = at.make_key("dense", (1, 16, 16, 8), (3, 3, 8, 16))
    k2 = at.make_key("dense", (1, 16, 16, 8), (3, 3, 8, 16), stride=2)
    k3 = at.make_key("dilated", (1, 16, 16, 8), (3, 3, 8, 16))
    k4 = at.make_key("dense", (1, 16, 16, 8), (3, 3, 8, 16),
                     dtype=jnp.bfloat16)
    # padding changes the output extent, hence the tiling: distinct keys
    k5 = at.make_key("dense", (1, 16, 16, 8), (3, 3, 8, 16), padding=0)
    k6 = at.make_key("tconv", (1, 16, 16, 8), (3, 3, 8, 16), stride=2,
                     output_padding=0)
    k7 = at.make_key("tconv", (1, 16, 16, 8), (3, 3, 8, 16), stride=2)
    assert len({k1, k2, k3, k4, k5, k6, k7}) == 7
    with pytest.raises(ValueError):
        at.make_key("conv3d", (1, 16, 16, 8), (3, 3, 8, 16))


def test_candidates_clip_to_geometry():
    cands = at.candidates(h_out=6, cout=32)
    assert cands and all(th <= max(6, 4) and tc <= 64 for th, tc in cands)
    big = at.candidates(h_out=64, cout=512)
    assert (32, 256) in big


def test_cold_miss_returns_defaults_without_sweeping(cache_dir, monkeypatch):
    monkeypatch.setattr(at, "_time_candidate",
                        lambda *a, **k: pytest.fail("swept on a cold miss"))
    assert at.get_tiles("dense", **GEOM) == at.DEFAULT_TILES
    assert not at.cache_path().exists()     # pure lookup — nothing persisted


def test_tune_roundtrip_and_determinism(cache_dir, monkeypatch):
    tiles = at.tune("dense", **GEOM, cands=[(4, 64), (8, 64)], iters=1)
    assert tiles in [(4, 64), (8, 64)]

    # on-disk layout: schema + entries keyed by make_key
    raw = json.loads(at.cache_path().read_text())
    key = at.make_key("dense", **GEOM)
    assert raw["schema"] == at._SCHEMA
    assert raw["entries"][key] == list(tiles)

    # a fresh process (cleared memory cache) serves the disk entry and
    # NEVER re-times — cached tiles are deterministic across runs
    at.clear_memory_cache()
    monkeypatch.setattr(at, "_time_candidate",
                        lambda *a, **k: pytest.fail("re-timed a cache hit"))
    assert at.get_tiles("dense", **GEOM) == tiles
    assert at.get_tiles("dense", **GEOM) == tiles     # mem-cache hit too


def test_enabled_env_sweeps_on_miss(cache_dir, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    calls = []

    def fake_time(call, iters):
        calls.append(1)
        return float(len(calls))        # first candidate wins

    monkeypatch.setattr(at, "_time_candidate", fake_time)
    monkeypatch.setattr(at, "TH_CANDIDATES", (4, 8))
    monkeypatch.setattr(at, "TC_CANDIDATES", (64,))
    tiles = at.get_tiles("dense", **GEOM)
    assert tiles == (4, 64) and len(calls) == 2
    assert at.cache_path().exists()


def test_aot_tune_key_matches_dispatcher_key(cache_dir, monkeypatch):
    """An ahead-of-time ``tune()`` with engine defaults must be served to
    dispatcher calls, whose padding/output_padding arrive resolved."""
    monkeypatch.setattr(at, "_time_candidate", lambda call, iters: 1.0)
    tiles = at.tune("tconv", (1, 6, 6, 4), (3, 3, 4, 8), stride=2,
                    cands=[(4, 64)], iters=1)
    # dispatcher-style key: p resolved to (k-1)//2 = 1, op explicit 1
    assert at.get_tiles("tconv", (1, 6, 6, 4), (3, 3, 4, 8), stride=2,
                        padding=1, output_padding=1) == tiles
    tiles_d = at.tune("dense", (1, 8, 8, 4), (3, 3, 4, 8),
                      cands=[(8, 64)], iters=1)
    assert at.get_tiles("dense", (1, 8, 8, 4), (3, 3, 4, 8),
                        padding=None) == tiles_d


def test_prune_times_only_ranked_top_plus_default(cache_dir, monkeypatch):
    """``prune=k`` times the k model-ranked candidates plus DEFAULT_TILES."""
    timed = []

    def fake_time(call, iters):
        timed.append(1)
        return float(len(timed))

    monkeypatch.setattr(at, "_time_candidate", fake_time)
    cands = [(4, 64), (8, 64), (8, 128)]
    at.tune("dense", (1, 16, 16, 4), (3, 3, 4, 8), cands=cands, prune=1,
            iters=1)
    # top-1 by tile score is (8, 64); DEFAULT_TILES (8, 128) always rides
    assert len(timed) == 2


def test_prune_env_var_caps_the_sweep(cache_dir, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_PRUNE", "1")
    timed = []
    monkeypatch.setattr(at, "_time_candidate",
                        lambda call, iters: timed.append(1) or float(len(timed)))
    at.tune("dense", (1, 16, 16, 4), (3, 3, 4, 8),
            cands=[(4, 64), (8, 64), (8, 128)], iters=1)
    assert len(timed) == 2
    # garbage value: pruning silently off, the full grid is timed
    monkeypatch.setenv("REPRO_AUTOTUNE_PRUNE", "nope")
    timed.clear()
    at.tune("dense", (1, 16, 16, 4), (3, 3, 4, 8),
            cands=[(4, 64), (8, 64), (8, 128)], iters=1)
    assert len(timed) == 3


def test_corrupt_cache_file_is_ignored(cache_dir):
    path = at.cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text("{not json")
    at.clear_memory_cache()
    assert at.get_tiles("dense", **GEOM) == at.DEFAULT_TILES


def test_epilogue_key_separates_fused_configs(cache_dir, monkeypatch):
    """Schema-2 regression pin: one geometry tuned bare and with a fused
    residual must land in DISTINCT cache entries with their own winners.
    Pre-fix, ``make_key`` ignored the epilogue, so whichever configuration
    tuned second overwrote the first and both lookups served one winner.
    """
    from repro.kernels.epilogue import EpilogueSpec, fingerprint

    spec = EpilogueSpec(residual="post_act")
    assert fingerprint(None) == "none"
    assert fingerprint(spec) == "bn0.pr0.res-post_act"
    k_plain = at.make_key("dense", **GEOM)
    k_res = at.make_key("dense", **GEOM, epilogue=spec)
    assert k_plain != k_res

    # deterministic synthetic timings: the bare tune's first candidate
    # wins, the fused tune's second — distinct winners prove no overwrite
    times = iter([1.0, 2.0, 2.0, 1.0])
    monkeypatch.setattr(at, "_time_candidate",
                        lambda call, iters: next(times))
    cands = [(4, 64), (8, 64)]
    assert at.tune("dense", **GEOM, cands=cands, iters=1) == (4, 64)
    assert at.tune("dense", **GEOM, cands=cands, iters=1,
                   epilogue=spec) == (8, 64)
    raw = json.loads(at.cache_path().read_text())
    assert raw["entries"][k_plain] == [4, 64]
    assert raw["entries"][k_res] == [8, 64]

    # a fresh process keeps serving each configuration its own winner
    at.clear_memory_cache()
    monkeypatch.setattr(at, "_time_candidate",
                        lambda *a, **k: pytest.fail("re-timed a cache hit"))
    assert at.get_tiles("dense", **GEOM) == (4, 64)
    assert at.get_tiles("dense", **GEOM, epilogue=spec) == (8, 64)


def test_policy_times_top_plus_default(cache_dir, monkeypatch):
    """The default tune() path times at most POLICY_TOP + DEFAULT_TILES of
    a large grid — the analytic policy replaced the exhaustive sweep."""
    timed = []
    monkeypatch.setattr(
        at, "_time_candidate",
        lambda call, iters: timed.append(1) or float(len(timed)))
    cands = at.candidates(h_out=64, cout=512)       # full 4x3 grid
    assert len(cands) == 12
    at.tune("dense", (1, 64, 64, 16), (3, 3, 16, 512), cands=cands, iters=1)
    assert len(timed) <= at.POLICY_TOP + 1

    # REPRO_AUTOTUNE_SWEEP=1 forces the old exhaustive behaviour
    monkeypatch.setenv("REPRO_AUTOTUNE_SWEEP", "1")
    timed.clear()
    at.tune("dense", (1, 64, 64, 16), (3, 3, 16, 512), cands=cands, iters=1)
    assert len(timed) == len(cands)


def test_dispatcher_resolves_tiles_through_autotune(cache_dir, monkeypatch):
    """decompose.conv2d consults the table when th/tc are unset."""
    import jax

    from repro.core.decompose import conv2d

    seen = []
    real = at.get_tiles

    def spy(kind, xs, ws, **kw):
        seen.append((kind, xs, ws))
        return real(kind, xs, ws, **kw)

    monkeypatch.setattr(at, "get_tiles", spy)
    x = jax.numpy.ones((1, 8, 8, 4))
    w = jax.numpy.ones((3, 3, 4, 8))
    conv2d(x, w, backend="pallas")
    assert seen and seen[0][0] == "dense"
    seen.clear()
    conv2d(x, w, backend="pallas", th=8, tc=128)   # explicit tiles: no lookup
    assert not seen
