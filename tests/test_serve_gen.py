"""Generative serving path (DESIGN.md §9).

Three claim families from the serving issue:

* **queue packing** — with more requests than batch slots, every request
  completes (no starvation), admission is FIFO within a lane, and a server
  run is deterministic given the request seeds;
* **mixed-timestep batching is lossless** — a request served in a
  continuously-rebatched mixed-step queue matches the unbatched reference
  DDIM loop to <= 1e-5 on both backends (the transposed-conv geometry is
  timestep-invariant, so one compiled step serves the whole queue);
* **cycle-model consistency** — ``serve_report()`` steady-state throughput
  agrees with the per-pass ``report()`` numbers for the same layer table
  (within the issue's 5% bar; the model makes them exactly equal);
* **fused K-step scan** — ``make_gen_scan_step(K)`` serving is bitwise
  equal (xla) to the K=1 loop and the unbatched reference, in strictly
  fewer host dispatches, and the K amortisation shows up in the
  ``serve_report`` dispatch/calibration model;
* **SLO scheduling** — priority admission with FIFO-within-class and an
  aging bound, deadline-infeasible shedding off the stamped ``est_us``,
  timeout/cancel leaving slots reusable and results absent, and
  deterministic lane autoscaling;
* **bugfix pins** — DCGAN lane compiled once (warm ticks are pure
  dispatch), admission estimates priced off the server's actual geometry,
  and warm-steady throughput reported separately from the compile-laden
  whole-window numbers.

Tiny widths (8, 8) / 16x16 images keep the interpret-mode pallas loop
inside the tier-1 budget.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import calibrate as cal
from repro.core import cycle_model as cm
from repro.core import gen_spec
from repro.core.gen_spec import GEN_WORKLOADS
from repro.launch.serve_gen import (DEFAULT_SCAN_STEPS, GenServer, SLOClass,
                                    choose_scan_steps, init_noise,
                                    reference_sample)
from repro.launch.steps import (ddim_timesteps, make_gen_scan_step,
                                make_gen_step)
from repro.models import dcgan, unet_decoder

_WIDTHS = (8, 8)
_HW = 4
_SIZE = _HW * 2 ** len(_WIDTHS)      # 16x16 images


@pytest.fixture(scope="module")
def denoiser():
    return unet_decoder.init_denoiser_params(jax.random.PRNGKey(0),
                                             widths=_WIDTHS)


def _server(denoiser, batch=3, backend="xla", **kw):
    return GenServer(batch=batch, backend=backend, unet_widths=_WIDTHS,
                     unet_hw=_HW, params={"unet_dec": denoiser}, **kw)


# ------------------------------------------------------ queue invariants ---

def test_all_requests_complete_mixed_steps(denoiser):
    """7 requests with mixed step budgets drain through 3 slots."""
    srv = _server(denoiser, batch=3)
    steps = [4, 2, 5, 1, 3, 2, 4]
    rids = [srv.submit("unet_dec", steps=s, seed=i)
            for i, s in enumerate(steps)]
    images = srv.run()
    assert sorted(images) == sorted(rids)
    for rid in rids:
        assert images[rid].shape == (_SIZE, _SIZE, 3)
        assert np.isfinite(images[rid]).all()
    st = srv.stats()
    # work conservation: total device steps is bounded by the per-tick
    # batch, and every request ran its full trajectory
    assert st["device_steps"] * 3 >= sum(steps)
    assert st["requests"] == len(steps)


def test_admission_is_fifo_within_lane(denoiser):
    """A request never overtakes an earlier request for the same lane."""
    srv = _server(denoiser, batch=2)
    rids = [srv.submit("unet_dec", steps=3, seed=i) for i in range(6)]
    srv.run()
    admits = [srv.completed[r].admit_tick for r in rids]
    assert admits == sorted(admits)
    assert all(a >= 0 for a in admits)
    # the queue actually forced waiting (the invariant was exercised)
    assert srv.completed[rids[-1]].wait_ticks > 0


def test_deterministic_given_seeds(denoiser):
    subs = [(4, 11), (2, 12), (3, 13), (4, 14)]
    runs = []
    for _ in range(2):
        srv = _server(denoiser, batch=2)
        rids = [srv.submit("unet_dec", steps=s, seed=sd) for s, sd in subs]
        images = srv.run()
        runs.append([images[r] for r in rids])
    for a, b in zip(*runs):
        np.testing.assert_array_equal(a, b)
    # different seed -> different sample (the determinism is not collapse)
    assert not np.array_equal(runs[0][0], runs[0][3])


def test_inactive_slots_pass_through(denoiser):
    """Padding slots are bit-frozen by the active mask."""
    step = jax.jit(make_gen_step(), donate_argnums=(1,))
    x = jax.random.normal(jax.random.PRNGKey(3), (3, _SIZE, _SIZE, 3))
    x0 = np.asarray(x)
    batch = {"t": jnp.array([500, 400, 300], jnp.int32),
             "t_next": jnp.array([250, 200, -1], jnp.int32),
             "active": jnp.array([False, True, False])}
    y = np.asarray(step(denoiser, x, batch))
    np.testing.assert_array_equal(y[0], x0[0])
    np.testing.assert_array_equal(y[2], x0[2])
    assert not np.array_equal(y[1], x0[1])


def test_ddim_trajectories():
    traj = ddim_timesteps(5)
    assert traj[0] == 999 and traj[-1] == 0
    assert (np.diff(traj) < 0).all()
    assert list(ddim_timesteps(1)) == [999]
    with pytest.raises(ValueError):
        ddim_timesteps(0)


# ------------------------------------------- served vs unbatched reference ---

@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_served_matches_reference_loop(denoiser, backend):
    """The issue's parity bar: a request served inside a continuously
    rebatched mixed-timestep queue == the unbatched loop, <= 1e-5."""
    steps = [3, 1, 2] if backend == "pallas" else [4, 2, 3, 5]
    srv = _server(denoiser, batch=2, backend=backend)
    rids = [srv.submit("unet_dec", steps=s, seed=20 + i)
            for i, s in enumerate(steps)]
    images = srv.run()
    for i, rid in enumerate(rids):
        ref = reference_sample(denoiser, steps=steps[i], seed=20 + i,
                               image_size=_SIZE, backend=backend)
        assert np.abs(images[rid] - ref).max() <= 1e-5


def test_backends_agree_on_served_output(denoiser):
    """xla-served vs pallas-served: the fused parity-plane kernels drive the
    same sampling trajectory to <= 1e-5 *relative* scale (a short
    trajectory's rsqrt(alpha_bar) amplifies x0 to O(100), so the engines'
    1e-7 per-conv deviation is compared against the signal magnitude)."""
    outs = {}
    for backend in ("xla", "pallas"):
        srv = _server(denoiser, batch=2, backend=backend)
        rid = srv.submit("unet_dec", steps=2, seed=7)
        outs[backend] = srv.run()[rid]
    scale = max(1.0, float(np.abs(outs["xla"]).max()))
    assert np.abs(outs["xla"] - outs["pallas"]).max() / scale <= 1e-5


def test_dcgan_lane_single_shot():
    params = dcgan.init_params(jax.random.PRNGKey(1), size=64, nz=16, ngf=4)
    srv = GenServer(batch=2, dcgan_nz=16, params={"dcgan64": params})
    a = srv.submit("dcgan64", seed=5)
    b = srv.submit("dcgan64", seed=6)
    c = srv.submit("dcgan64", seed=5, steps=99)   # steps forced to 1
    images = srv.run()
    assert images[a].shape == (64, 64, 3)
    assert srv.completed[c].steps == 1
    np.testing.assert_array_equal(images[a], images[c])   # same seed
    assert not np.array_equal(images[a], images[b])
    # single-shot: z latent matches init_noise contract
    np.testing.assert_array_equal(
        np.asarray(init_noise(5, (16,))), np.asarray(init_noise(5, (16,))))


def test_unknown_workload_rejected(denoiser):
    with pytest.raises(ValueError, match="unknown workload"):
        _server(denoiser).submit("vae", steps=3)


# ------------------------------------------------- cycle-model consistency ---

@pytest.mark.parametrize("name", sorted(GEN_WORKLOADS))
def test_serve_report_consistent_with_report(name):
    layers = GEN_WORKLOADS[name]()
    base = cm.report(layers)
    srv = cm.serve_report(layers, steps=25)
    # the issue's bar: serving throughput ratio within 5% of the per-layer
    # report(); the model makes them exactly equal
    assert srv["serve_speedup_vs_naive"] == pytest.approx(
        base["speedup_vs_naive"], rel=0.05)
    assert srv["images_per_s_ours"] / srv["images_per_s_naive"] == \
        pytest.approx(base["speedup_vs_naive"], rel=1e-9)


def test_serve_report_scaling():
    layers = GEN_WORKLOADS["unet_dec"]()
    one = cm.serve_report(layers, steps=1)
    many = cm.serve_report(layers, steps=10, batch=4)
    # throughput scales 1/steps; latency scales steps * batch
    assert many["images_per_s_ours"] == pytest.approx(
        one["images_per_s_ours"] / 10, rel=1e-9)
    assert many["latency_ms_ours"] == pytest.approx(
        one["latency_ms_ours"] * 40, rel=1e-9)
    with pytest.raises(ValueError):
        cm.serve_report(layers, steps=0)


def _full_calibration(a=1e-3, b=5.0):
    """Coeffs for every engine kind (host-keyed), known slope/intercept."""
    return cal.Calibration({cal.key_of(k, "xla"): cal.Coeffs(a, b, 3)
                            for k in cal.KINDS})


def test_serve_report_scan_amortisation():
    """K-step fusion divides the per-image dispatch count (and only the
    dispatch term of the calibrated host estimate)."""
    layers = GEN_WORKLOADS["unet_dec"]()
    calib = _full_calibration(a=1e-3, b=5.0)
    r1 = cm.serve_report(layers, steps=8, calibration=calib)
    r4 = cm.serve_report(layers, steps=8, scan_steps=4, calibration=calib)
    assert r1["dispatches_per_image"] == 8
    assert r4["dispatches_per_image"] == 2
    # device throughput is scan-invariant; only host overhead amortises
    assert r4["images_per_s_ours"] == r1["images_per_s_ours"]
    compute, dispatch = calib.predict_layers_split(layers, backend="xla")
    assert r4["calibrated_us_per_image"] == pytest.approx(
        8 * compute + 2 * dispatch, rel=1e-9)
    assert r4["calibrated_us_per_image"] < r1["calibrated_us_per_image"]
    with pytest.raises(ValueError):
        cm.serve_report(layers, steps=4, scan_steps=0)


def test_serve_report_recovery_term():
    """``snapshot_every`` prices worst-case recovery (DESIGN.md §11):
    snapshot_every ticks of batch x scan_steps passes replay, in array
    cycles and (with a calibration) host wall time."""
    layers = GEN_WORKLOADS["unet_dec"]()
    calib = _full_calibration(a=1e-3, b=5.0)
    r = cm.serve_report(layers, steps=8, batch=2, scan_steps=4,
                        calibration=calib, snapshot_every=6)
    assert r["recovery_ticks_worst"] == 6
    # recovery cost = snapshot_every x one tick of batch*K passes
    tick_ms = 1e3 * 2 * 4 * cm.report(layers)["our_cycles"] / cm.FREQ_HZ
    assert r["recovery_ms_worst"] == pytest.approx(6 * tick_ms, rel=1e-9)
    compute, dispatch = calib.predict_layers_split(layers, backend="xla")
    assert r["calibrated_recovery_us_worst"] == pytest.approx(
        6 * (2 * 4 * compute + dispatch), rel=1e-9)
    # a tighter cadence bounds recovery lower, linearly
    r3 = cm.serve_report(layers, steps=8, batch=2, scan_steps=4,
                         snapshot_every=3)
    assert r3["recovery_ms_worst"] == pytest.approx(
        r["recovery_ms_worst"] / 2, rel=1e-9)
    # off by default: no recovery keys without a snapshot cadence
    r0 = cm.serve_report(layers, steps=8)
    assert "recovery_ms_worst" not in r0


def test_serve_percentiles_model():
    """The drain-simulation percentile model: deterministic, ordered, and
    conserving (every request completes; dispatches follow the tick sim)."""
    layers = GEN_WORKLOADS["unet_dec"]()
    steps_list = [8, 5, 3, 8, 5, 3]
    p = cm.serve_percentiles(layers, steps_list, batch=2, scan_steps=4)
    assert p["requests"] == len(steps_list)
    assert p["latency_p99_ms"] >= p["latency_p50_ms"] > 0
    assert p == cm.serve_percentiles(layers, steps_list, batch=2,
                                     scan_steps=4)
    # one request at a time, fused exactly: latency is ceil(s/K) ticks
    solo = cm.serve_percentiles(layers, [8], batch=1, scan_steps=4)
    assert solo["dispatches"] == 2
    # percentile helper: linear interpolation, no numpy dependency drift
    assert cm.np_percentile([1.0, 2.0, 3.0, 4.0], 50.0) == pytest.approx(2.5)
    assert cm.np_percentile([7.0], 99.0) == 7.0


def test_serve_report_percentile_keys():
    layers = GEN_WORKLOADS["unet_dec"]()
    rep = cm.serve_report(layers, steps=8, scan_steps=4,
                          steps_list=[8, 5, 3])
    assert rep["latency_p99_ms"] >= rep["latency_p50_ms"] > 0
    assert "latency_p50_ms" not in cm.serve_report(layers, steps=8)


# ----------------------------------------------------- fused K-step scan ---

def test_scan_step_matches_single_steps(denoiser):
    """lax.scan-fused K substeps == K separate jitted single steps, bitwise
    — including a slot whose trajectory tail is padding."""
    k = 3
    scan = jax.jit(make_gen_scan_step(k))
    one = jax.jit(make_gen_step())
    x = jax.random.normal(jax.random.PRNGKey(9), (2, _SIZE, _SIZE, 3))
    t = np.array([[999, 500, 250], [999, 0, 0]], np.int32)
    t_next = np.array([[500, 250, 0], [-1, -1, -1]], np.int32)
    act = np.array([[True, True, True], [True, False, False]])
    y_scan = np.asarray(scan(denoiser, x, {
        "t": jnp.asarray(t), "t_next": jnp.asarray(t_next),
        "active": jnp.asarray(act)}))
    y = x
    for j in range(k):
        y = one(denoiser, y, {"t": jnp.asarray(t[:, j]),
                              "t_next": jnp.asarray(t_next[:, j]),
                              "active": jnp.asarray(act[:, j])})
    np.testing.assert_array_equal(y_scan, np.asarray(y))
    with pytest.raises(ValueError):
        make_gen_scan_step(0)


def test_fused_scan_serving_bitwise_parity(denoiser):
    """The acceptance bar: a mixed-step request set served with K>1 fused
    steps per dispatch stays BITWISE equal (xla) to both the unbatched
    reference loop and the K=1 server — in fewer host dispatches."""
    steps = [4, 2, 3, 5]
    imgs, stats = {}, {}
    for k in (3, 1):
        srv = _server(denoiser, batch=2, scan_steps=k)
        rids = [srv.submit("unet_dec", steps=s, seed=30 + i)
                for i, s in enumerate(steps)]
        out = srv.run()
        imgs[k] = [out[r] for r in rids]
        stats[k] = srv.stats()
    for i, s in enumerate(steps):
        ref = reference_sample(denoiser, steps=s, seed=30 + i,
                               image_size=_SIZE)
        np.testing.assert_array_equal(imgs[3][i], ref)
        np.testing.assert_array_equal(imgs[1][i], ref)
    assert stats[3]["device_steps"] < stats[1]["device_steps"]
    # trajectory work is conserved: same substeps, fewer dispatches
    assert stats[3]["substeps"] == stats[1]["substeps"] == sum(steps)


def test_fused_scan_cross_backend(denoiser):
    """Fused-scan serving agrees across engines to <= 1e-5 relative scale
    (same bar as the K=1 cross-backend pin)."""
    outs = {}
    for backend in ("xla", "pallas"):
        srv = _server(denoiser, batch=2, backend=backend, scan_steps=2)
        rid = srv.submit("unet_dec", steps=3, seed=7)
        outs[backend] = srv.run()[rid]
    scale = max(1.0, float(np.abs(outs["xla"]).max()))
    assert np.abs(outs["xla"] - outs["pallas"]).max() / scale <= 1e-5


def test_choose_scan_steps():
    layers = GEN_WORKLOADS["unet_dec"]()
    # no calibration (or no coverage): the fixed default
    assert choose_scan_steps(None, layers) == DEFAULT_SCAN_STEPS
    assert choose_scan_steps(cal.Calibration(), layers) == DEFAULT_SCAN_STEPS
    calib = _full_calibration(a=1e-3, b=5.0)
    compute, dispatch = calib.predict_layers_split(layers, backend="xla")
    k = choose_scan_steps(calib, layers, target_tick_us=1e9)
    assert k == 8                                    # clamped at max_scan
    k = choose_scan_steps(calib, layers,
                          target_tick_us=dispatch + 2.5 * compute)
    assert k == 2                                    # floor of the budget
    assert choose_scan_steps(calib, layers, target_tick_us=0.0) == 1


# ------------------------------------------------------- SLO scheduling ---

def test_slo_priority_admission_and_fifo_within_class(denoiser):
    """Realtime overtakes earlier batch-class requests at admission, while
    same-class requests keep strict FIFO order."""
    srv = _server(denoiser, batch=1)
    a = srv.submit("unet_dec", steps=2, seed=0, slo="batch")
    b = srv.submit("unet_dec", steps=1, seed=1, slo="batch")
    c = srv.submit("unet_dec", steps=1, seed=2, slo="realtime")
    d = srv.submit("unet_dec", steps=1, seed=3, slo="realtime")
    images = srv.run()
    assert sorted(images) == [a, b, c, d]            # nobody starves
    admit = {r: srv.completed[r].admit_tick for r in (a, b, c, d)}
    assert admit[c] < admit[a] < admit[b]            # priority overtake
    assert admit[c] < admit[d]                       # FIFO within class
    assert srv.completed[c].slo.name == "realtime"


def test_slo_aging_prevents_starvation(denoiser):
    """A low-priority request older than starvation_ticks beats fresh
    high-priority arrivals."""
    srv = _server(denoiser, batch=1, starvation_ticks=2)
    old = srv.submit("unet_dec", steps=1, seed=0, slo="batch")
    fill = srv.submit("unet_dec", steps=3, seed=1, slo="realtime")
    srv.step()                                       # fill admitted, old waits
    srv.step()
    srv.step()                                       # old is now aged
    fresh = srv.submit("unet_dec", steps=1, seed=2, slo="realtime")
    srv.run()
    assert srv.completed[old].admit_tick < srv.completed[fresh].admit_tick
    assert srv.completed[fill].admit_tick == 0


def test_slo_shed_infeasible_deadline(denoiser):
    """A request whose calibrated est_us already exceeds its remaining
    deadline budget is shed at admission: no slot burnt, no result, status
    queryable — while feasible requests in the same queue complete."""
    srv = _server(denoiser, batch=2, calibration=_full_calibration())
    doomed = srv.submit("unet_dec", steps=4, seed=0,
                        slo=SLOClass("tight", 0, target_us=1e-3))
    ok = srv.submit("unet_dec", steps=2, seed=1)     # standard: no target
    images = srv.run()
    assert srv.request(doomed).status == "shed"
    assert doomed not in images and srv.request(doomed).result is None
    assert srv.request(doomed).est_us is not None    # the estimate was used
    assert ok in images
    assert srv.stats()["shed"] == 1


def test_unknown_slo_rejected(denoiser):
    with pytest.raises(ValueError, match="unknown SLO class"):
        _server(denoiser).submit("unet_dec", steps=1, slo="platinum")


# ---------------------------------------------------- timeout and cancel ---

def test_cancel_pending_and_active_slot_reuse(denoiser):
    """Cancel works queued and mid-flight; the vacated slot serves a later
    request to a bit-identical sample, and cancelled rids have no result."""
    srv = _server(denoiser, batch=1, scan_steps=1)
    active = srv.submit("unet_dec", steps=6, seed=0)
    queued = srv.submit("unet_dec", steps=2, seed=1)
    srv.step()                                       # `active` is in-flight
    assert srv.cancel(queued) and srv.request(queued).status == "cancelled"
    assert srv.cancel(active) and srv.request(active).status == "cancelled"
    assert not srv.cancel(active)                    # terminal: idempotent no
    fresh = srv.submit("unet_dec", steps=3, seed=42)
    images = srv.run()
    assert sorted(images) == [fresh]                 # cancelled rids absent
    ref = reference_sample(denoiser, steps=3, seed=42, image_size=_SIZE)
    np.testing.assert_array_equal(images[fresh], ref)
    st = srv.stats()
    assert st["cancelled"] == 2 and st["requests"] == 1


def test_timeout_expires_queued_and_inflight(denoiser):
    """timeout_ticks bounds a request's whole scheduler lifetime; expiry
    frees the slot for the queue behind it."""
    srv = _server(denoiser, batch=1, scan_steps=1)
    hog = srv.submit("unet_dec", steps=50, seed=0, timeout_ticks=2)
    waiting = srv.submit("unet_dec", steps=1, seed=1, timeout_ticks=1)
    patient = srv.submit("unet_dec", steps=2, seed=2)
    images = srv.run()
    assert srv.request(hog).status == "timeout"      # expired in-flight
    assert srv.request(waiting).status == "timeout"  # expired in queue
    assert sorted(images) == [patient]
    np.testing.assert_array_equal(
        images[patient],
        reference_sample(denoiser, steps=2, seed=2, image_size=_SIZE))
    assert srv.stats()["timeout"] == 2


# -------------------------------------------------------- lane autoscale ---

def test_autoscale_grows_and_shrinks_deterministically(denoiser):
    """Backlog doubles the lane batch up to max_batch; idleness halves it
    back after shrink_patience ticks; the batch-size trajectory and every
    sample are identical across reruns, and samples still match the
    unbatched reference bitwise (resizes repack state losslessly)."""
    def drive():
        srv = _server(denoiser, batch=1, scan_steps=2, autoscale=True,
                      max_batch=4, shrink_patience=1)
        rids = [srv.submit("unet_dec", steps=s, seed=50 + i)
                for i, s in enumerate([4, 3, 2, 5, 3])]
        sizes = []
        while srv._pending or any(l.busy for l in srv._lanes.values()):
            srv.step()
            sizes.append(srv._lanes["unet_dec"].batch)
        for _ in range(3):                           # idle: shrink kicks in
            srv.step()
            sizes.append(srv._lanes["unet_dec"].batch)
        return srv, rids, sizes
    srv, rids, sizes = drive()
    assert max(sizes) > 1          # backlog grew the lane
    assert sizes[-1] < max(sizes)  # idleness shrank it
    images = {r: srv.request(r).result for r in rids}
    for i, s in enumerate([4, 3, 2, 5, 3]):
        np.testing.assert_array_equal(
            images[rids[i]],
            reference_sample(denoiser, steps=s, seed=50 + i,
                             image_size=_SIZE))
    _, rids2, sizes2 = drive()
    assert sizes2 == sizes         # policy is a pure function of the queue
    # every batch size that dispatched was compiled exactly once
    assert srv._lanes["unet_dec"].compiled_sizes <= set(sizes)


# --------------------------------------------------------- bugfix sweep ---

def test_dcgan_lane_jits_once():
    """The lane forward is compiled once per batch shape; warm ticks are
    pure dispatch (the pre-fix path re-entered the module-level wrapper
    every tick)."""
    params = dcgan.init_params(jax.random.PRNGKey(1), size=64, nz=16, ngf=4)
    srv = GenServer(batch=2, dcgan_nz=16, params={"dcgan64": params})
    for i in range(6):
        srv.submit("dcgan64", seed=i)
    srv.run()
    lane = srv._lanes["dcgan64"]
    assert lane.device_steps == 3        # 6 requests / 2 slots: 3 warm ticks
    assert lane._step._cache_size() == 1  # one executable for all ticks
    assert lane.compiled_sizes == {2}


def test_admission_estimate_prices_actual_geometry(denoiser):
    """est_us must reflect the geometry THIS server executes, not the
    canonical tables (the pre-fix path priced smoke/test servers at
    canonical-width cost)."""
    calib = _full_calibration(a=1e-3, b=5.0)
    srv = _server(denoiser, calibration=calib)      # non-canonical widths
    est = srv.admission_estimate("unet_dec", steps=3)
    actual = calib.predict_layers(
        gen_spec.unet_decoder_layers(_WIDTHS, hw=_HW), backend="xla")
    canonical = calib.predict_layers(GEN_WORKLOADS["unet_dec"](),
                                     backend="xla")
    assert est == pytest.approx(3 * actual)
    assert est != pytest.approx(3 * canonical)      # the bug this pins
    # stamped onto requests at submit
    rid = srv.submit("unet_dec", steps=3, seed=0)
    assert srv.request(rid).est_us == pytest.approx(est)
    # canonical-geometry servers still price off the canonical tables
    srv_canon = GenServer(batch=1, calibration=calib)
    assert srv_canon.admission_estimate("unet_dec", steps=1) == \
        pytest.approx(canonical)
    # no calibration -> no estimate (never zero)
    assert _server(denoiser).admission_estimate("unet_dec", 3) is None


def test_stats_reports_warm_throughput(denoiser):
    """Whole-window throughput folds first-tick compile in (by design, for
    trajectory continuity); the warm_* keys must exclude it, mirroring how
    time_call excludes compile everywhere else."""
    srv = _server(denoiser, batch=1, scan_steps=1)
    for i in range(3):
        srv.submit("unet_dec", steps=2, seed=i)
    srv.run()
    st = srv.stats()
    assert 0 < st["warm_wall_s"] < st["wall_s"]
    # the compile tick dominates tiny-width walls, so excluding it must
    # strictly raise measured throughput
    assert st["warm_images_per_s"] > st["images_per_s"]
    assert st["warm_steps_per_s"] > 0
    assert st["latency_p99_s"] >= st["latency_p50_s"] > 0
