"""Generative serving path (DESIGN.md §9).

Three claim families from the serving issue:

* **queue packing** — with more requests than batch slots, every request
  completes (no starvation), admission is FIFO within a lane, and a server
  run is deterministic given the request seeds;
* **mixed-timestep batching is lossless** — a request served in a
  continuously-rebatched mixed-step queue matches the unbatched reference
  DDIM loop to <= 1e-5 on both backends (the transposed-conv geometry is
  timestep-invariant, so one compiled step serves the whole queue);
* **cycle-model consistency** — ``serve_report()`` steady-state throughput
  agrees with the per-pass ``report()`` numbers for the same layer table
  (within the issue's 5% bar; the model makes them exactly equal).

Tiny widths (8, 8) / 16x16 images keep the interpret-mode pallas loop
inside the tier-1 budget.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cycle_model as cm
from repro.core.gen_spec import GEN_WORKLOADS
from repro.launch.serve_gen import GenServer, init_noise, reference_sample
from repro.launch.steps import ddim_timesteps, make_gen_step
from repro.models import dcgan, unet_decoder

_WIDTHS = (8, 8)
_HW = 4
_SIZE = _HW * 2 ** len(_WIDTHS)      # 16x16 images


@pytest.fixture(scope="module")
def denoiser():
    return unet_decoder.init_denoiser_params(jax.random.PRNGKey(0),
                                             widths=_WIDTHS)


def _server(denoiser, batch=3, backend="xla", **kw):
    return GenServer(batch=batch, backend=backend, unet_widths=_WIDTHS,
                     unet_hw=_HW, params={"unet_dec": denoiser}, **kw)


# ------------------------------------------------------ queue invariants ---

def test_all_requests_complete_mixed_steps(denoiser):
    """7 requests with mixed step budgets drain through 3 slots."""
    srv = _server(denoiser, batch=3)
    steps = [4, 2, 5, 1, 3, 2, 4]
    rids = [srv.submit("unet_dec", steps=s, seed=i)
            for i, s in enumerate(steps)]
    images = srv.run()
    assert sorted(images) == sorted(rids)
    for rid in rids:
        assert images[rid].shape == (_SIZE, _SIZE, 3)
        assert np.isfinite(images[rid]).all()
    st = srv.stats()
    # work conservation: total device steps is bounded by the per-tick
    # batch, and every request ran its full trajectory
    assert st["device_steps"] * 3 >= sum(steps)
    assert st["requests"] == len(steps)


def test_admission_is_fifo_within_lane(denoiser):
    """A request never overtakes an earlier request for the same lane."""
    srv = _server(denoiser, batch=2)
    rids = [srv.submit("unet_dec", steps=3, seed=i) for i in range(6)]
    srv.run()
    admits = [srv.completed[r].admit_tick for r in rids]
    assert admits == sorted(admits)
    assert all(a >= 0 for a in admits)
    # the queue actually forced waiting (the invariant was exercised)
    assert srv.completed[rids[-1]].wait_ticks > 0


def test_deterministic_given_seeds(denoiser):
    subs = [(4, 11), (2, 12), (3, 13), (4, 14)]
    runs = []
    for _ in range(2):
        srv = _server(denoiser, batch=2)
        rids = [srv.submit("unet_dec", steps=s, seed=sd) for s, sd in subs]
        images = srv.run()
        runs.append([images[r] for r in rids])
    for a, b in zip(*runs):
        np.testing.assert_array_equal(a, b)
    # different seed -> different sample (the determinism is not collapse)
    assert not np.array_equal(runs[0][0], runs[0][3])


def test_inactive_slots_pass_through(denoiser):
    """Padding slots are bit-frozen by the active mask."""
    step = jax.jit(make_gen_step(), donate_argnums=(1,))
    x = jax.random.normal(jax.random.PRNGKey(3), (3, _SIZE, _SIZE, 3))
    x0 = np.asarray(x)
    batch = {"t": jnp.array([500, 400, 300], jnp.int32),
             "t_next": jnp.array([250, 200, -1], jnp.int32),
             "active": jnp.array([False, True, False])}
    y = np.asarray(step(denoiser, x, batch))
    np.testing.assert_array_equal(y[0], x0[0])
    np.testing.assert_array_equal(y[2], x0[2])
    assert not np.array_equal(y[1], x0[1])


def test_ddim_trajectories():
    traj = ddim_timesteps(5)
    assert traj[0] == 999 and traj[-1] == 0
    assert (np.diff(traj) < 0).all()
    assert list(ddim_timesteps(1)) == [999]
    with pytest.raises(ValueError):
        ddim_timesteps(0)


# ------------------------------------------- served vs unbatched reference ---

@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_served_matches_reference_loop(denoiser, backend):
    """The issue's parity bar: a request served inside a continuously
    rebatched mixed-timestep queue == the unbatched loop, <= 1e-5."""
    steps = [3, 1, 2] if backend == "pallas" else [4, 2, 3, 5]
    srv = _server(denoiser, batch=2, backend=backend)
    rids = [srv.submit("unet_dec", steps=s, seed=20 + i)
            for i, s in enumerate(steps)]
    images = srv.run()
    for i, rid in enumerate(rids):
        ref = reference_sample(denoiser, steps=steps[i], seed=20 + i,
                               image_size=_SIZE, backend=backend)
        assert np.abs(images[rid] - ref).max() <= 1e-5


def test_backends_agree_on_served_output(denoiser):
    """xla-served vs pallas-served: the fused parity-plane kernels drive the
    same sampling trajectory to <= 1e-5 *relative* scale (a short
    trajectory's rsqrt(alpha_bar) amplifies x0 to O(100), so the engines'
    1e-7 per-conv deviation is compared against the signal magnitude)."""
    outs = {}
    for backend in ("xla", "pallas"):
        srv = _server(denoiser, batch=2, backend=backend)
        rid = srv.submit("unet_dec", steps=2, seed=7)
        outs[backend] = srv.run()[rid]
    scale = max(1.0, float(np.abs(outs["xla"]).max()))
    assert np.abs(outs["xla"] - outs["pallas"]).max() / scale <= 1e-5


def test_dcgan_lane_single_shot():
    params = dcgan.init_params(jax.random.PRNGKey(1), size=64, nz=16, ngf=4)
    srv = GenServer(batch=2, dcgan_nz=16, params={"dcgan64": params})
    a = srv.submit("dcgan64", seed=5)
    b = srv.submit("dcgan64", seed=6)
    c = srv.submit("dcgan64", seed=5, steps=99)   # steps forced to 1
    images = srv.run()
    assert images[a].shape == (64, 64, 3)
    assert srv.completed[c].steps == 1
    np.testing.assert_array_equal(images[a], images[c])   # same seed
    assert not np.array_equal(images[a], images[b])
    # single-shot: z latent matches init_noise contract
    np.testing.assert_array_equal(
        np.asarray(init_noise(5, (16,))), np.asarray(init_noise(5, (16,))))


def test_unknown_workload_rejected(denoiser):
    with pytest.raises(ValueError, match="unknown workload"):
        _server(denoiser).submit("vae", steps=3)


# ------------------------------------------------- cycle-model consistency ---

@pytest.mark.parametrize("name", sorted(GEN_WORKLOADS))
def test_serve_report_consistent_with_report(name):
    layers = GEN_WORKLOADS[name]()
    base = cm.report(layers)
    srv = cm.serve_report(layers, steps=25)
    # the issue's bar: serving throughput ratio within 5% of the per-layer
    # report(); the model makes them exactly equal
    assert srv["serve_speedup_vs_naive"] == pytest.approx(
        base["speedup_vs_naive"], rel=0.05)
    assert srv["images_per_s_ours"] / srv["images_per_s_naive"] == \
        pytest.approx(base["speedup_vs_naive"], rel=1e-9)


def test_serve_report_scaling():
    layers = GEN_WORKLOADS["unet_dec"]()
    one = cm.serve_report(layers, steps=1)
    many = cm.serve_report(layers, steps=10, batch=4)
    # throughput scales 1/steps; latency scales steps * batch
    assert many["images_per_s_ours"] == pytest.approx(
        one["images_per_s_ours"] / 10, rel=1e-9)
    assert many["latency_ms_ours"] == pytest.approx(
        one["latency_ms_ours"] * 40, rel=1e-9)
    with pytest.raises(ValueError):
        cm.serve_report(layers, steps=0)
