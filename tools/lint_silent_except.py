#!/usr/bin/env python
"""Repo lint: no silently-swallowed exceptions under ``src/``.

The fault-tolerance layer (DESIGN.md §11) is built on failures *surfacing* —
retry ladders, degradation, checkpoint recovery all key off the exception
actually propagating to the right handler.  A silent ``except`` turns a
recoverable fault into corrupted state, so this lint fails CI on:

* a bare ``except:`` anywhere (catches ``KeyboardInterrupt``/``SystemExit``
  and hides everything);
* ``except Exception`` / ``except BaseException`` (alone or in a tuple)
  whose handler body is only ``pass`` / ``...`` — catching broadly is fine
  *when the handler does something* (fallback, re-raise, record); eating
  the error is not.

Usage::

    python tools/lint_silent_except.py [paths...]    # default: src/

Exit status 0 when clean, 1 with one ``path:line: message`` per violation.
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path

BROAD = ("Exception", "BaseException")


def _names(expr: ast.expr | None) -> list[str]:
    """Exception class names in an ``except`` clause (tuple-aware)."""
    if expr is None:
        return []
    elts = expr.elts if isinstance(expr, ast.Tuple) else [expr]
    out = []
    for e in elts:
        if isinstance(e, ast.Name):
            out.append(e.id)
        elif isinstance(e, ast.Attribute):
            out.append(e.attr)
    return out


def _body_is_silent(body: list[ast.stmt]) -> bool:
    return all(isinstance(s, ast.Pass)
               or (isinstance(s, ast.Expr)
                   and isinstance(s.value, ast.Constant)
                   and s.value.value is ...)
               for s in body)


def check_file(path: Path) -> list[str]:
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [f"{path}:{e.lineno}: syntax error: {e.msg}"]
    problems = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            problems.append(
                f"{path}:{node.lineno}: bare 'except:' — name the "
                f"exceptions (a bare except hides even KeyboardInterrupt)")
        elif (any(n in BROAD for n in _names(node.type))
                and _body_is_silent(node.body)):
            problems.append(
                f"{path}:{node.lineno}: 'except {ast.unparse(node.type)}' "
                f"with a pass-only body silently eats errors — handle, "
                f"log, or re-raise")
    return problems


def main(argv: list[str]) -> int:
    roots = [Path(a) for a in argv] or [Path("src")]
    problems: list[str] = []
    n = 0
    for root in roots:
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            n += 1
            problems.extend(check_file(f))
    for p in problems:
        print(p)
    print(f"[lint_silent_except] {n} files, {len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
