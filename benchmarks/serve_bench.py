"""Generative serving benchmark: measured continuous-batching drains plus
the cycle-model steady-state serving numbers (DESIGN.md §9).

Two row families, both riding ``BENCH_<rev>.json`` via ``benchmarks/run.py``:

* ``serve.<workload>`` — a real :class:`repro.launch.serve_gen.GenServer`
  drain on this host: N >= 4 concurrent requests with *mixed* step budgets
  through the batched DDIM loop with ``SCAN_STEPS`` DDIM steps fused per
  dispatch, plus the same request set at K=1 (``serve.unet_dec_k1``) —
  the fused drain is asserted to take strictly fewer host dispatches per
  image at equal step budgets — plus a single-shot DCGAN batch.
  Wall-time per device dispatch; images/s, p50/p99 request latency and
  dispatches/image in the derived column (collected into the
  ``serve_latency`` section of ``BENCH_<rev>.json`` and gated by
  ``perf_gate.py`` at wall-ratio tolerance).  Demo widths — the point is
  the serving-path plumbing and its trajectory over revisions, not peak
  FLOPs.
* ``serve_model.<workload>`` — :func:`repro.core.cycle_model.serve_report`
  at canonical widths: images/s on the paper's 168-MAC array, decomposed vs
  the naive zero-laden schedule.  The decomposed-vs-naive throughput ratio
  is asserted consistent (within 5%) with the per-pass ``report()`` numbers
  for the same layer table — the acceptance bar of the serving issue.

Usage:
  PYTHONPATH=src:. python benchmarks/serve_bench.py --smoke   # CI tier-1
  PYTHONPATH=src:. python benchmarks/serve_bench.py --csv
"""

from __future__ import annotations

import time

import jax

from repro.core import cycle_model as cm
from repro.core.gen_spec import GEN_WORKLOADS

#: DDIM step budget the canonical-width model rows assume per diffusion
#: sample (a typical few-dozen-step DDIM schedule); GANs are single-shot.
MODEL_STEPS = {"dcgan64": 1, "dcgan128": 1, "unet_dec": 25}

#: DDIM steps fused per dispatch in the measured ``serve.unet_dec`` drain
#: (the K of ``make_gen_scan_step``); ``serve.unet_dec_k1`` is the same
#: request set unfused, so the dispatch amortisation is visible per rev.
SCAN_STEPS = 4

#: snapshot cadence the model rows assume when pricing worst-case recovery
#: (``serve_report(snapshot_every=...)``, DESIGN.md §11)
MODEL_SNAPSHOT_EVERY = 4


def _measured_rows(rows: list, smoke: bool) -> None:
    from repro.launch.serve_gen import GenServer

    if smoke:
        widths, hw, n_req, steps = (8, 8), 4, 4, (4, 2, 3)
        nz, ngf = 16, 4
    else:
        widths, hw, n_req, steps = (16, 8, 8), 4, 8, (8, 5, 3, 6)
        nz, ngf = 32, 8

    def _drain(scan_steps: int):
        """Mixed-step diffusion drain through the batched K-step loop."""
        server = GenServer(batch=4, unet_widths=widths, unet_hw=hw,
                           dcgan_nz=nz, dcgan_ngf=ngf, scan_steps=scan_steps)
        for i in range(n_req):
            server.submit("unet_dec", steps=steps[i % len(steps)], seed=i)
        t0 = time.perf_counter()
        images = server.run()
        wall = time.perf_counter() - t0
        st = server.stats()
        assert len(images) == n_req, (len(images), n_req)
        return server, wall, st

    server, wall, st = _drain(SCAN_STEPS)
    _, wall1, st1 = _drain(1)
    # acceptance bar of the fused-sampling issue: at equal step budgets the
    # K-step scan takes strictly fewer host dispatches per image
    assert st["device_steps"] < st1["device_steps"], (
        st["device_steps"], st1["device_steps"])

    def _lat(st_: dict) -> str:
        return (f"p50_us={st_['latency_p50_s'] * 1e6:.0f},"
                f"p99_us={st_['latency_p99_s'] * 1e6:.0f}")

    rows.append((
        "serve.unet_dec",
        wall / max(st["device_steps"], 1) * 1e6,
        f"imgs_per_s={st['images_per_s']:.2f},"
        f"warm_imgs_per_s={st['warm_images_per_s']:.2f},reqs={n_req},"
        f"mixed_steps={'/'.join(map(str, steps))},"
        f"ticks={st['ticks']:.0f},mean_wait={st['mean_wait_ticks']:.1f},"
        f"scan_steps={SCAN_STEPS},"
        f"dispatches_per_image={st['device_steps'] / n_req:.2f},{_lat(st)}"))
    rows.append((
        "serve.unet_dec_k1",
        wall1 / max(st1["device_steps"], 1) * 1e6,
        f"imgs_per_s={st1['images_per_s']:.2f},reqs={n_req},"
        f"dispatches_per_image={st1['device_steps'] / n_req:.2f},{_lat(st1)}"))

    # single-shot GAN batch through the same scheduler (run() returns all
    # completed requests cumulatively, so check the new rids specifically)
    rids = [server.submit("dcgan64", seed=100 + i) for i in range(n_req)]
    t0 = time.perf_counter()
    images = server.run()
    wall = time.perf_counter() - t0
    assert all(images[r] is not None for r in rids)
    lats = sorted(server.request(r).latency_s for r in rids)
    rows.append(("serve.dcgan64", wall / n_req * 1e6,
                 f"imgs_per_s={n_req / wall:.2f},reqs={n_req},"
                 f"p50_us={cm.np_percentile(lats, 50.0) * 1e6:.0f},"
                 f"p99_us={cm.np_percentile(lats, 99.0) * 1e6:.0f}"))

    _fault_rows(rows, widths=widths, hw=hw, nz=nz, ngf=ngf, n_req=n_req,
                steps=steps)


def _fault_rows(rows: list, *, widths, hw, nz, ngf, n_req, steps) -> None:
    """Fault-tolerance trajectory rows (DESIGN.md §11).

    ``serve.recovery`` — a snapshotted drain is killed mid-flight and
    restored; the column is the restore cost (checkpoint load + lane
    rebuild + jit), the derived keys the recovered drain's throughput.  The
    recovered images are asserted bitwise-equal to an uninterrupted drain —
    the exact-resume acceptance bar, priced every revision.

    ``serve.degraded`` — a persistent injected pallas failure forces the
    retry ladder through backoff into per-lane xla fallback; the derived
    keys are the degraded drain's throughput, the ``stats()`` counters
    asserted to show exactly one degraded lane.
    """
    import tempfile

    import numpy as np

    from repro.distributed.fault_tolerance import failure_faults
    from repro.launch.serve_gen import GenServer

    # K=1 so the drain spans one tick per DDIM step — the kill tick must
    # land mid-flight (a K=SCAN_STEPS smoke drain finishes in one tick)
    kw = dict(batch=4, unet_widths=widths, unet_hw=hw, dcgan_nz=nz,
              dcgan_ngf=ngf, scan_steps=1)

    def _submit(server):
        for i in range(n_req):
            server.submit("unet_dec", steps=steps[i % len(steps)], seed=i)

    ref = GenServer(**kw)
    _submit(ref)
    ref_imgs = ref.run()

    with tempfile.TemporaryDirectory() as d:
        inj = failure_faults(kill_at=2)
        server = GenServer(snapshot_dir=d, snapshot_every=1, faults=inj, **kw)
        _submit(server)
        try:
            server.run()
            raise AssertionError("injected kill did not fire")
        except RuntimeError:
            pass
        t0 = time.perf_counter()
        restored = GenServer.restore(d)
        restore_wall = time.perf_counter() - t0
        t0 = time.perf_counter()
        imgs = restored.run()
        drain_wall = time.perf_counter() - t0
    assert sorted(imgs) == sorted(ref_imgs)
    for rid in ref_imgs:        # exact resume: bitwise, not just close
        assert np.array_equal(imgs[rid], ref_imgs[rid]), rid
    st = restored.stats()
    rows.append((
        "serve.recovery", restore_wall * 1e6,
        f"restore_us={restore_wall * 1e6:.0f},"
        f"recovered_imgs_per_s={n_req / drain_wall:.2f},reqs={n_req},"
        f"snapshot_every=1,snapshots={st['snapshots']:.0f},"
        f"recoveries={st['recoveries']:.0f}"))

    inj = failure_faults(backend_broken="pallas")
    server = GenServer(**dict(kw, backend="pallas", interpret=True),
                       faults=inj, max_retries=1, retry_backoff_s=1e-4)
    _submit(server)
    t0 = time.perf_counter()
    imgs = server.run()
    wall = time.perf_counter() - t0
    st = server.stats()
    assert len(imgs) == n_req and st["degraded"] >= 1, st
    rows.append((
        "serve.degraded", wall / max(st["device_steps"], 1) * 1e6,
        f"degraded_imgs_per_s={n_req / wall:.2f},reqs={n_req},"
        f"degraded={st['degraded']:.0f},retries={st['retries']:.0f},"
        f"recoveries={st['recoveries']:.0f}"))


def _mesh_rows(rows: list, smoke: bool) -> None:
    """Sharded-drain scaling rows (DESIGN.md §13): one ``serve.mesh_d<N>``
    row per device count, lanes spanning an N-device ``(data,)`` mesh via
    the ``image_sharding`` hook.  Emitted only when several devices exist
    (CI runs this under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    and merges the rows into the main ``BENCH_<rev>.json`` with
    ``--merge-json``); each drain's images are asserted bitwise-equal to
    the 1-device drain — scaling must never buy a different sample."""
    import numpy as np

    from repro.launch.mesh import make_train_mesh
    from repro.launch.serve_gen import GenServer

    n_dev = len(jax.devices())
    if n_dev < 2:
        return
    batch, n_req = 8, 6
    widths, hw = ((8, 8), 4) if smoke else ((16, 8, 8), 4)
    steps = (4, 2, 3)
    ref_imgs = None
    for nd in (1, 2, 4, 8):
        if nd > n_dev or batch % nd:
            continue
        server = GenServer(batch=batch, unet_widths=widths, unet_hw=hw,
                           dcgan_nz=16, dcgan_ngf=4, scan_steps=SCAN_STEPS,
                           mesh=make_train_mesh(nd))
        for i in range(n_req):
            server.submit("unet_dec", steps=steps[i % len(steps)], seed=i)
        t0 = time.perf_counter()
        images = server.run()
        wall = time.perf_counter() - t0
        st = server.stats()
        assert len(images) == n_req, (nd, len(images))
        if ref_imgs is None:
            ref_imgs = images
        else:
            for rid in ref_imgs:
                assert np.array_equal(images[rid], ref_imgs[rid]), (nd, rid)
        rows.append((
            f"serve.mesh_d{nd}",
            wall / max(st["device_steps"], 1) * 1e6,
            f"devices={nd},imgs_per_s={st['images_per_s']:.2f},"
            f"warm_imgs_per_s={st['warm_images_per_s']:.2f},reqs={n_req},"
            f"p50_us={st['latency_p50_s'] * 1e6:.0f},"
            f"p99_us={st['latency_p99_s'] * 1e6:.0f},"
            f"dispatches_per_image={st['device_steps'] / n_req:.2f}"))


def merge_json(rows: list, path: str | None = None) -> str:
    """Fold freshly measured rows into an existing ``BENCH_<rev>.json``.

    The CI mesh step runs this benchmark under 8 fake devices AFTER the
    main single-device ``benchmarks/run.py --smoke`` wrote its JSON; the
    sharded scaling rows belong in the same trajectory file, so they are
    appended here (replacing same-name rows) and the ``serve_latency``
    section re-derived.  ``device_count`` is stamped so ``perf_gate.py``
    can skip mesh rows across mesh-size changes.
    """
    import json

    from benchmarks.perf_gate import newest_bench
    from benchmarks.run import _serve_latency

    path = path or newest_bench()
    if path is None:
        raise SystemExit("--merge-json: no BENCH_*.json in cwd "
                         "(run benchmarks/run.py --smoke first)")
    with open(path) as f:
        payload = json.load(f)
    fresh = {name: (name, us, derived) for name, us, derived in rows}
    kept = [r for r in payload.get("rows", [])
            if r.get("name") not in fresh]
    payload["rows"] = kept + [
        {"name": n, "us_per_call": round(u, 1), "derived": d}
        for n, u, d in rows]
    merged = [(r["name"], r["us_per_call"], r["derived"])
              for r in payload["rows"]]
    payload["serve_latency"] = _serve_latency(merged)
    payload["device_count"] = len(jax.devices())
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def _model_rows(rows: list) -> None:
    for name, fn in GEN_WORKLOADS.items():
        # per-row timer: a shared t0 would fold every earlier workload's
        # cost into later rows' us_per_call column
        t0 = time.perf_counter()
        layers = fn()
        steps = MODEL_STEPS[name]
        scan = SCAN_STEPS if name == "unet_dec" else 1
        srv = cm.serve_report(layers, steps=steps, scan_steps=scan,
                              steps_list=[steps] * 4,
                              snapshot_every=MODEL_SNAPSHOT_EVERY)
        base = cm.report(layers)
        ratio = srv["serve_speedup_vs_naive"] / base["speedup_vs_naive"]
        # acceptance bar: serving throughput ratio consistent with the
        # per-pass report() speedup to within 5%
        assert abs(ratio - 1.0) <= 0.05, (name, ratio)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"serve_model.{name}", us,
            f"imgs_per_s={srv['images_per_s_ours']:.1f},"
            f"naive_imgs_per_s={srv['images_per_s_naive']:.1f},"
            f"serve_speedup={srv['serve_speedup_vs_naive']:.2f}x,"
            f"steps={steps},latency_ms={srv['latency_ms_ours']:.1f},"
            f"dispatches_per_image={srv['dispatches_per_image']:.0f},"
            f"model_p50_ms={srv['latency_p50_ms']:.1f},"
            f"model_p99_ms={srv['latency_p99_ms']:.1f},"
            f"recovery_ms_worst={srv['recovery_ms_worst']:.1f}"))


def run(csv: bool = False, smoke: bool = False,
        mesh_only: bool = False) -> list[tuple]:
    rows: list[tuple] = []
    if mesh_only:
        _mesh_rows(rows, smoke)
    else:
        _measured_rows(rows, smoke)
        _mesh_rows(rows, smoke)
        _model_rows(rows)
    if not csv:
        print(f"== Generative serving (backend={jax.default_backend()}"
              f"{'; smoke' if smoke else ''}) ==")
        for name, us, derived in rows:
            print(f"  {name:22s} {us:12.1f} us  {derived}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny widths / fewer requests (CI tier-1)")
    ap.add_argument("--csv", action="store_true", help="CSV rows only")
    ap.add_argument("--mesh-only", action="store_true",
                    help="only the sharded serve.mesh_d<N> scaling rows "
                         "(run under XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=8)")
    ap.add_argument("--merge-json", action="store_true",
                    help="append/replace this run's rows in the newest "
                         "BENCH_<rev>.json and re-derive serve_latency")
    ns = ap.parse_args()
    out = run(csv=ns.csv, smoke=ns.smoke, mesh_only=ns.mesh_only)
    if ns.csv:
        print("name,us_per_call,derived")
        for name, us, derived in out:
            print(f"{name},{us:.1f},{derived}")
    if ns.merge_json:
        import sys
        print(f"merged {len(out)} row(s) into {merge_json(out)}",
              file=sys.stderr)
