"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``BENCH_<rev>.json`` — the
per-kernel wall times, the fused/unfused and tuned/default ratio tables,
and the calibrated cycles->us prediction-error report
(``repro.core.calibrate``) — is written by default in ``--smoke`` mode and
under ``--emit-json`` otherwise, so the perf trajectory is machine-tracked
from the blocking tier-1 CI job (``benchmarks/perf_gate.py`` fails the
build on drift against the committed baseline; the non-blocking slow job
emits the full-size variant).  ``--no-json`` suppresses the file.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time


def _git_rev() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True, check=True,
                              timeout=10).stdout.strip()
    except Exception:
        return "unknown"


def _ratios(rows: list[tuple]) -> dict:
    """Pull the ``key=value`` ratio annotations out of the derived column."""
    out: dict[str, dict[str, float]] = {"fused_unfused": {}, "tuned_default": {}}
    for name, _, derived in rows:
        for part in str(derived).split(","):
            if "=" not in part:
                continue
            k, _, v = part.partition("=")
            try:
                val = float(v.rstrip("x"))
            except ValueError:
                continue
            if k in out:
                out[k][name] = val
    return out


#: derived keys of the measured ``serve.*`` rows that form the serving
#: latency trajectory (``perf_gate.py`` gates them at wall-ratio tolerance);
#: restore/degraded keys come from the fault-tolerance rows (DESIGN.md §11)
_SERVE_KEYS = ("p50_us", "p99_us", "dispatches_per_image",
               "restore_us", "recovered_imgs_per_s", "degraded_imgs_per_s",
               "imgs_per_s")


def _serve_latency(rows: list[tuple]) -> dict:
    """Latency-percentile section: p50/p99 and dispatch amortisation of the
    measured serving drains, keyed ``serve.<row>`` -> metric."""
    out: dict[str, dict[str, float]] = {}
    for name, _, derived in rows:
        if not name.startswith("serve."):
            continue
        for part in str(derived).split(","):
            k, _, v = part.partition("=")
            if k in _SERVE_KEYS:
                try:
                    out.setdefault(name, {})[k] = float(v)
                except ValueError:
                    continue
    return out


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--emit-json", action="store_true",
                    help="write BENCH_<rev>.json next to the CSV output "
                         "(implied by --smoke)")
    ap.add_argument("--no-json", action="store_true",
                    help="never write BENCH_<rev>.json (overrides both)")
    ap.add_argument("--smoke", action="store_true",
                    help="pass smoke mode to the kernel microbenchmarks; "
                         "emits BENCH_<rev>.json by default")
    ap.add_argument("--calibrate-backends", default="xla",
                    help="comma list of backends the calibration capture "
                         "times (default xla; add pallas on accelerators)")
    ns = ap.parse_args(argv)

    from benchmarks import (enet_roofline, fig10_enet_speedup,
                            fig11_dilated_layers, fig12_transposed_layers,
                            kernel_bench, mixed_precision, roofline,
                            serve_bench, table1_throughput)

    all_rows = []
    print("name,us_per_call,derived")
    for mod in (fig10_enet_speedup, fig11_dilated_layers,
                fig12_transposed_layers, table1_throughput, kernel_bench,
                serve_bench, enet_roofline, roofline):
        kw = ({"smoke": True}
              if (ns.smoke and mod in (kernel_bench, serve_bench)) else {})
        for name, us, derived in mod.run(csv=True, **kw):
            print(f"{name},{us:.1f},{derived}")
            all_rows.append((name, us, derived))

    # bf16/fp32 wall ratios + analytic-policy agreement (DESIGN.md §12);
    # measured once, feeding both the CSV stream and the JSON section
    mp_section = mixed_precision.section(smoke=ns.smoke)
    for name, us, derived in mixed_precision.rows(mp_section):
        print(f"{name},{us:.1f},{derived}")
        all_rows.append((name, us, derived))

    if (ns.emit_json or ns.smoke) and not ns.no_json:
        import jax

        from repro.core import calibrate

        backends = tuple(b for b in ns.calibrate_backends.split(",") if b)
        rev = _git_rev()
        payload = {
            "rev": rev,
            "generated_unix": time.time(),
            "backend": jax.default_backend(),
            "device_kind": jax.devices()[0].device_kind,
            # sharded serve.mesh_d<N> rows are only comparable at equal
            # mesh size; perf_gate skips them when this differs
            "device_count": len(jax.devices()),
            "jax_version": jax.__version__,
            "smoke": ns.smoke,
            "rows": [{"name": n, "us_per_call": round(u, 1), "derived": d}
                     for n, u, d in all_rows],
            "ratios": _ratios(all_rows),
            # measured serving p50/p99 + dispatches/image (DESIGN.md §9) —
            # gated by perf_gate.py like the wall-ratio families
            "serve_latency": _serve_latency(all_rows),
            # bf16/fp32 wall ratio per engine + analytic tiling policy vs
            # exhaustive sweep (DESIGN.md §12) — wall-class gate family
            "mixed_precision": mp_section,
            # calibrated cycles->us fit + prediction-error report per
            # (engine kind, backend, device kind) — the trajectory the
            # perf gate tracks (DESIGN.md §10)
            "calibration": calibrate.capture_and_fit(
                smoke=ns.smoke, backends=backends),
        }
        path = f"BENCH_{rev}.json"
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
        # stderr: stdout is the CSV stream (CI redirects it into bench.csv)
        print(f"wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
