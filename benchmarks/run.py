"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations


def main() -> None:
    from benchmarks import (enet_roofline, fig10_enet_speedup,
                            fig11_dilated_layers, fig12_transposed_layers,
                            kernel_bench, roofline, table1_throughput)

    print("name,us_per_call,derived")
    for mod in (fig10_enet_speedup, fig11_dilated_layers,
                fig12_transposed_layers, table1_throughput, kernel_bench,
                enet_roofline, roofline):
        for name, us, derived in mod.run(csv=True):
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
