"""Fig. 11 reproduction: per-dilation-rate speedup + efficiency vs ideal
sparse (paper: 83%-98%, higher speedup for larger D), plus an executable
cross-check that the decomposed convolution's MAC skip matches the model.

Costs BOTH workloads: ENet (the paper's test case) and ESPNet (the spatial
pyramid of dilated convolutions — Mehta et al. 2018), whose downsampling ESP
modules exercise the strided output-class schedule (DESIGN.md §2c).
"""

from __future__ import annotations

import time

from repro.core import cycle_model as cm
from repro.core import dilated as dil
from repro.core.enet_spec import dilated_layer_sets, enet_512_layers
from repro.core.espnet_spec import espnet_512_layers

WORKLOADS = {"enet": enet_512_layers, "espnet": espnet_512_layers}


def _epilogue_deltas() -> list[tuple]:
    """Measured fused-vs-unfused epilogue delta on the dilated engine
    (ESP-branch geometry; pallas — interpret-mode relative on CPU; shared
    measurement harness: ``benchmarks.kernel_bench``)."""
    from benchmarks.kernel_bench import epilogue_delta_rows
    from repro.kernels import ops
    from repro.kernels.epilogue import EpilogueSpec

    xs, ws = (1, 16, 16, 16), (3, 3, 16, 16)
    cases = [
        (f"epilogue_d{d}",
         lambda x, w, d=d, **ep: ops.dilated_conv2d(x, w, d, **ep), xs, ws)
        for d in (2, 8)
    ]
    return epilogue_delta_rows("fig11.", cases, iters=5,
                               spec=EpilogueSpec(bn=True, prelu=True))


def run(csv: bool = False, workloads: tuple[str, ...] = ("enet", "espnet")
        ) -> list[tuple]:
    rows = []
    for wl in workloads:
        layers = WORKLOADS[wl]()
        for D, ls in sorted(dilated_layer_sets(layers).items()):
            # per-group timer: a run-wide t0 would accumulate earlier
            # groups' cost into later rows' us_per_call column
            t0 = time.perf_counter()
            dense = sum(cm.cycles_ideal_dense(l) for l in ls)
            sparse = sum(cm.cycles_ideal_sparse(l) for l in ls)
            ours = sum(cm.cycles_our_decomposed(l) for l in ls)
            # executable cross-check from the layer set's own geometry
            # (input extent s*h_out), so the strided ESPNet branches exercise
            # the output-class MAC accounting
            mac_ratio = (
                sum(dil.macs_dense(l.stride * l.h_out, l.stride * l.w_out,
                                   l.cin, l.cout, l.kh, l.D + 1, l.stride)
                    for l in ls)
                / sum(dil.macs_decomposed(l.stride * l.h_out,
                                          l.stride * l.w_out, l.cin, l.cout,
                                          l.kh, l.D + 1, l.stride)
                      for l in ls))
            us = (time.perf_counter() - t0) * 1e6
            tag = f"fig11.{wl}.D{D}"
            rows.append((f"{tag}.speedup_x", us, f"{dense / ours:.2f}"))
            rows.append((f"{tag}.eff_vs_sparse_pct", us,
                         f"{100 * sparse / ours:.1f}"))
            rows.append((f"{tag}.mac_skip_ratio", us, f"{mac_ratio:.2f}"))
    rows += _epilogue_deltas()
    if not csv:
        print("== Fig. 11: dilated layers (ENet L1..L4 <-> D = 1,3,7,15; "
              "ESPNet pyramid D = 1,3,7 incl. strided) ==")
        print("   paper: efficiency 83%..98%, falling with D; speedup rising")
        for name, _, derived in rows:
            print(f"  {name:36s} {derived}")
    return rows


if __name__ == "__main__":
    run()
