"""Fig. 11 reproduction: per-dilation-rate speedup + efficiency vs ideal
sparse (paper: 83%-98%, higher speedup for larger D), plus an executable
cross-check that the decomposed convolution's MAC skip matches the model.
"""

from __future__ import annotations

import time

from repro.core import cycle_model as cm
from repro.core import dilated as dil
from repro.core.enet_spec import dilated_layer_sets, enet_512_layers


def run(csv: bool = False) -> list[tuple]:
    t0 = time.perf_counter()
    layers = enet_512_layers()
    rows = []
    for D, ls in sorted(dilated_layer_sets(layers).items()):
        dense = sum(cm.cycles_ideal_dense(l) for l in ls)
        sparse = sum(cm.cycles_ideal_sparse(l) for l in ls)
        ours = sum(cm.cycles_our_decomposed(l) for l in ls)
        mac_ratio = dil.macs_dense(64, 64, 1, 1, 3, D + 1) / \
            dil.macs_decomposed(64, 64, 1, 1, 3, D + 1)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig11.D{D}.speedup_x", us, f"{dense / ours:.2f}"))
        rows.append((f"fig11.D{D}.eff_vs_sparse_pct", us,
                     f"{100 * sparse / ours:.1f}"))
        rows.append((f"fig11.D{D}.mac_skip_ratio", us, f"{mac_ratio:.2f}"))
    if not csv:
        print("== Fig. 11: dilated layers (L1..L4 <-> D = 1,3,7,15) ==")
        print("   paper: efficiency 83%..98%, falling with D; speedup rising")
        for name, _, derived in rows:
            print(f"  {name:32s} {derived}")
    return rows


if __name__ == "__main__":
    run()
