"""Fig. 10 reproduction: ENet cycle breakdown + overall speedup.

Paper claims: dilated 85% -> 2%, transposed 7% -> 2%, general 8% -> 9%,
87.8% cycle reduction, 8.2x speedup over the ideal dense baseline.

Beyond the cycle model, two *measured* deltas on a representative ENet
bottleneck conv ride along (DESIGN.md §7): fused-epilogue vs unfused wall
time, and autotuned vs default tiling (both through the Pallas engine —
interpret-mode relative numbers on CPU hosts).
"""

from __future__ import annotations

import time

from repro.core import cycle_model as cm
from repro.core.enet_spec import enet_512_layers


def _measured_deltas() -> list[tuple]:
    """Fused/unfused + tuned/default on the ENet 3x3 bottleneck geometry
    (shared measurement harness: ``benchmarks.kernel_bench``)."""
    from benchmarks.kernel_bench import autotune_delta_rows, epilogue_delta_rows
    from repro.kernels import ops

    xs, ws = (1, 16, 16, 32), (3, 3, 32, 32)
    cases = [("bottleneck_epilogue",
              lambda x, w, **ep: ops.conv2d(x, w, **ep), xs, ws)]
    return (epilogue_delta_rows("fig10.", cases, iters=5)
            + autotune_delta_rows("fig10.bottleneck_tiles_", xs, ws, iters=5,
                                  cands=[(4, 64), (8, 128), (16, 128)]))


def run(csv: bool = False) -> list[tuple]:
    t0 = time.perf_counter()
    layers = enet_512_layers()
    rep = cm.report(layers)
    hl = cm.headline(layers)
    tr = cm.training_report(layers)
    us = (time.perf_counter() - t0) * 1e6

    rows = [
        ("fig10.share_dilated_pct", us, f"{rep['share_dilated_pct']:.1f} (paper 85)"),
        ("fig10.share_transposed_pct", us, f"{rep['share_transposed_pct']:.1f} (paper 7)"),
        ("fig10.share_general_pct", us, f"{rep['share_general_pct']:.1f} (paper 8)"),
        ("fig10.ours_dilated_pct", us, f"{rep['ours_dilated_pct']:.1f} (paper 2)"),
        ("fig10.ours_transposed_pct", us, f"{rep['ours_transposed_pct']:.1f} (paper 2)"),
        ("fig10.ours_general_pct", us, f"{rep['ours_general_pct']:.1f} (paper 9)"),
        ("fig10.cycle_reduction_pct", us, f"{rep['cycle_reduction_pct']:.1f} (paper 87.8)"),
        ("fig10.overall_speedup_x", us, f"{rep['overall_speedup']:.2f} (paper 8.2)"),
        ("fig10.speedup_vs_naive_x", us, f"{rep['speedup_vs_naive']:.2f} (zero-laden array schedule)"),
        ("fig10.headline_speedup_x", us, f"{hl['speedup']:.2f} (paper-mix normalized; paper 8.2)"),
        ("fig10.headline_reduction_pct", us, f"{hl['cycle_reduction_pct']:.1f} (paper 87.8)"),
        ("fig10.train_speedup_x", us, f"{tr['train_speedup_vs_naive']:.2f} (fwd+bwd, EcoFlow setting)"),
    ]
    rows += _measured_deltas()
    if not csv:
        print("== Fig. 10: ENet cycle counts (ideal-dense baseline = 100%) ==")
        for name, _, derived in rows:
            print(f"  {name:32s} {derived}")
    return rows


if __name__ == "__main__":
    run()
