"""Pallas kernel microbenchmarks (CPU interpret mode — relative numbers only;
the structural BlockSpec tiling is the TPU artifact).

Also measures the XLA-compiled decomposition vs naive zero-laden execution —
the paper's speedup mechanism, executable today on CPU via XLA.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(csv: bool = False) -> list[tuple]:
    rows = []
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)

    # XLA decomposition speedup (the paper's mechanism, executable form):
    # naive zero-inserted kernel vs phase-batched decomposition, D=1,3,7,15
    from repro.core import dilated as dil
    x = jax.random.normal(k1, (1, 64, 64, 32), jnp.float32)
    w = jax.random.normal(k2, (3, 3, 32, 32), jnp.float32)
    for D in (1, 3, 7, 15):
        d = D + 1
        naive = jax.jit(lambda x, w, d=d: dil.dilated_conv2d_naive(x, w, d))
        dec = jax.jit(lambda x, w, d=d: dil.dilated_conv2d_decomposed(x, w, d))
        t_n = _time(naive, x, w)
        t_d = _time(dec, x, w)
        rows.append((f"kern.dilated_D{D}.naive", t_n, ""))
        rows.append((f"kern.dilated_D{D}.decomposed", t_d,
                     f"speedup={t_n / t_d:.2f}x"))

    from repro.core import transposed as tr
    xt = jax.random.normal(k1, (1, 64, 64, 16), jnp.float32)
    wt = jax.random.normal(k2, (3, 3, 16, 16), jnp.float32)
    naive_t = jax.jit(lambda x, w: tr.transposed_conv2d_naive(x, w, 2, 1, 1))
    dec_t = jax.jit(
        lambda x, w: tr.transposed_conv2d_decomposed(x, w, 2, 1, 1))
    t_n, t_d = _time(naive_t, xt, wt), _time(dec_t, xt, wt)
    rows.append(("kern.transposed.naive", t_n, ""))
    rows.append(("kern.transposed.decomposed", t_d,
                 f"speedup={t_n / t_d:.2f}x"))

    # Pallas kernels, interpret mode (correct-by-construction check + timing)
    from repro.kernels import ops
    xp = jax.random.normal(k1, (1, 32, 32, 8), jnp.float32)
    wp = jax.random.normal(k2, (3, 3, 8, 16), jnp.float32)
    rows.append(("kern.pallas_conv2d.interp",
                 _time(lambda a, b: ops.conv2d(a, b), xp, wp, iters=2), ""))
    rows.append(("kern.pallas_tconv.interp",
                 _time(lambda a, b: ops.transposed_conv2d(a, b), xp,
                       jax.random.normal(k2, (3, 3, 8, 8)), iters=2), ""))
    a = jax.random.normal(k1, (256, 256), jnp.float32)
    b = jax.random.normal(k2, (256, 256), jnp.float32)
    rows.append(("kern.pallas_matmul.interp",
                 _time(lambda a, b: ops.matmul(a, b), a, b, iters=2), ""))
    q = jax.random.normal(k1, (1, 4, 256, 64), jnp.float32)
    rows.append(("kern.pallas_flashattn.interp",
                 _time(lambda q: ops.attention(q, q, q), q, iters=2), ""))

    if not csv:
        print("== Kernel microbenchmarks (CPU; Pallas in interpret mode) ==")
        for name, us, derived in rows:
            print(f"  {name:34s} {us:10.1f} us  {derived}")
    return rows


if __name__ == "__main__":
    run()
