"""Pallas kernel microbenchmarks.

On CPU hosts the Pallas kernels run in interpret mode (relative numbers
only; the structural BlockSpec tiling is the TPU artifact).  On a real
backend the kernels compile — ``interpret=None`` auto-detects via
``jax.default_backend()`` (override per-call to force either mode).

Also measures the XLA-compiled decomposition vs naive zero-laden execution —
the paper's speedup mechanism, executable today on CPU via XLA — including
the general (kernel, stride) transposed cases and the strided-dilated
output-class path served by the generalized engine.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(csv: bool = False) -> list[tuple]:
    rows = []
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)

    # XLA decomposition speedup (the paper's mechanism, executable form):
    # naive zero-inserted kernel vs phase-batched decomposition, D=1,3,7,15
    from repro.core import dilated as dil
    x = jax.random.normal(k1, (1, 64, 64, 32), jnp.float32)
    w = jax.random.normal(k2, (3, 3, 32, 32), jnp.float32)
    for D in (1, 3, 7, 15):
        d = D + 1
        naive = jax.jit(lambda x, w, d=d: dil.dilated_conv2d_naive(x, w, d))
        dec = jax.jit(lambda x, w, d=d: dil.dilated_conv2d_decomposed(x, w, d))
        t_n = _time(naive, x, w)
        t_d = _time(dec, x, w)
        rows.append((f"kern.dilated_D{D}.naive", t_n, ""))
        rows.append((f"kern.dilated_D{D}.decomposed", t_d,
                     f"speedup={t_n / t_d:.2f}x"))

    # strided-dilated (output-class schedule, DESIGN.md §2c)
    for d, s in ((4, 2), (8, 2), (4, 4)):
        naive = jax.jit(
            lambda x, w, d=d, s=s: dil.dilated_conv2d_naive(x, w, d, s))
        dec = jax.jit(
            lambda x, w, d=d, s=s: dil.dilated_conv2d_decomposed(
                x, w, d, stride=s))
        t_n, t_d = _time(naive, x, w), _time(dec, x, w)
        rows.append((f"kern.dilated_d{d}s{s}.naive", t_n, ""))
        rows.append((f"kern.dilated_d{d}s{s}.decomposed", t_d,
                     f"speedup={t_n / t_d:.2f}x"))

    from repro.core import transposed as tr
    xt = jax.random.normal(k1, (1, 64, 64, 16), jnp.float32)
    for k, s in ((3, 2), (2, 2), (4, 2), (5, 3), (4, 4)):
        wt = jax.random.normal(k2, (k, k, 16, 16), jnp.float32)
        p = (k - 1) // 2
        naive_t = jax.jit(
            lambda x, w, s=s, p=p: tr.transposed_conv2d_naive(x, w, s, p, 1))
        dec_t = jax.jit(
            lambda x, w, s=s, p=p: tr.transposed_conv2d_decomposed(
                x, w, s, p, 1))
        t_n, t_d = _time(naive_t, xt, wt), _time(dec_t, xt, wt)
        rows.append((f"kern.transposed_k{k}s{s}.naive", t_n, ""))
        rows.append((f"kern.transposed_k{k}s{s}.decomposed", t_d,
                     f"speedup={t_n / t_d:.2f}x"))

    # Pallas kernels (auto mode: interpret on CPU, compiled on accelerators)
    from repro.kernels import ops
    xp = jax.random.normal(k1, (1, 32, 32, 8), jnp.float32)
    wp = jax.random.normal(k2, (3, 3, 8, 16), jnp.float32)
    mode = "interp" if jax.default_backend() == "cpu" else "compiled"
    rows.append((f"kern.pallas_conv2d.{mode}",
                 _time(lambda a, b: ops.conv2d(a, b), xp, wp, iters=2), ""))
    rows.append((f"kern.pallas_tconv.{mode}",
                 _time(lambda a, b: ops.transposed_conv2d(a, b), xp,
                       jax.random.normal(k2, (3, 3, 8, 8)), iters=2), ""))
    rows.append((f"kern.pallas_tconv_k5s3.{mode}",
                 _time(lambda a, b: ops.transposed_conv2d(a, b, stride=3), xp,
                       jax.random.normal(k2, (5, 5, 8, 8)), iters=2), ""))
    a = jax.random.normal(k1, (256, 256), jnp.float32)
    b = jax.random.normal(k2, (256, 256), jnp.float32)
    rows.append((f"kern.pallas_matmul.{mode}",
                 _time(lambda a, b: ops.matmul(a, b), a, b, iters=2), ""))
    q = jax.random.normal(k1, (1, 4, 256, 64), jnp.float32)
    rows.append((f"kern.pallas_flashattn.{mode}",
                 _time(lambda q: ops.attention(q, q, q), q, iters=2), ""))

    if not csv:
        print(f"== Kernel microbenchmarks (backend={jax.default_backend()}; "
              f"Pallas mode={mode}) ==")
        for name, us, derived in rows:
            print(f"  {name:34s} {us:10.1f} us  {derived}")
    return rows


if __name__ == "__main__":
    run()
