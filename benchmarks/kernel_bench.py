"""Pallas kernel microbenchmarks.

On CPU hosts the Pallas kernels run in interpret mode (relative numbers
only; the structural BlockSpec tiling is the TPU artifact).  On a real
backend the kernels compile — ``interpret=None`` auto-detects via
``jax.default_backend()`` (override per-call to force either mode).

Also measures the XLA-compiled decomposition vs naive zero-laden execution —
the paper's speedup mechanism, executable today on CPU via XLA — including
the general (kernel, stride) transposed cases and the strided-dilated
output-class path served by the generalized engine.

Two perf-trajectory sections ride along (DESIGN.md §7):

* **fused vs unfused epilogues** — each engine with the full
  BN+PReLU+residual epilogue fused in-kernel vs the same kernel followed by
  the unfused :func:`repro.kernels.epilogue.apply_reference` passes
  (``fused/unfused`` < 1 means the fusion wins).  The win is an HBM-traffic
  property: it shows on real accelerator backends, where the unfused
  variant round-trips the conv output through HBM; on CPU interpret hosts
  everything is host memory and the ratio is ~1.0 plus per-tile interpreter
  noise — treat CPU values as plumbing smoke, not perf signal;
* **tuned vs default tiling** — the autotune sweep's winner vs the
  hard-coded ``(8, 128)`` tile (populates the on-disk autotune cache as a
  side effect, which CI persists between runs).

``--smoke`` runs a minimal subset of every section in seconds — wired into
the tier-1 CI job so the kernel-perf plumbing cannot silently rot.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 5) -> float:
    """Best-of-``iters`` wall time (us) after a compile/warmup call.

    Delegates to the shared blocking timer (``repro.kernels.util.time_call``)
    — one audited timed region for the whole repo: ``block_until_ready``
    inside the timing window (async dispatch must not record launch latency
    as kernel runtime) and minimum-of-N against scheduler-noise tails.
    """
    from repro.kernels.util import time_call

    return time_call(fn, *args, iters=iters) * 1e6


def epilogue_delta_rows(prefix: str, cases, iters: int,
                        spec=None) -> list[tuple]:
    """Fused-epilogue vs unfused-reference wall time for a list of engines.

    ``cases``: ``(name, call(x, w, **epilogue_kw), x_shape, w_shape)``
    tuples.  The single measurement harness shared by this module and the
    fig10/fig11 delta rows — emits ``<prefix><name>.{unfused,fused}`` rows
    with a ``fused_unfused=`` ratio the ``run.py`` JSON emitter collects.
    """
    from repro.kernels.epilogue import EpilogueSpec, apply_reference

    spec = EpilogueSpec(bn=True, prelu=True,
                        residual="pre_act") if spec is None else spec
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)

    def _make(call, ep):
        # plain closures (no function-valued default args) so jit caches one
        # trace per callable and the comparison is compiled-vs-compiled
        @jax.jit
        def unfused(x, w):
            return apply_reference(spec, call(x, w),
                                   tuple(ep[k] for k in spec.slots))

        @jax.jit
        def fused(x, w):
            return call(x, w, epilogue=spec, **ep)

        return unfused, fused

    rows = []
    for name, call, xs, ws in cases:
        x = jax.random.normal(k1, xs, jnp.float32)
        w = jax.random.normal(k2, ws, jnp.float32)
        cout = ws[-1]
        full = {
            "scale": jax.random.normal(k3, (cout,)) * 0.1 + 1.0,
            "shift": jnp.linspace(-0.5, 0.5, cout),
            "alpha": jnp.full((1,), 0.25),
            "residual": jnp.zeros(jax.eval_shape(call, x, w).shape,
                                  jnp.float32),
        }
        unfused, fused = _make(call, {k: full[k] for k in spec.slots})
        t_u = _time(unfused, x, w, iters=iters)
        t_f = _time(fused, x, w, iters=iters)
        rows.append((f"{prefix}{name}.unfused", t_u, ""))
        rows.append((f"{prefix}{name}.fused", t_f,
                     f"fused_unfused={t_f / t_u:.3f}"))
    return rows


def autotune_delta_rows(prefix: str, xs: tuple, ws: tuple, iters: int,
                        cands=None) -> list[tuple]:
    """Tuned vs default dense tiling on one geometry; persists the table."""
    from repro.kernels import autotune
    from repro.kernels.conv2d import conv2d

    tiles = autotune.tune("dense", xs, ws, iters=max(1, iters // 2),
                          cands=cands)
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, xs, jnp.float32)
    w = jax.random.normal(k2, ws, jnp.float32)
    dth, dtc = autotune.DEFAULT_TILES
    t_def = _time(lambda a, b: conv2d(a, b, th=dth, tc=dtc), x, w, iters=iters)
    t_tun = _time(lambda a, b: conv2d(a, b, th=tiles[0], tc=tiles[1]), x, w,
                  iters=iters)
    return [
        (f"{prefix}default", t_def, f"tiles={dth}x{dtc}"),
        (f"{prefix}tuned", t_tun,
         f"tiles={tiles[0]}x{tiles[1]},tuned_default={t_tun / t_def:.3f}"),
    ]


def _epilogue_rows(rows: list, iters: int, smoke: bool) -> None:
    """Fused-epilogue vs unfused-reference wall time, all three engines."""
    from repro.kernels import ops

    hw = 16 if smoke else 32
    cases = [
        ("dense", lambda x, w, **ep: ops.conv2d(x, w, **ep),
         (1, hw, hw, 8), (3, 3, 8, 16)),
        ("dilated_d2", lambda x, w, **ep: ops.dilated_conv2d(x, w, 2, **ep),
         (1, hw, hw, 8), (3, 3, 8, 16)),
        ("tconv_k3s2", lambda x, w, **ep: ops.transposed_conv2d(x, w, stride=2, **ep),
         (1, hw // 2, hw // 2, 8), (3, 3, 8, 16)),
    ]
    rows += epilogue_delta_rows("kern.epilogue_", cases, iters)


def _autotune_rows(rows: list, iters: int, smoke: bool) -> None:
    """Tuned vs default (8, 128) tiling; persists the autotune table."""
    hw = 16 if smoke else 64
    cands = [(4, 64), (8, 128)] if smoke else None
    rows += autotune_delta_rows("kern.autotune_dense.", (1, hw, hw, 8),
                                (3, 3, 8, 32), iters, cands=cands)


def run(csv: bool = False, smoke: bool = False) -> list[tuple]:
    rows = []
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    iters = 2 if smoke else 5

    # XLA decomposition speedup (the paper's mechanism, executable form):
    # naive zero-inserted kernel vs phase-batched decomposition, D=1,3,7,15
    from repro.core import dilated as dil
    x = jax.random.normal(k1, (1, 64, 64, 32), jnp.float32)
    w = jax.random.normal(k2, (3, 3, 32, 32), jnp.float32)
    for D in ((3,) if smoke else (1, 3, 7, 15)):
        d = D + 1
        naive = jax.jit(lambda x, w, d=d: dil.dilated_conv2d_naive(x, w, d))
        dec = jax.jit(lambda x, w, d=d: dil.dilated_conv2d_decomposed(x, w, d))
        t_n = _time(naive, x, w, iters=iters)
        t_d = _time(dec, x, w, iters=iters)
        rows.append((f"kern.dilated_D{D}.naive", t_n, ""))
        rows.append((f"kern.dilated_D{D}.decomposed", t_d,
                     f"speedup={t_n / t_d:.2f}x"))

    # strided-dilated (output-class schedule, DESIGN.md §2c)
    for d, s in (((4, 2),) if smoke else ((4, 2), (8, 2), (4, 4))):
        naive = jax.jit(
            lambda x, w, d=d, s=s: dil.dilated_conv2d_naive(x, w, d, s))
        dec = jax.jit(
            lambda x, w, d=d, s=s: dil.dilated_conv2d_decomposed(
                x, w, d, stride=s))
        t_n, t_d = _time(naive, x, w, iters=iters), _time(dec, x, w, iters=iters)
        rows.append((f"kern.dilated_d{d}s{s}.naive", t_n, ""))
        rows.append((f"kern.dilated_d{d}s{s}.decomposed", t_d,
                     f"speedup={t_n / t_d:.2f}x"))

    from repro.core import transposed as tr
    xt = jax.random.normal(k1, (1, 64, 64, 16), jnp.float32)
    for k, s in (((3, 2),) if smoke else ((3, 2), (2, 2), (4, 2), (5, 3), (4, 4))):
        wt = jax.random.normal(k2, (k, k, 16, 16), jnp.float32)
        p = (k - 1) // 2
        naive_t = jax.jit(
            lambda x, w, s=s, p=p: tr.transposed_conv2d_naive(x, w, s, p, 1))
        dec_t = jax.jit(
            lambda x, w, s=s, p=p: tr.transposed_conv2d_decomposed(
                x, w, s, p, 1))
        t_n, t_d = _time(naive_t, xt, wt, iters=iters), _time(dec_t, xt, wt, iters=iters)
        rows.append((f"kern.transposed_k{k}s{s}.naive", t_n, ""))
        rows.append((f"kern.transposed_k{k}s{s}.decomposed", t_d,
                     f"speedup={t_n / t_d:.2f}x"))

    # Pallas kernels (auto mode: interpret on CPU, compiled on accelerators)
    from repro.kernels import ops
    xp = jax.random.normal(k1, (1, 32, 32, 8), jnp.float32)
    wp = jax.random.normal(k2, (3, 3, 8, 16), jnp.float32)
    mode = "interp" if jax.default_backend() == "cpu" else "compiled"
    rows.append((f"kern.pallas_conv2d.{mode}",
                 _time(lambda a, b: ops.conv2d(a, b), xp, wp, iters=2), ""))
    rows.append((f"kern.pallas_tconv.{mode}",
                 _time(lambda a, b: ops.transposed_conv2d(a, b), xp,
                       jax.random.normal(k2, (3, 3, 8, 8)), iters=2), ""))
    if not smoke:
        rows.append((f"kern.pallas_tconv_k5s3.{mode}",
                     _time(lambda a, b: ops.transposed_conv2d(a, b, stride=3), xp,
                           jax.random.normal(k2, (5, 5, 8, 8)), iters=2), ""))
        a = jax.random.normal(k1, (256, 256), jnp.float32)
        b = jax.random.normal(k2, (256, 256), jnp.float32)
        rows.append((f"kern.pallas_matmul.{mode}",
                     _time(lambda a, b: ops.matmul(a, b), a, b, iters=2), ""))
        q = jax.random.normal(k1, (1, 4, 256, 64), jnp.float32)
        rows.append((f"kern.pallas_flashattn.{mode}",
                     _time(lambda q: ops.attention(q, q, q), q, iters=2), ""))

    # fused epilogues + autotuned tiling (DESIGN.md §7)
    _epilogue_rows(rows, iters, smoke)
    _autotune_rows(rows, iters, smoke)

    if not csv:
        print(f"== Kernel microbenchmarks (backend={jax.default_backend()}; "
              f"Pallas mode={mode}{'; smoke' if smoke else ''}) ==")
        for name, us, derived in rows:
            print(f"  {name:34s} {us:10.1f} us  {derived}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="minimal subset of every section (CI tier-1)")
    ap.add_argument("--csv", action="store_true", help="CSV rows only")
    ns = ap.parse_args()
    out = run(csv=ns.csv, smoke=ns.smoke)
    if ns.csv:
        print("name,us_per_call,derived")
        for name, us, derived in out:
            print(f"{name},{us:.1f},{derived}")
