"""Mixed-precision benchmark section (DESIGN.md §12).

Two measured tables feed the ``mixed_precision`` section of
``BENCH_<rev>.json``:

* **wall_ratio** — bf16/fp32 wall-time ratio per engine kind, one small
  geometry each through the real dispatcher (``calibrate.measure_case``).
  On CPU hosts bf16 may be *slower* than fp32 (emulated arithmetic); the
  number is tracked as a trajectory, not asserted against 1.0.
* **policy_vs_sweep** — the analytic tiling policy
  (:mod:`repro.kernels.tiling_policy`) against the exhaustive sweep on the
  same measured candidate times: whether the swept winner lands inside the
  policy's timed set (``agree``), and the measured-time ratio of the
  policy's pick to the swept winner (``time_ratio`` — 1.0 means the policy
  found the true winner; the acceptance bar is 1.05).

Both are wall-derived, so ``perf_gate.py`` gates them at the loose
wall-ratio tolerance and skips them across ``(backend, device kind)``
changes.
"""

from __future__ import annotations

from dataclasses import replace


#: geometries the policy-vs-sweep comparison times (smoke-sized: the full
#: candidate grid is exhaustively measured once per kind)
POLICY_GEOMETRIES = (
    ("dense", (1, 16, 16, 16), (3, 3, 16, 16), dict()),
    ("dilated", (1, 16, 16, 16), (3, 3, 16, 16), dict(dilation=2)),
    ("tconv", (1, 8, 8, 16), (3, 3, 16, 16), dict(stride=2)),
)


def wall_ratios(*, smoke: bool = True, backend: str = "xla",
                iters: int = 3) -> dict:
    """bf16/fp32 measured wall ratio per engine kind (smallest geometry)."""
    from repro.core import calibrate

    seen: dict[str, object] = {}
    for case in calibrate.default_cases(smoke):
        seen.setdefault(case.kind, case)     # first = smallest hw
    out = {}
    for kind, case in seen.items():
        us32 = calibrate.measure_case(case, backend=backend, iters=iters)
        us16 = calibrate.measure_case(replace(case, dtype="bfloat16"),
                                      backend=backend, iters=iters)
        out[kind] = {"fp32_us": round(us32, 1), "bf16_us": round(us16, 1),
                     "ratio": round(us16 / us32, 3)}
    return out


def policy_vs_sweep(*, iters: int = 2) -> dict:
    """Exhaustive sweep vs analytic policy on shared measured times.

    Every candidate of each geometry is timed ONCE; the sweep winner and
    the policy winner are both read off that one table, so ``time_ratio``
    compares selections, not re-measurements.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import autotune as at
    from repro.kernels import tiling_policy as tp

    out = {}
    for kind, x_shape, w_shape, kw in POLICY_GEOMETRIES:
        stride = kw.get("stride", 1)
        dilation = kw.get("dilation", 1)
        h_out = x_shape[1] if kind == "tconv" else -(-x_shape[1] // stride)
        cands = at.candidates(h_out, w_shape[3])
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(k1, x_shape, jnp.float32)
        w = jax.random.normal(k2, w_shape, jnp.float32)
        times = {}
        for th, tc in cands:
            call = at._build_call(kind, x, w, th, tc, stride, dilation,
                                  None, None)
            times[(th, tc)] = at._time_candidate(call, iters)
        sweep_winner = min(cands, key=lambda c: times[c])
        policy_set = tp.top_candidates(
            kind, x_shape, w_shape, cands, top=at.POLICY_TOP,
            default_tiles=at.DEFAULT_TILES, stride=stride,
            dilation=dilation, dtype=jnp.float32)
        policy_winner = min(policy_set, key=lambda c: times[c])
        out[kind] = {
            "n_candidates": len(cands),
            "n_timed_policy": len(policy_set),
            "agree": sweep_winner in policy_set,
            "time_ratio": round(times[policy_winner] / times[sweep_winner],
                                4),
        }
    return out


def section(*, smoke: bool = True, backend: str = "xla") -> dict:
    """The full ``mixed_precision`` payload section."""
    return {
        "backend": backend,
        "wall_ratio": wall_ratios(smoke=smoke, backend=backend),
        "policy_vs_sweep": policy_vs_sweep(),
    }


def rows(sec: dict) -> list[tuple[str, float, str]]:
    """CSV rows (name, us, derived) for the printed benchmark stream."""
    out = []
    for kind, r in sorted(sec["wall_ratio"].items()):
        out.append((f"mixed.{kind}", r["bf16_us"],
                    f"bf16_fp32_ratio={r['ratio']}x"))
    for kind, r in sorted(sec["policy_vs_sweep"].items()):
        out.append((f"policy.{kind}", 0.0,
                    f"agree={int(r['agree'])},time_ratio={r['time_ratio']},"
                    f"timed={r['n_timed_policy']}/{r['n_candidates']}"))
    return out
