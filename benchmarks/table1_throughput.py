"""Table I reproduction (throughput rows): peak vs zero-skipping GOPS.

Paper: 168 GOPS peak / 1377 GOPS logical on ENet.  Area and power rows are
silicon measurements — out of scope for a software reproduction (noted in
DESIGN.md §1).
"""

from __future__ import annotations

import time

from repro.core import cycle_model as cm
from repro.core.enet_spec import enet_512_layers


def run(csv: bool = False) -> list[tuple]:
    t0 = time.perf_counter()
    rep = cm.report(enet_512_layers())
    us = (time.perf_counter() - t0) * 1e6
    rows = [
        ("table1.peak_gops", us, f"{rep['peak_gops']:.0f} (paper 168)"),
        ("table1.effective_gops_enet", us,
         f"{rep['effective_gops']:.0f} (paper 1377)"),
        ("table1.macs_per_cycle", us, f"{cm.MACS_PER_CYCLE}"),
        ("table1.freq_mhz", us, f"{cm.FREQ_HZ / 1e6:.0f}"),
    ]
    if not csv:
        print("== Table I: throughput (software-reproducible rows) ==")
        for name, _, derived in rows:
            print(f"  {name:32s} {derived}")
    return rows


if __name__ == "__main__":
    run()
