"""Trace-driven perf-regression gate over ``BENCH_<rev>.json`` (DESIGN.md §10).

Compares the current bench JSON against the last committed baseline and
exits nonzero when a tracked number drifts beyond tolerance — the CI step
that turns the bench trajectory from an uploaded artifact into an enforced
contract.

Three entry families, with per-family tolerances (all relative):

* **model** — deterministic cycle-model numbers parsed from the benchmark
  rows' derived column (``fig10.*``, ``fig11.*``, ``fig12.*``, ``table1.*``,
  ``serve_model.*``).  These only change when the model changes, so the
  default tolerance is tight (1%): an unintended drift here means a
  modeled *claim* regressed.
* **ratio** — the measured wall-time ratio tables (``fused_unfused``,
  ``tuned_default``).  Wall noise on shared CI hosts is real; default
  tolerance is loose (75% relative), catching order-of-magnitude rot, not
  jitter.
* **serve** — the measured serving-latency section (``serve_latency``):
  p50/p99 request latency and host dispatches per image of the
  ``serve.*`` drains.  Wall-derived, so gated at the same loose tolerance
  class as **ratio** (``--serve-tol``) and skipped across
  ``(backend, device kind)`` changes; the sharded ``serve.mesh_d<N>``
  scaling rows are additionally skipped when the two files' simulated
  ``device_count`` differs (DESIGN.md §13).
* **mixed** — the ``mixed_precision`` section (DESIGN.md §12): bf16/fp32
  wall ratio per engine and the analytic-policy-vs-sweep ``time_ratio``.
  Wall-derived; gated at the **ratio** tolerance and skipped cross-host.
* **calibration** — the calibrated prediction-error report: per
  ``(kind, backend, device kind)`` key, the MAPE may not grow by more than
  ``--mape-slack`` percentage points over baseline (a growing MAPE means
  the cycle model is drifting away from what the hardware does), and the
  fitted us/cycle slope may not drift beyond ``--calib-tol``.

Wall-time-derived comparisons (ratio + calibration) only apply when the two
files were produced by the same ``(backend, device kind)`` — cross-machine
wall numbers are not comparable and are skipped with a note.  Entries
present in the baseline but missing from the current file FAIL the gate
(a silently vanished row is how trajectories become empty lists).

Usage:
  python benchmarks/perf_gate.py                          # newest BENCH_*.json
      --baseline benchmarks/baselines/bench_smoke_baseline.json
  python benchmarks/perf_gate.py --current BENCH_abc.json --baseline old.json
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

#: benchmark-row prefixes whose derived column is a deterministic
#: cycle-model number (pure function of the model, no wall time)
MODEL_PREFIXES = ("fig10.", "fig11.", "fig12.", "table1.", "serve_model.")


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def newest_bench(directory: str = ".") -> str | None:
    paths = glob.glob(os.path.join(directory, "BENCH_*.json"))
    return max(paths, key=os.path.getmtime) if paths else None


def _model_number(derived: str) -> float | None:
    """A derived column that is one bare number is a model entry value."""
    try:
        return float(str(derived).rstrip("x%"))
    except ValueError:
        return None


def extract(payload: dict) -> dict[str, dict[str, float]]:
    """Flatten a bench JSON into gate-comparable ``family -> name -> value``."""
    out: dict[str, dict[str, float]] = {
        "model": {}, "ratio": {}, "serve": {}, "calib_slope": {},
        "calib_mape": {}, "mixed": {},
    }
    for row in payload.get("rows", []):
        name = row.get("name", "")
        if name.startswith(MODEL_PREFIXES):
            val = _model_number(row.get("derived", ""))
            if val is not None:
                out["model"][name] = val
    for family, table in payload.get("ratios", {}).items():
        for name, val in table.items():
            out["ratio"][f"{family}/{name}"] = float(val)
    for row, metrics in payload.get("serve_latency", {}).items():
        for key, val in metrics.items():
            out["serve"][f"{row}/{key}"] = float(val)
    calib = payload.get("calibration", {})
    for key, co in calib.get("fit", {}).get("coeffs", {}).items():
        out["calib_slope"][key] = float(co.get("a_us_per_cycle", 0.0))
    for key, err in calib.get("errors", {}).items():
        out["calib_mape"][key] = float(err.get("mape_pct", 0.0))
    mp = payload.get("mixed_precision", {})
    for kind, r in mp.get("wall_ratio", {}).items():
        out["mixed"][f"wall_ratio/{kind}"] = float(r.get("ratio", 0.0))
    for kind, r in mp.get("policy_vs_sweep", {}).items():
        out["mixed"][f"policy/{kind}/time_ratio"] = float(
            r.get("time_ratio", 0.0))
    return out


def _same_host(cur: dict, base: dict) -> bool:
    keys = ("backend", "device_kind")
    return all(cur.get(k) == base.get(k) for k in keys)


def compare(cur: dict, base: dict, *, model_tol: float = 0.01,
            ratio_tol: float = 0.75, serve_tol: float = 0.75,
            calib_tol: float = 1.0,
            mape_slack: float = 10.0) -> tuple[list[str], list[str]]:
    """Gate the current payload against the baseline.

    Returns ``(violations, notes)`` — the gate fails iff ``violations`` is
    non-empty.  Tolerances are relative drift ``|cur/base - 1|`` except
    ``mape_slack`` (absolute percentage points, one-sided: improvements
    never fail).
    """
    cur_e, base_e = extract(cur), extract(base)
    violations: list[str] = []
    notes: list[str] = []
    wall_ok = _same_host(cur, base)
    if not wall_ok:
        notes.append(
            f"wall-derived families skipped: baseline host "
            f"({base.get('backend')}/{base.get('device_kind')}) != current "
            f"({cur.get('backend')}/{cur.get('device_kind')})")

    def rel_gate(family: str, tol: float) -> None:
        for name, bval in sorted(base_e[family].items()):
            cval = cur_e[family].get(name)
            if cval is None:
                violations.append(f"[{family}] {name}: present in baseline, "
                                  f"missing from current")
                continue
            if bval == 0.0:
                if cval != 0.0:
                    violations.append(f"[{family}] {name}: baseline 0, "
                                      f"current {cval:.4g}")
                continue
            drift = abs(cval / bval - 1.0)
            if drift > tol:
                violations.append(
                    f"[{family}] {name}: {bval:.4g} -> {cval:.4g} "
                    f"({100 * drift:.1f}% drift > {100 * tol:.0f}% tol)")

    # sharded serve.mesh_d<N> rows only compare at equal mesh size — a CI
    # change to the fake-device count must not read as a latency regression
    if cur.get("device_count") != base.get("device_count"):
        mesh = [n for n in set(base_e["serve"]) | set(cur_e["serve"])
                if n.startswith("serve.mesh")]
        if mesh:
            notes.append(
                f"{len(mesh)} serve.mesh entries skipped: device_count "
                f"{base.get('device_count')} -> {cur.get('device_count')}")
        for n in mesh:
            base_e["serve"].pop(n, None)
            cur_e["serve"].pop(n, None)

    rel_gate("model", model_tol)
    if wall_ok:
        rel_gate("ratio", ratio_tol)
        rel_gate("serve", serve_tol)
        rel_gate("mixed", ratio_tol)
        rel_gate("calib_slope", calib_tol)
        for key, bmape in sorted(base_e["calib_mape"].items()):
            cmape = cur_e["calib_mape"].get(key)
            if cmape is None:
                violations.append(f"[calib_mape] {key}: present in baseline, "
                                  f"missing from current")
            elif cmape > bmape + mape_slack:
                violations.append(
                    f"[calib_mape] {key}: prediction error grew "
                    f"{bmape:.1f}% -> {cmape:.1f}% (> +{mape_slack:.0f}pt)")
    new = [n for fam in cur_e for n in cur_e[fam] if n not in base_e[fam]]
    if new:
        notes.append(f"{len(new)} new entries not in baseline (tracked from "
                     f"the next baseline refresh)")
    return violations, notes


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default=None,
                    help="current BENCH_<rev>.json (default: newest in cwd)")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/bench_smoke_baseline.json")
    ap.add_argument("--model-tol", type=float, default=0.01)
    ap.add_argument("--ratio-tol", type=float, default=0.75)
    ap.add_argument("--serve-tol", type=float, default=0.75)
    ap.add_argument("--calib-tol", type=float, default=1.0)
    ap.add_argument("--mape-slack", type=float, default=10.0)
    ns = ap.parse_args(argv)

    current = ns.current or newest_bench()
    if current is None:
        print("perf-gate: no BENCH_*.json found in cwd", file=sys.stderr)
        return 2
    if not os.path.exists(ns.baseline):
        # bootstrap: a branch that predates the committed baseline passes
        # with a note — the gate arms itself once a baseline lands
        print(f"perf-gate: no baseline at {ns.baseline}; PASS (bootstrap)")
        return 0
    cur, base = load(current), load(ns.baseline)
    violations, notes = compare(
        cur, base, model_tol=ns.model_tol, ratio_tol=ns.ratio_tol,
        serve_tol=ns.serve_tol, calib_tol=ns.calib_tol,
        mape_slack=ns.mape_slack)
    print(f"perf-gate: {current} vs {ns.baseline} "
          f"(baseline rev {base.get('rev', '?')})")
    for n in notes:
        print(f"  note: {n}")
    if violations:
        for v in violations:
            print(f"  FAIL {v}")
        print(f"perf-gate: {len(violations)} violation(s)")
        return 1
    print("perf-gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
