"""ENet on the TPU roofline: naive zero-laden execution vs the paper's
decomposition, measured on the *compiled HLO* (FLOPs/bytes from the
loop-aware analyzer) — the XLA-level counterpart of Fig. 10.

This is the cell most representative of the paper's technique; §Perf
hillclimbs it (ragged -> phase-batched -> fused stitching).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.distributed.hlo_analysis import V5E, analyze, roofline_terms


def _enet_flops(decomposed: bool, batch: int = 1, hw: int = 512):
    from repro.models import enet

    params = jax.eval_shape(
        lambda k: enet.init_params(k, 19), jax.random.PRNGKey(0))
    x = jax.ShapeDtypeStruct((batch, hw, hw, 3), jnp.float32)
    lowered = jax.jit(
        lambda p, x: enet.forward(p, x, decomposed=decomposed)).lower(
            params, x)
    return analyze(lowered.compile().as_text())


def run(csv: bool = False) -> list[tuple]:
    rows = []
    t0 = time.perf_counter()
    naive = _enet_flops(False)
    dec = _enet_flops(True)
    us = (time.perf_counter() - t0) * 1e6

    cut = 100.0 * (1 - dec.flops / naive.flops)
    rows.append(("enet_hlo.naive_gflops", us, f"{naive.flops/1e9:.2f}"))
    rows.append(("enet_hlo.decomposed_gflops", us, f"{dec.flops/1e9:.2f}"))
    rows.append(("enet_hlo.flop_cut_pct", us,
                 f"{cut:.1f} (paper cycle cut: 87.8)"))
    rows.append(("enet_hlo.flop_speedup_x", us,
                 f"{naive.flops/dec.flops:.2f} (paper: 8.2)"))
    tn, td = roofline_terms(naive), roofline_terms(dec)
    for k in ("compute_s", "memory_s"):
        rows.append((f"enet_hlo.naive_{k}", us, f"{tn[k]*1e3:.3f} ms"))
        rows.append((f"enet_hlo.dec_{k}", us, f"{td[k]*1e3:.3f} ms"))
    bound_n = "compute" if tn["compute_s"] > tn["memory_s"] else "memory"
    bound_d = "compute" if td["compute_s"] > td["memory_s"] else "memory"
    rows.append(("enet_hlo.naive_bound", us, bound_n))
    rows.append(("enet_hlo.dec_bound", us, bound_d))

    if not csv:
        print("== ENet @512x512 compiled-HLO roofline (1 v5e chip) ==")
        for name, _, derived in rows:
            print(f"  {name:30s} {derived}")
    return rows


if __name__ == "__main__":
    run()
