"""Fig. 12 reproduction: transposed layers at output sizes 128/256/512 —
efficiency vs ideal sparse (paper: up to 99%, loss from input tiling).

Beyond the paper's ENet layers (k=3, s=2), two extra sweeps cost what the
engine now executes:

* the general (kernel, stride) parity schedules — the modeled speedup tracks
  the ``s*s / (k/s-rounding)`` MAC-skip ratio of DESIGN.md §3;
* the generative decoder workloads (``repro.core.gen_spec``: DCGAN 64/128
  generators, diffusion U-Net decoder) — EcoFlow's setting, where transposed
  convolution is the whole network rather than a decoder tail.  Each row set
  carries an executable MAC-skip cross-check computed from the layer set's
  own (k, s, padding, output_padding) geometry.
"""

from __future__ import annotations

import time

from repro.core import cycle_model as cm
from repro.core import transposed as tr
from repro.core.enet_spec import ConvLayer, enet_512_layers, transposed_layer_sets
from repro.core.gen_spec import GEN_WORKLOADS

# general-engine sweep: (kernel, stride) pairs served by the parity schedule
GENERAL_CASES = [(2, 2), (3, 2), (4, 2), (5, 2), (3, 3), (4, 3), (4, 4), (5, 4)]


def _tconv_mac_skip(layers: list[ConvLayer]) -> float:
    """naive/decomposed MAC ratio of the transposed layers from their own
    geometry (exactly 4.0 for the even-k exact-2x generative chains)."""
    naive = dec = 0
    for l in layers:
        if l.kind != "transposed":
            continue
        h_in, w_in = cm.tconv_input_size(l)
        p_lo, p_hi = cm.tconv_pads(l)
        naive += tr.macs_naive(h_in, w_in, l.cin, l.cout, l.kh, l.stride,
                               p_lo, p_hi)
        dec += tr.macs_decomposed_transposed(h_in, w_in, l.cin, l.cout,
                                             l.kh, l.stride, p_lo, p_hi)
    # a workload with no transposed layers skips nothing (neutral 1.0, like
    # cycle_model's absent-group speedup) rather than dividing by zero
    return naive / dec if dec else 1.0


def run(csv: bool = False) -> list[tuple]:
    layers = enet_512_layers()
    rows = []
    for size, ls in sorted(transposed_layer_sets(layers).items()):
        # per-group timer (not run-wide): us_per_call must not accumulate
        # earlier groups' cost
        t0 = time.perf_counter()
        dense = sum(cm.cycles_ideal_dense(l) for l in ls)
        sparse = sum(cm.cycles_ideal_sparse(l) for l in ls)
        ours = sum(cm.cycles_our_decomposed(l) for l in ls)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig12.L{size}.speedup_x", us, f"{dense / ours:.2f}"))
        rows.append((f"fig12.L{size}.eff_vs_sparse_pct", us,
                     f"{100 * sparse / ours:.1f}"))
    for k, s in GENERAL_CASES:
        t0 = time.perf_counter()
        l = ConvLayer(f"gen.k{k}s{s}", "transposed", 256, 256, 32, 32, k, k,
                      stride=s, group="transposed",
                      output_padding=min(1, s - 1))
        dense = cm.cycles_ideal_dense(l)
        ours = cm.cycles_our_decomposed(l)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig12.general_k{k}s{s}.speedup_x", us,
                     f"{dense / ours:.2f}"))
    # generative decoder workloads: whole-net naive-vs-decomposed costing
    for name, fn in GEN_WORKLOADS.items():
        t0 = time.perf_counter()
        gl = fn()
        rep = cm.report(gl)
        trn = cm.training_report(gl)
        us = (time.perf_counter() - t0) * 1e6
        tag = f"fig12.{name}"
        rows.append((f"{tag}.speedup_vs_naive_x", us,
                     f"{rep['speedup_vs_naive']:.2f}"))
        rows.append((f"{tag}.cycle_reduction_vs_naive_pct", us,
                     f"{rep['cycle_reduction_vs_naive_pct']:.1f}"))
        rows.append((f"{tag}.share_transposed_pct", us,
                     f"{rep['share_transposed_pct']:.1f}"))
        rows.append((f"{tag}.transposed_speedup_x", us,
                     f"{rep['transposed_speedup']:.2f}"))
        rows.append((f"{tag}.mac_skip_ratio", us,
                     f"{_tconv_mac_skip(gl):.2f}"))
        rows.append((f"{tag}.train_speedup_x", us,
                     f"{trn['train_speedup_vs_naive']:.2f}"))
    if not csv:
        print("== Fig. 12: transposed layers (output 128/256/512) ==")
        print("   paper: close to ideal sparse (up to 99%); aggregate 3.5x")
        print("   + generative decoders (EcoFlow setting): DCGAN 64/128,")
        print("     diffusion U-Net decoder — naive vs decomposed whole-net")
        for name, _, derived in rows:
            print(f"  {name:40s} {derived}")
    return rows


if __name__ == "__main__":
    run()
