"""Fig. 12 reproduction: transposed layers at output sizes 128/256/512 —
efficiency vs ideal sparse (paper: up to 99%, loss from input tiling).

Beyond the paper's ENet layers (k=3, s=2), a second sweep costs the general
(kernel, stride) parity schedules the engine now executes — the modeled
speedup tracks the ``s*s / (k/s-rounding)`` MAC-skip ratio of DESIGN.md §3.
"""

from __future__ import annotations

import time

from repro.core import cycle_model as cm
from repro.core.enet_spec import ConvLayer, enet_512_layers, transposed_layer_sets

# general-engine sweep: (kernel, stride) pairs served by the parity schedule
GENERAL_CASES = [(2, 2), (3, 2), (4, 2), (5, 2), (3, 3), (4, 3), (4, 4), (5, 4)]


def run(csv: bool = False) -> list[tuple]:
    t0 = time.perf_counter()
    layers = enet_512_layers()
    rows = []
    for size, ls in sorted(transposed_layer_sets(layers).items()):
        dense = sum(cm.cycles_ideal_dense(l) for l in ls)
        sparse = sum(cm.cycles_ideal_sparse(l) for l in ls)
        ours = sum(cm.cycles_our_decomposed(l) for l in ls)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig12.L{size}.speedup_x", us, f"{dense / ours:.2f}"))
        rows.append((f"fig12.L{size}.eff_vs_sparse_pct", us,
                     f"{100 * sparse / ours:.1f}"))
    for k, s in GENERAL_CASES:
        l = ConvLayer(f"gen.k{k}s{s}", "transposed", 256, 256, 32, 32, k, k,
                      stride=s, group="transposed",
                      output_padding=min(1, s - 1))
        dense = cm.cycles_ideal_dense(l)
        ours = cm.cycles_our_decomposed(l)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig12.general_k{k}s{s}.speedup_x", us,
                     f"{dense / ours:.2f}"))
    if not csv:
        print("== Fig. 12: transposed layers (output 128/256/512) ==")
        print("   paper: close to ideal sparse (up to 99%); aggregate 3.5x")
        for name, _, derived in rows:
            print(f"  {name:32s} {derived}")
    return rows


if __name__ == "__main__":
    run()
