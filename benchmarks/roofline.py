"""Roofline table: reads the dry-run artifacts (results/dryrun/*.json) and
prints the three terms + bottleneck + MODEL_FLOPS ratio per cell.

Run the dry-run first:
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config
from repro.launch.shapes import SHAPES

V5E_FLOPS = 197e12
CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(arch: str, shape: str) -> float:
    """Analytic MODEL_FLOPS per step: 6*N*D train (N = active params),
    2*N*D prefill, 2*N*B decode (matmul terms only — the denominator of the
    'useful compute' ratio)."""
    cfg = get_config(arch)
    counts = cfg.param_counts()
    n_active = counts["active"]
    cell = SHAPES[shape]
    if cell.kind == "train":
        return 6.0 * n_active * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n_active * cell.global_batch * cell.seq_len
    return 2.0 * n_active * cell.global_batch  # one decoded token


def load_records(out_dir: str = "results/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(csv: bool = False, out_dir: str = "results/dryrun") -> list[tuple]:
    rows = []
    recs = [r for r in load_records(out_dir) if r.get("mesh") == "16x16"]
    if not recs:
        rows.append(("roofline.no_dryrun_artifacts", 0.0,
                     "run repro.launch.dryrun first"))
        if not csv:
            print("no dry-run artifacts found under", out_dir)
        return rows
    if not csv:
        print(f"== Roofline (single pod, 256 chips x {V5E_FLOPS/1e12:.0f} "
              f"TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI) ==")
        hdr = (f"{'arch x shape':42s} {'comp_ms':>8s} {'mem_ms':>8s} "
               f"{'coll_ms':>8s} {'bound':>6s} {'MFLOP%':>7s} {'mem_GB':>7s}")
        print(hdr)
    for r in recs:
        cell = f"{r['arch']} x {r['shape']}"
        if r["status"] != "ok":
            if not csv:
                print(f"{cell:42s} {r['status'].upper()}: "
                      f"{r.get('reason', r.get('error', ''))[:60]}")
            rows.append((f"roofline.{r['arch']}.{r['shape']}.status", 0.0,
                         r["status"]))
            continue
        t = r["roofline"]
        dom = max(t, key=t.get).replace("_s", "")
        mf = model_flops(r["arch"], r["shape"])
        hlo_global = r["hlo"]["flops_per_chip"] * r["chips"]
        ratio = mf / hlo_global if hlo_global else 0.0
        if not csv:
            print(f"{cell:42s} {t['compute_s']*1e3:8.2f} "
                  f"{t['memory_s']*1e3:8.2f} {t['collective_s']*1e3:8.2f} "
                  f"{dom:>6s} {100*ratio:7.1f} "
                  f"{r['memory']['per_chip_total_gb']:7.2f}")
        rows.append((f"roofline.{r['arch']}.{r['shape']}.dominant", 0.0, dom))
        rows.append((f"roofline.{r['arch']}.{r['shape']}.model_flops_ratio",
                     0.0, f"{ratio:.3f}"))
    return rows


if __name__ == "__main__":
    run()
